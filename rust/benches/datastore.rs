//! Data-fabric benches: the paper's store-vs-shared-FS comparison
//! (Fig. 5 ordering — the in-memory tier must beat the shared file
//! system by ≥ 3x for intra-endpoint payload exchange), tier put/get
//! costs, spill throughput, and ref-dispatch vs inline task framing.
//! Emits `BENCH_datastore.json` (uploaded by CI next to
//! `BENCH_hotpath.json`).

mod harness;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use funcx::common::ids::{ContainerId, EndpointId, FunctionId, UserId};
use funcx::common::task::{Payload, Task};
use funcx::data::{DataChannel, SharedFsChannel};
use funcx::datastore::{DataFabric, TieredConfig, TieredStore};
use funcx::metrics::summarize;
use funcx::routing::WarmingAware;
use funcx::serialize::{pack, Buffer, Value, Wire};
use funcx::sim::{SimEndpoint, SimProfile, SimTask};

fn frame_of(len: usize) -> Buffer {
    pack(&Value::Bytes(vec![0xA5; len]), 0).unwrap()
}

fn mem_store() -> TieredStore {
    TieredStore::new(
        EndpointId::new(),
        TieredConfig { mem_high_watermark: 1 << 30, default_ttl_s: 0.0, spool_dir: None },
    )
    .unwrap()
}

fn disk_store() -> TieredStore {
    // Watermark 0: every frame spills (background) and never promotes.
    TieredStore::new(
        EndpointId::new(),
        TieredConfig { mem_high_watermark: 0, default_ttl_s: 0.0, spool_dir: None },
    )
    .unwrap()
}

fn main() {
    let sizes = [(64usize * 1024, "64KB"), (1024 * 1024, "1MB")];

    harness::section("store tiers: put/get (intra-endpoint payload exchange; §5.2)");
    let mut mem_get_s = f64::NAN;
    let mut fs_get_s = f64::NAN;
    for (size, label) in sizes {
        let n = 2000;
        let frame = frame_of(size);

        // Memory tier: put then repeated get (handle clones).
        let mem = mem_store();
        mem.put("k", frame.clone(), 0.0).unwrap();
        let t_mem = harness::bench(&format!("memory-tier get x{n} ({label})"), 5, || {
            for _ in 0..n {
                std::hint::black_box(mem.get("k", 0.0).unwrap());
            }
        }) / n as f64;
        harness::record(&format!("memory get ({label})"), t_mem * 1e6, "us/op");

        // Disk tier: spilled frame, every get reads the spool file.
        let disk = disk_store();
        disk.put("k", frame.clone(), 0.0).unwrap();
        assert!(disk.settle(Duration::from_secs(10)), "background spill must finish");
        let t_disk = harness::bench(&format!("disk-tier get x{n} ({label})"), 5, || {
            for _ in 0..n {
                std::hint::black_box(disk.get("k", 0.0).unwrap());
            }
        }) / n as f64;
        harness::record(&format!("disk get ({label})"), t_disk * 1e6, "us/op");

        // Shared-FS channel (the paper's baseline data plane).
        let fs = SharedFsChannel::temp().unwrap();
        fs.put("k", frame.as_slice()).unwrap();
        let t_fs = harness::bench(&format!("shared-fs get x{n} ({label})"), 5, || {
            for _ in 0..n {
                std::hint::black_box(fs.get("k").unwrap());
            }
        }) / n as f64;
        harness::record(&format!("shared-fs get ({label})"), t_fs * 1e6, "us/op");

        let speedup = t_fs / t_mem;
        println!("  => in-memory tier is {speedup:.1}x faster than shared-FS ({label})");
        harness::record(&format!("mem vs shared-fs speedup ({label})"), speedup, "x");
        if size == 1024 * 1024 {
            mem_get_s = t_mem;
            fs_get_s = t_fs;
        }
    }
    // Fig. 5 ordering acceptance: in-memory ≥ 3x shared file system.
    let speedup = fs_get_s / mem_get_s;
    assert!(
        speedup >= 3.0,
        "in-memory tier must be >= 3x the shared-FS path (got {speedup:.1}x)"
    );

    harness::section("spill throughput (memory -> disk tier)");
    {
        let n = 64;
        let size = 1024 * 1024;
        let frames: Vec<Buffer> = (0..n).map(|_| frame_of(size)).collect();
        let mean_s = harness::bench(&format!("put {n} x 1MB through a 8MB watermark"), 3, || {
            let s = TieredStore::new(
                EndpointId::new(),
                TieredConfig {
                    mem_high_watermark: 8 << 20,
                    default_ttl_s: 0.0,
                    spool_dir: None,
                },
            )
            .unwrap();
            for (i, f) in frames.iter().enumerate() {
                s.put(&format!("k{i}"), f.clone(), 0.0).unwrap();
            }
            // Spilling is asynchronous now; wait for the spiller to
            // drain so the measurement still covers the disk writes.
            assert!(s.settle(Duration::from_secs(60)));
            std::hint::black_box(s.stats.spills.load(Ordering::Relaxed));
        });
        let spilled_mb = (n * size) as f64 / 1e6 - 8.0; // roughly n MB minus resident
        harness::record("spill throughput", spilled_mb / mean_s, "MB/s");
        println!("  => ~{:.0} MB/s spill throughput", spilled_mb / mean_s);
    }

    harness::section("ref dispatch vs inline (8MB input through the task wire format)");
    {
        let n = 200;
        let big = frame_of(8 << 20);
        let mk_inline = || {
            Task::new(
                FunctionId::new(),
                EndpointId::new(),
                UserId::new(),
                None,
                Payload::Echo,
                big.clone(),
            )
        };
        let t_inline = harness::bench(&format!("inline to_buffer+from_buffer x{n}"), 5, || {
            let t = mk_inline();
            for _ in 0..n {
                let f = t.to_buffer();
                std::hint::black_box(Task::from_buffer(&f).unwrap());
            }
        }) / n as f64;
        harness::record("inline frame+parse (8MB)", t_inline * 1e6, "us/op");

        let store = Arc::new(mem_store());
        let fabric = DataFabric::new(store.clone());
        let dref = fabric.put("task-input:bench", big.clone(), 0.0).unwrap();
        let t_ref = harness::bench(&format!("by-ref to_buffer+from_buffer+resolve x{n}"), 5, || {
            let t = mk_inline().with_input_ref(dref.clone());
            for _ in 0..n {
                let f = t.to_buffer();
                let back = Task::from_buffer(&f).unwrap();
                let r = back.input_ref.as_ref().unwrap();
                std::hint::black_box(fabric.resolve(r, 0.0).unwrap());
            }
        }) / n as f64;
        harness::record("ref frame+parse+resolve (8MB)", t_ref * 1e6, "us/op");
        println!(
            "  => by-ref dispatch is {:.1}x cheaper per hop than re-framing 8MB inline",
            t_inline / t_ref
        );
        harness::record("ref vs inline speedup (8MB)", t_inline / t_ref, "x");
    }

    harness::section("ref-forwarded chain vs inline (3 stages, 64MB intermediates; sim)");
    {
        // The A → B → C shape: A's output feeds B, B's feeds C. With
        // result offload + ref forwarding the intermediates stay in the
        // endpoint store (ref frames on the wire, one store fetch per
        // hop); inline they cross the serial agent wire both ways.
        let mb64 = 64 * 1024 * 1024;
        let stages = [
            SimTask::noop().with_output_bytes(mb64),
            SimTask::noop().with_input_bytes(mb64).with_output_bytes(mb64),
            SimTask::noop().with_input_bytes(mb64),
        ];
        let run_chain = |profile: SimProfile| {
            let mut ep = SimEndpoint::new(profile, 1, Box::new(WarmingAware::default()), true, 5)
                .deterministic_cold(true);
            ep.prewarm(&[ContainerId(funcx::Uuid::NIL)]);
            ep.run_chain(&stages)
        };
        let by_ref = run_chain(SimProfile::theta());
        let mut inline_profile = SimProfile::theta();
        inline_profile.ref_threshold_bytes = u64::MAX;
        let inline = run_chain(inline_profile);
        harness::record("chain completion ref-forwarded (3x64MB)", by_ref * 1e3, "ms");
        harness::record("chain completion inline (3x64MB)", inline * 1e3, "ms");
        harness::record("ref chain speedup (3x64MB)", inline / by_ref, "x");
        println!(
            "  => ref-forwarded chain {:.0} ms vs inline {:.0} ms ({:.2}x)",
            by_ref * 1e3,
            inline * 1e3,
            inline / by_ref
        );
        // Acceptance: keeping intermediates in the store must beat
        // shipping them through the service path inline.
        assert!(
            inline > by_ref,
            "ref-forwarded chain ({by_ref}s) must beat inline ({inline}s)"
        );
    }

    harness::section("lock contention: p99 mem-hit latency under a spill storm (state machine)");
    {
        // The tentpole's perf half: a memory-tier get must stay
        // memory-speed while the store is spilling — the index mutex
        // holds metadata transitions only, never tier I/O. Measure
        // per-get latency on a hot resident key (a) uncontended and
        // (b) under a continuous watermark-crossing put storm that
        // keeps the background spiller writing 256 KB spool files.
        const SAMPLES: usize = 20_000;
        let sample_gets = |s: &TieredStore, key: &str| -> Vec<f64> {
            let mut lat = Vec::with_capacity(SAMPLES);
            for _ in 0..SAMPLES {
                let t0 = std::time::Instant::now();
                std::hint::black_box(s.get(key, 0.0).unwrap());
                lat.push(t0.elapsed().as_secs_f64());
            }
            lat
        };
        let store = TieredStore::new(
            EndpointId::new(),
            TieredConfig {
                mem_high_watermark: 4 << 20,
                default_ttl_s: 0.0,
                spool_dir: None,
            },
        )
        .unwrap();
        let hot = frame_of(64 * 1024);
        store.put("hot", hot, 0.0).unwrap();

        // Uncontended baseline.
        sample_gets(&store, "hot"); // warm-up
        let base = summarize(&sample_gets(&store, "hot"));

        // Spill storm: a writer thread keeps the memory tier over the
        // watermark with fresh 256 KB frames while we re-sample. The
        // sampling starts at the same instant and touches the hot key
        // every iteration, so LRU keeps requeuing it past the spiller's
        // victim picks — its gets stay memory-tier throughout (asserted
        // below). No warm-up gap: an untouched hot key would be the
        // oldest entry and the first victim.
        let store = Arc::new(store);
        let stop = Arc::new(AtomicBool::new(false));
        let storm = {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let f = frame_of(256 * 1024);
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    store.put(&format!("storm{i}"), f.clone(), 0.0).unwrap();
                    i += 1;
                }
                i
            })
        };
        let contended = summarize(&sample_gets(&store, "hot"));
        // Captured before the storm winds down: every sampled get must
        // have been a memory hit (the constantly-touched hot key is
        // never the LRU victim while sampling runs), or the comparison
        // would be measuring disk reads, not lock contention.
        let disk_hits = store.stats.disk_hits.load(Ordering::Relaxed);
        stop.store(true, Ordering::Relaxed);
        let storm_puts = storm.join().unwrap();
        assert_eq!(disk_hits, 0, "sampled gets must all be memory-tier hits");
        let spills = store.stats.spills.load(Ordering::Relaxed);
        assert!(spills > 0, "the storm never forced a spill ({storm_puts} puts)");

        harness::record("mem-hit p99 uncontended", base.p99 * 1e6, "us");
        harness::record("mem-hit p99 under spill storm", contended.p99 * 1e6, "us");
        harness::record("mem-hit p99 contention ratio", contended.p99 / base.p99, "x");
        println!(
            "  => p99 {:.2} us uncontended vs {:.2} us under storm ({} spills) — {:.2}x",
            base.p99 * 1e6,
            contended.p99 * 1e6,
            spills,
            contended.p99 / base.p99
        );
        // Acceptance: within 2x of uncontended (+25 us absolute floor —
        // at sub-microsecond baselines a single scheduler wakeup would
        // otherwise dominate the ratio). Before the state-machine
        // rework, a 256 KB spool write under the index lock put
        // disk-write latency on this path's tail.
        assert!(
            contended.p99 <= base.p99 * 2.0 + 25e-6,
            "mem-hit p99 under spill storm {:.2} us vs uncontended {:.2} us — \
             tier I/O is back under the index lock",
            contended.p99 * 1e6,
            base.p99 * 1e6
        );
    }

    harness::section("replication & failover (survivable data fabric)");
    {
        // Replica push cost plus resolve-ladder latency: the healthy
        // owner path vs failing over through a replica holder. A fresh
        // fabric per closure invocation keeps every resolve off the
        // verified cache — bench()'s warm-up pass would otherwise turn
        // the timed runs into cache hits.
        let n = 200;
        let frame = frame_of(256 * 1024);
        let owner = Arc::new(mem_store());
        let replica = Arc::new(mem_store());

        // Mint by-ref results in the owner store and push one replica
        // copy of each into the peer store — the copy the service makes
        // per Success result when replication_factor > 0.
        let refs: Vec<_> = (0..n)
            .map(|i| {
                let mut r = owner.put(&format!("task-result:b{i}"), frame.clone(), 0.0).unwrap();
                replica.put_with_ttl(&r.replica_key(), frame.clone(), None, 0.0).unwrap();
                r.replicas = vec![replica.owner()];
                r
            })
            .collect();

        let rkey = refs[0].replica_key();
        let t_push = harness::bench(&format!("replica push x{n} (256KB)"), 5, || {
            for _ in 0..n {
                std::hint::black_box(
                    replica.put_with_ttl(&rkey, frame.clone(), None, 0.0).unwrap(),
                );
            }
        }) / n as f64;
        harness::record("replica push (256KB)", t_push * 1e6, "us/op");

        let t_owner = harness::bench(&format!("cold resolve via owner x{n} (256KB)"), 5, || {
            let fab = DataFabric::new(Arc::new(mem_store()));
            fab.connect_peer(owner.owner(), owner.clone());
            for r in &refs {
                std::hint::black_box(fab.resolve(r, 0.0).unwrap());
            }
        }) / n as f64;
        harness::record("cold resolve via owner (256KB)", t_owner * 1e6, "us/op");

        let t_failover = harness::bench(&format!("cold failover resolve x{n} (256KB)"), 5, || {
            // Owner never connected: dead or decommissioned. The ladder
            // must fall through to the advertised replica holder on
            // every single resolve (asserted via the failover counter).
            let fab = DataFabric::new(Arc::new(mem_store()));
            fab.connect_peer(replica.owner(), replica.clone());
            for r in &refs {
                std::hint::black_box(fab.resolve(r, 0.0).unwrap());
            }
            assert_eq!(fab.stats.failovers.load(Ordering::Relaxed), n as u64);
        }) / n as f64;
        harness::record("cold failover resolve (256KB)", t_failover * 1e6, "us/op");
        harness::record("failover vs owner ratio", t_failover / t_owner, "x");
        println!(
            "  => push {:.2} us, owner resolve {:.2} us, failover resolve {:.2} us ({:.2}x)",
            t_push * 1e6,
            t_owner * 1e6,
            t_failover * 1e6,
            t_failover / t_owner
        );

        // Replication must stay off the critical path: the sim ships
        // replica copies asynchronously, so makespan with R=2 matches
        // R=0 exactly while the background replica bytes are accounted.
        let mb64 = 64 * 1024 * 1024;
        let tasks: Vec<SimTask> =
            (0..50).map(|_| SimTask::noop().with_output_bytes(mb64)).collect();
        let run_rep = |copies: usize| {
            let mut ep = SimEndpoint::new(
                SimProfile::theta(),
                2,
                Box::new(WarmingAware::default()),
                true,
                7,
            )
            .deterministic_cold(true)
            .with_replication(copies);
            ep.prewarm(&[ContainerId(funcx::Uuid::NIL)]);
            ep.run(&tasks)
        };
        let base = run_rep(0);
        let replicated = run_rep(2);
        harness::record("sim makespan R=0 (50x64MB results)", base.completion_s, "s");
        harness::record("sim makespan R=2 (50x64MB results)", replicated.completion_s, "s");
        harness::record(
            "sim replica bytes R=2",
            replicated.replica_bytes as f64 / (1 << 20) as f64,
            "MB",
        );
        println!(
            "  => R=2 makespan {:.2} s vs R=0 {:.2} s; {} background replica pushes ({} MB)",
            replicated.completion_s,
            base.completion_s,
            replicated.replica_pushes,
            replicated.replica_bytes >> 20
        );
        // Acceptance: replication is asynchronous — it must not move
        // the makespan at all, while every copy is accounted.
        assert_eq!(replicated.completion_s, base.completion_s);
        assert_eq!(replicated.replica_pushes, 2 * 50);
    }

    harness::write_json("BENCH_datastore.json");
}
