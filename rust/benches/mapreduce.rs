//! E6 / Table 1 — MapReduce WordCount & Sort phase times under the
//! in-memory store vs the shared FS: paper-scale model plus a real
//! scaled-down WordCount on both live data channels.

mod harness;

use std::collections::BTreeMap;

use funcx::data::{DataChannel, InMemoryChannel, SharedFsChannel};
use funcx::experiments as exp;

fn main() {
    harness::section("Table 1 — paper-scale model (30 GB, 300x300 tasks)");
    println!(
        "{:<10} {:<10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "app", "transport", "in-read", "map", "iw", "ir", "reduce", "out", "total"
    );
    for r in exp::table1_mapreduce() {
        let p = r.phases;
        println!(
            "{:<10} {:<10} {:>9.2} {:>9.1} {:>9.2} {:>9.2} {:>9.1} {:>9.2} {:>9.1}",
            r.app,
            r.transport.name(),
            p.input_read_s,
            p.map_process_s,
            p.intermediate_write_s,
            p.intermediate_read_s,
            p.reduce_process_s,
            p.output_write_s,
            p.total()
        );
    }
    println!("(paper per-task: WC iw 3.55/8.15 ir 33.39/43.40; Sort iw 3.27/5.32 ir 11.37/41.77)");

    harness::section("real scaled-down WordCount shuffle (16x16, live channels)");
    let run = |ch: &dyn DataChannel| {
        let maps = 16;
        let reduces = 16;
        let mut rng = funcx::common::rng::Rng::new(1);
        // map + write
        for m in 0..maps {
            let mut parts: Vec<BTreeMap<u32, u32>> = vec![BTreeMap::new(); reduces];
            for _ in 0..20_000 {
                let w = rng.below(997) as u32;
                *parts[w as usize % reduces].entry(w).or_insert(0) += 1;
            }
            for (r, part) in parts.iter().enumerate() {
                let blob: Vec<u8> = part
                    .iter()
                    .flat_map(|(k, v)| k.to_le_bytes().into_iter().chain(v.to_le_bytes()))
                    .collect();
                ch.put(&format!("s/m{m}r{r}"), &blob).unwrap();
            }
        }
        // read + reduce
        let mut totals: BTreeMap<u32, u64> = BTreeMap::new();
        for r in 0..reduces {
            for m in 0..maps {
                let blob = ch.get(&format!("s/m{m}r{r}")).unwrap();
                for rec in blob.chunks_exact(8) {
                    let k = u32::from_le_bytes(rec[0..4].try_into().unwrap());
                    let v = u32::from_le_bytes(rec[4..8].try_into().unwrap());
                    *totals.entry(k).or_insert(0) += v as u64;
                }
            }
        }
        assert_eq!(totals.values().sum::<u64>(), 16 * 20_000);
    };
    let mem = InMemoryChannel::default();
    harness::bench("wordcount shuffle via in-memory", 3, || run(&mem));
    let fs = SharedFsChannel::temp().unwrap();
    harness::bench("wordcount shuffle via shared-fs", 3, || run(&fs));
}
