//! E11 / §7.5 — internal batching ablation: 10 000 no-ops on 4 Theta
//! nodes with manager bulk task requests on vs off, plus the live
//! user-facing batch API.

mod harness;

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::Payload;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::experiments as exp;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

fn main() {
    harness::section("§7.5 — internal batching ablation (simulated, paper setup)");
    let r = exp::batching_ablation();
    println!("batching ON : {:>8.1} s   (paper: 6.7 s)", r.batched_s);
    println!("batching OFF: {:>8.1} s   (paper: 118 s)", r.unbatched_s);
    println!("speedup     : {:>8.1}x  (paper: 17.6x)", r.unbatched_s / r.batched_s);

    harness::section("live user-facing batch API vs singleton submits");
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("bench");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("local", "").unwrap();
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 2, workers_per_node: 4, ..Default::default() })
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = fc.register_function("noop", Payload::Noop).unwrap();

    harness::bench("500 no-ops via run_batch", 3, || {
        let inputs: Vec<Value> = (0..500).map(|_| Value::Null).collect();
        let tasks = fc.run_batch(f, ep, &inputs).unwrap();
        fc.get_batch_results(&tasks, Duration::from_secs(60)).unwrap();
    });
    harness::bench("500 no-ops via singleton run()", 3, || {
        let tasks: Vec<_> = (0..500).map(|_| fc.run(f, ep, &Value::Null).unwrap()).collect();
        fc.get_batch_results(&tasks, Duration::from_secs(60)).unwrap();
    });

    fh.shutdown();
    agent.join();
}
