//! E2/E3/E4 / Fig. 4 + §7.2.3 — strong & weak scaling of the funcX agent
//! to 131 072 containers (discrete-event simulation; see DESIGN.md §5).

mod harness;

use funcx::experiments as exp;
use funcx::sim::SimProfile;

fn main() {
    harness::section("Fig. 4(a) strong scaling — Theta, 100k concurrent requests");
    for (label, dur, counts) in [
        ("no-op", 0.0, vec![64, 128, 256, 512, 1024, 2048]),
        ("1s sleep", 1.0, vec![256, 1024, 2048, 4096, 8192]),
    ] {
        println!("{label}:");
        for p in exp::fig4_strong(SimProfile::theta(), 100_000, dur, &counts) {
            println!(
                "  {:>6} containers  {:>9.1} s  ({:>7.0} tasks/s)",
                p.containers, p.completion_s, p.throughput
            );
        }
    }
    println!("(paper: no-op stops improving at 256 containers, sleep at 2048)");

    harness::section("Fig. 4(b) weak scaling — Cori, 10 requests/container");
    for (label, dur) in [("no-op", 0.0), ("1s sleep", 1.0), ("1min stress", 60.0)] {
        println!("{label}:");
        let counts = [256usize, 1024, 4096, 16_384, 65_536, 131_072];
        for p in exp::fig4_weak(SimProfile::cori(), 10, dur, &counts) {
            println!(
                "  {:>7} containers ({:>8} tasks)  {:>9.1} s",
                p.containers,
                p.containers * 10,
                p.completion_s
            );
        }
    }
    println!("(paper: 131072 containers / 1.3M no-ops complete; sleep ~flat to 2048; stress to 16384)");

    harness::section("§7.2.3 peak agent throughput");
    let theta = exp::peak_throughput(SimProfile::theta());
    let cori = exp::peak_throughput(SimProfile::cori());
    println!("Theta: {theta:.0} tasks/s (paper: 1694)");
    println!("Cori:  {cori:.0} tasks/s (paper: 1466)");

    harness::section("simulator cost");
    harness::bench("simulate 100k no-ops @ 2048 containers", 3, || {
        let _ = exp::fig4_strong(SimProfile::theta(), 100_000, 0.0, &[2048]);
    });

    harness::section("agent dispatch cost at 1k/10k managers (indexed routing)");
    {
        use funcx::common::ids::ContainerId;
        use funcx::common::rng::Rng;
        use funcx::routing::WarmingAware;
        use funcx::sim::{SimEndpoint, SimTask};
        // The sim drives the real RoutingTable; wall-clock per routed
        // task should grow sub-linearly with the manager fleet.
        let types: Vec<ContainerId> = (1..=10).map(ContainerId::from_bits).collect();
        let mut rng = Rng::new(13);
        let tasks: Vec<SimTask> = (0..50_000)
            .map(|_| SimTask::with_container(types[rng.below(types.len())], 0.0))
            .collect();
        for &nodes in &[1_000usize, 10_000] {
            let mut ep = SimEndpoint::new(
                SimProfile::theta(),
                nodes,
                Box::new(WarmingAware { prefetch: 10 }),
                true,
                17,
            )
            .deterministic_cold(true);
            ep.prewarm(&types);
            let t0 = std::time::Instant::now();
            let r = ep.run(&tasks);
            let el = t0.elapsed().as_secs_f64();
            println!(
                "  {:>6} managers  {:>8.2} s wall  ({:>6.1} µs/task routed, {} colds)",
                nodes,
                el,
                1e6 * el / tasks.len() as f64,
                r.cold_starts
            );
        }
    }
}
