//! E2/E3/E4 / Fig. 4 + §7.2.3 — strong & weak scaling of the funcX agent
//! to 131 072 containers (discrete-event simulation; see DESIGN.md §5).

mod harness;

use funcx::experiments as exp;
use funcx::sim::SimProfile;

fn main() {
    harness::section("Fig. 4(a) strong scaling — Theta, 100k concurrent requests");
    for (label, dur, counts) in [
        ("no-op", 0.0, vec![64, 128, 256, 512, 1024, 2048]),
        ("1s sleep", 1.0, vec![256, 1024, 2048, 4096, 8192]),
    ] {
        println!("{label}:");
        for p in exp::fig4_strong(SimProfile::theta(), 100_000, dur, &counts) {
            println!(
                "  {:>6} containers  {:>9.1} s  ({:>7.0} tasks/s)",
                p.containers, p.completion_s, p.throughput
            );
        }
    }
    println!("(paper: no-op stops improving at 256 containers, sleep at 2048)");

    harness::section("Fig. 4(b) weak scaling — Cori, 10 requests/container");
    for (label, dur) in [("no-op", 0.0), ("1s sleep", 1.0), ("1min stress", 60.0)] {
        println!("{label}:");
        let counts = [256usize, 1024, 4096, 16_384, 65_536, 131_072];
        for p in exp::fig4_weak(SimProfile::cori(), 10, dur, &counts) {
            println!(
                "  {:>7} containers ({:>8} tasks)  {:>9.1} s",
                p.containers,
                p.containers * 10,
                p.completion_s
            );
        }
    }
    println!("(paper: 131072 containers / 1.3M no-ops complete; sleep ~flat to 2048; stress to 16384)");

    harness::section("§7.2.3 peak agent throughput");
    let theta = exp::peak_throughput(SimProfile::theta());
    let cori = exp::peak_throughput(SimProfile::cori());
    println!("Theta: {theta:.0} tasks/s (paper: 1694)");
    println!("Cori:  {cori:.0} tasks/s (paper: 1466)");

    harness::section("simulator cost");
    harness::bench("simulate 100k no-ops @ 2048 containers", 3, || {
        let _ = exp::fig4_strong(SimProfile::theta(), 100_000, 0.0, &[2048]);
    });
}
