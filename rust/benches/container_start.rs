//! E8 / Table 3 — cold container instantiation across (system, tech)
//! pairs, plus live warm-pool micro-benches.

mod harness;

use funcx::common::ids::ContainerId;
use funcx::common::rng::Rng;
use funcx::containers::WarmPool;
use funcx::experiments as exp;

fn main() {
    harness::section("Table 3 — cold instantiation samples (10k per model)");
    println!("{:<8} {:<12} {:>8} {:>8} {:>8}", "system", "container", "min", "max", "mean");
    for r in exp::table3_containers(10_000, 42) {
        println!(
            "{:<8} {:<12} {:>8.2} {:>8.2} {:>8.2}",
            r.system, r.container, r.min_s, r.max_s, r.mean_s
        );
    }
    println!("(paper: 9.83/14.06/10.40, 7.25/31.26/8.49, 1.74/1.88/1.79, 1.19/1.26/1.22)");

    harness::section("warm-pool operations (hot path of every dispatch)");
    let types: Vec<ContainerId> = (1..=16).map(ContainerId::from_bits).collect();
    harness::bench("1M acquire/release on a 64-slot pool", 3, || {
        let mut pool = WarmPool::new(64, 600.0);
        let mut rng = Rng::new(1);
        let mut held: Vec<usize> = Vec::new();
        for i in 0..1_000_000u64 {
            if held.len() >= 64 || (i % 3 == 0 && !held.is_empty()) {
                let slot = held.swap_remove(rng.below(held.len()));
                pool.release(slot, i as f64 * 1e-6);
            } else {
                let c = types[rng.below(types.len())];
                if let Some(s) = pool.acquire(c, i as f64 * 1e-6) {
                    held.push(s);
                }
            }
        }
        std::hint::black_box(pool.cold_starts());
    });
}
