//! E8 / Table 3 — cold container instantiation across (system, tech)
//! pairs, live warm-pool micro-benches, and the process-executor
//! measured-cold-start section: real forked worker children feed their
//! spawn cost into the routing comparison, and warming-aware routing
//! must beat random on that measured cost (asserted in-bench).

mod harness;

use funcx::common::ids::{ContainerId, ManagerId};
use funcx::common::rng::Rng;
use funcx::containers::{WarmPool, TABLE3_MODELS};
use funcx::experiments as exp;
use funcx::routing::{ManagerView, Randomized, Scheduler, WarmingAware};
use funcx::runtime::{ProcessExecutor, ProcessExecutorConfig, WorkerExecutor};

/// Cold-start outcome of one routed 3000-task workload.
struct RunStats {
    cold_starts: u64,
    cold_seconds: f64,
}

/// Route a fixed 3000-task, 10-type workload across 10 managers x 10
/// slots, charging each cold start the *measured* child spawn cost and
/// feeding it back into the pools' EWMAs (what the live agent does).
/// Tasks are short, so execution overlaps are ignored and the policies
/// differ only in where cold starts land.
fn run_routing(mut sched: Box<dyn Scheduler>, start_cost: f64) -> RunStats {
    const MANAGERS: usize = 10;
    const SLOTS: usize = 10;
    const TYPES: u128 = 10;
    const TASKS: usize = 3000;
    let ids: Vec<ManagerId> = (1..=MANAGERS as u128).map(ManagerId::from_bits).collect();
    let mut pools: Vec<WarmPool> = (0..MANAGERS).map(|_| WarmPool::new(SLOTS, 600.0)).collect();
    let types: Vec<ContainerId> = (1..=TYPES).map(ContainerId::from_bits).collect();
    let mut task_rng = Rng::new(7); // same task sequence for every policy
    let mut route_rng = Rng::new(11);
    let mut stats = RunStats { cold_starts: 0, cold_seconds: 0.0 };
    for i in 0..TASKS {
        let now = i as f64 * 1e-3;
        let ct = types[task_rng.below(types.len())];
        let views: Vec<ManagerView> = ids
            .iter()
            .zip(&pools)
            .map(|(id, p)| ManagerView {
                id: *id,
                deployed: p.deployed_census(),
                warm_idle: p.warm_census(),
                available_slots: p.available_slots(),
                total_slots: p.capacity(),
                queued: 0,
                endpoint: None,
                cold_start_est_s: p.start_cost_estimate().unwrap_or(start_cost),
            })
            .collect();
        let routed = sched.route(Some(ct), &views, &mut route_rng);
        let mid = routed.expect("all managers have free slots");
        let idx = ids.iter().position(|x| *x == mid).unwrap();
        let (slot, cold) = pools[idx].acquire_with_origin(ct, now).expect("slots free");
        if cold {
            stats.cold_starts += 1;
            stats.cold_seconds += start_cost;
            pools[idx].note_start_cost(start_cost);
        }
        pools[idx].release(slot, now + 1e-4).unwrap();
    }
    stats
}

fn main() {
    harness::section("Table 3 — cold instantiation samples (10k per model)");
    println!("{:<8} {:<12} {:>8} {:>8} {:>8}", "system", "container", "min", "max", "mean");
    for r in exp::table3_containers(10_000, 42) {
        println!(
            "{:<8} {:<12} {:>8.2} {:>8.2} {:>8.2}",
            r.system, r.container, r.min_s, r.max_s, r.mean_s
        );
    }
    println!("(paper: 9.83/14.06/10.40, 7.25/31.26/8.49, 1.74/1.88/1.79, 1.19/1.26/1.22)");

    harness::section("Table 3 — statistical pin (sample mean within 2% of the row)");
    for (i, model) in TABLE3_MODELS.all().into_iter().enumerate() {
        let mut rng = Rng::new(0xC0FFEE ^ i as u64);
        let n = 10_000;
        let sampled: f64 = (0..n).map(|_| model.sample(&mut rng)).sum::<f64>() / n as f64;
        let target = model.mean_s;
        let rel = ((sampled - target) / target).abs();
        let label = format!("{}/{}", model.system.name(), model.tech.name());
        println!("  {label:<16} sampled {sampled:>6.2} s  target {target:>6.2} s  rel {rel:.4}");
        harness::record(&format!("{label} rel mean error"), rel, "ratio");
        assert!(rel < 0.02, "{label}: sampled mean {sampled} vs {target}, rel {rel}");
    }
    println!("  all four models within the 2% statistical pin");

    harness::section("warm-pool operations (hot path of every dispatch)");
    let types: Vec<ContainerId> = (1..=16).map(ContainerId::from_bits).collect();
    harness::bench("1M acquire/release on a 64-slot pool", 3, || {
        let mut pool = WarmPool::new(64, 600.0);
        let mut rng = Rng::new(1);
        let mut held: Vec<usize> = Vec::new();
        for i in 0..1_000_000u64 {
            if held.len() >= 64 || (i % 3 == 0 && !held.is_empty()) {
                let slot = held.swap_remove(rng.below(held.len()));
                pool.release(slot, i as f64 * 1e-6).unwrap();
            } else {
                let c = types[rng.below(types.len())];
                if let Some(s) = pool.acquire(c, i as f64 * 1e-6) {
                    held.push(s);
                }
            }
        }
        std::hint::black_box(pool.cold_starts());
    });

    harness::section("process executor — measured cold starts (real forks)");
    let ex = ProcessExecutor::new(ProcessExecutorConfig::new(env!("CARGO_BIN_EXE_funcx")));
    let mut costs = Vec::new();
    for slot in 0..8 {
        let measured = ex.start_slot(1, slot).unwrap();
        costs.push(measured.expect("process backend measures starts"));
    }
    for slot in 0..8 {
        ex.stop_slot(1, slot);
    }
    let mean_start = costs.iter().sum::<f64>() / costs.len() as f64;
    let min_start = costs.iter().copied().fold(f64::INFINITY, f64::min);
    let mean_ms = mean_start * 1e3;
    let min_ms = min_start * 1e3;
    println!("  8 forks: spawn + handshake mean {mean_ms:.2} ms   min {min_ms:.2} ms");
    harness::record("measured child start (mean)", mean_start, "s");
    harness::record("measured child start (min)", min_start, "s");

    harness::section("warming-aware vs random routing on measured cold starts");
    let wa = run_routing(Box::new(WarmingAware { prefetch: 10 }), mean_start);
    let rnd = run_routing(Box::new(Randomized { prefetch: 10 }), mean_start);
    let wa_n = wa.cold_starts;
    let rnd_n = rnd.cold_starts;
    let wa_s = wa.cold_seconds;
    let rnd_s = rnd.cold_seconds;
    println!("  warming-aware: {wa_n:>4} cold starts = {wa_s:>7.2} s of measured start cost");
    println!("  randomized:    {rnd_n:>4} cold starts = {rnd_s:>7.2} s of measured start cost");
    harness::record("warming-aware cold starts", wa_n as f64, "count");
    harness::record("randomized cold starts", rnd_n as f64, "count");
    harness::record("warming-aware cold seconds", wa_s, "s");
    harness::record("randomized cold seconds", rnd_s, "s");
    assert!(wa_s < rnd_s, "warming-aware must beat random: {wa_s} s vs {rnd_s} s");
    let saved = 100.0 * (rnd_s - wa_s) / rnd_s;
    println!("  warming-aware saves {saved:.1}% of the measured cold-start cost");

    harness::write_json("BENCH_container.json");
}
