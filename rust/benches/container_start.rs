//! E8 / Table 3 — cold container instantiation across (system, tech)
//! pairs, live warm-pool micro-benches, the process-executor
//! measured-cold-start section (real forked worker children feed their
//! spawn cost into the routing comparison, and warming-aware routing
//! must beat random on that measured cost), and the worker-IPC section:
//! pipelined v2 frame dispatch must be ≥2x serial request/reply on
//! no-op payloads, with parent-side per-exchange allocations flat in
//! input size. All pins asserted in-bench.

mod harness;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use funcx::common::ids::{ContainerId, ManagerId};
use funcx::common::rng::Rng;
use funcx::common::task::Payload;
use funcx::containers::{WarmPool, TABLE3_MODELS};
use funcx::experiments as exp;
use funcx::routing::{ManagerView, Randomized, Scheduler, WarmingAware};
use funcx::runtime::{BatchItem, ProcessExecutor, ProcessExecutorConfig, WorkerExecutor};
use funcx::serialize::Buffer;

/// Byte-counting allocator for the IPC zero-clone pin: dispatch writes
/// each input trailer straight from the task's buffer, so what the
/// parent allocates per exchange must be protocol overhead only —
/// independent of input size.
struct CountingAlloc;

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Cold-start outcome of one routed 3000-task workload.
struct RunStats {
    cold_starts: u64,
    cold_seconds: f64,
}

/// Route a fixed 3000-task, 10-type workload across 10 managers x 10
/// slots, charging each cold start the *measured* child spawn cost and
/// feeding it back into the pools' EWMAs (what the live agent does).
/// Tasks are short, so execution overlaps are ignored and the policies
/// differ only in where cold starts land.
fn run_routing(mut sched: Box<dyn Scheduler>, start_cost: f64) -> RunStats {
    const MANAGERS: usize = 10;
    const SLOTS: usize = 10;
    const TYPES: u128 = 10;
    const TASKS: usize = 3000;
    let ids: Vec<ManagerId> = (1..=MANAGERS as u128).map(ManagerId::from_bits).collect();
    let mut pools: Vec<WarmPool> = (0..MANAGERS).map(|_| WarmPool::new(SLOTS, 600.0)).collect();
    let types: Vec<ContainerId> = (1..=TYPES).map(ContainerId::from_bits).collect();
    let mut task_rng = Rng::new(7); // same task sequence for every policy
    let mut route_rng = Rng::new(11);
    let mut stats = RunStats { cold_starts: 0, cold_seconds: 0.0 };
    for i in 0..TASKS {
        let now = i as f64 * 1e-3;
        let ct = types[task_rng.below(types.len())];
        let views: Vec<ManagerView> = ids
            .iter()
            .zip(&pools)
            .map(|(id, p)| ManagerView {
                id: *id,
                deployed: p.deployed_census(),
                warm_idle: p.warm_census(),
                available_slots: p.available_slots(),
                total_slots: p.capacity(),
                queued: 0,
                endpoint: None,
                cold_start_est_s: p.start_cost_estimate().unwrap_or(start_cost),
            })
            .collect();
        let routed = sched.route(Some(ct), &views, &mut route_rng);
        let mid = routed.expect("all managers have free slots");
        let idx = ids.iter().position(|x| *x == mid).unwrap();
        let (slot, cold) = pools[idx].acquire_with_origin(ct, now).expect("slots free");
        if cold {
            stats.cold_starts += 1;
            stats.cold_seconds += start_cost;
            pools[idx].note_start_cost(start_cost);
        }
        pools[idx].release(slot, now + 1e-4).unwrap();
    }
    stats
}

fn main() {
    harness::section("Table 3 — cold instantiation samples (10k per model)");
    println!("{:<8} {:<12} {:>8} {:>8} {:>8}", "system", "container", "min", "max", "mean");
    for r in exp::table3_containers(10_000, 42) {
        println!(
            "{:<8} {:<12} {:>8.2} {:>8.2} {:>8.2}",
            r.system, r.container, r.min_s, r.max_s, r.mean_s
        );
    }
    println!("(paper: 9.83/14.06/10.40, 7.25/31.26/8.49, 1.74/1.88/1.79, 1.19/1.26/1.22)");

    harness::section("Table 3 — statistical pin (sample mean within 2% of the row)");
    for (i, model) in TABLE3_MODELS.all().into_iter().enumerate() {
        let mut rng = Rng::new(0xC0FFEE ^ i as u64);
        let n = 10_000;
        let sampled: f64 = (0..n).map(|_| model.sample(&mut rng)).sum::<f64>() / n as f64;
        let target = model.mean_s;
        let rel = ((sampled - target) / target).abs();
        let label = format!("{}/{}", model.system.name(), model.tech.name());
        println!("  {label:<16} sampled {sampled:>6.2} s  target {target:>6.2} s  rel {rel:.4}");
        harness::record(&format!("{label} rel mean error"), rel, "ratio");
        assert!(rel < 0.02, "{label}: sampled mean {sampled} vs {target}, rel {rel}");
    }
    println!("  all four models within the 2% statistical pin");

    harness::section("warm-pool operations (hot path of every dispatch)");
    let types: Vec<ContainerId> = (1..=16).map(ContainerId::from_bits).collect();
    harness::bench("1M acquire/release on a 64-slot pool", 3, || {
        let mut pool = WarmPool::new(64, 600.0);
        let mut rng = Rng::new(1);
        let mut held: Vec<usize> = Vec::new();
        for i in 0..1_000_000u64 {
            if held.len() >= 64 || (i % 3 == 0 && !held.is_empty()) {
                let slot = held.swap_remove(rng.below(held.len()));
                pool.release(slot, i as f64 * 1e-6).unwrap();
            } else {
                let c = types[rng.below(types.len())];
                if let Some(s) = pool.acquire(c, i as f64 * 1e-6) {
                    held.push(s);
                }
            }
        }
        std::hint::black_box(pool.cold_starts());
    });

    harness::section("process executor — measured cold starts (real forks)");
    let ex = ProcessExecutor::new(ProcessExecutorConfig::new(env!("CARGO_BIN_EXE_funcx")));
    let mut costs = Vec::new();
    for slot in 0..8 {
        let measured = ex.start_slot(1, slot).unwrap();
        costs.push(measured.expect("process backend measures starts"));
    }
    for slot in 0..8 {
        ex.stop_slot(1, slot);
    }
    let mean_start = costs.iter().sum::<f64>() / costs.len() as f64;
    let min_start = costs.iter().copied().fold(f64::INFINITY, f64::min);
    let mean_ms = mean_start * 1e3;
    let min_ms = min_start * 1e3;
    println!("  8 forks: spawn + handshake mean {mean_ms:.2} ms   min {min_ms:.2} ms");
    harness::record("measured child start (mean)", mean_start, "s");
    harness::record("measured child start (min)", min_start, "s");

    harness::section("warming-aware vs random routing on measured cold starts");
    let wa = run_routing(Box::new(WarmingAware { prefetch: 10 }), mean_start);
    let rnd = run_routing(Box::new(Randomized { prefetch: 10 }), mean_start);
    let wa_n = wa.cold_starts;
    let rnd_n = rnd.cold_starts;
    let wa_s = wa.cold_seconds;
    let rnd_s = rnd.cold_seconds;
    println!("  warming-aware: {wa_n:>4} cold starts = {wa_s:>7.2} s of measured start cost");
    println!("  randomized:    {rnd_n:>4} cold starts = {rnd_s:>7.2} s of measured start cost");
    harness::record("warming-aware cold starts", wa_n as f64, "count");
    harness::record("randomized cold starts", rnd_n as f64, "count");
    harness::record("warming-aware cold seconds", wa_s, "s");
    harness::record("randomized cold seconds", rnd_s, "s");
    assert!(wa_s < rnd_s, "warming-aware must beat random: {wa_s} s vs {rnd_s} s");
    let saved = 100.0 * (rnd_s - wa_s) / rnd_s;
    println!("  warming-aware saves {saved:.1}% of the measured cold-start cost");

    harness::section("worker IPC — pipelined v2 frames vs serial request/reply");
    const IPC_TASKS: usize = 600;
    let noop_items = |n: usize, input_bytes: usize| -> Vec<BatchItem> {
        (0..n)
            .map(|_| BatchItem {
                payload: Payload::Noop,
                input: if input_bytes == 0 {
                    Buffer::empty()
                } else {
                    Buffer::from_vec(vec![0x5A; input_bytes])
                },
            })
            .collect()
    };
    let throughput = |depth: usize| -> f64 {
        let mut cfg = ProcessExecutorConfig::new(env!("CARGO_BIN_EXE_funcx"));
        cfg.pipeline_depth = depth;
        let ex = ProcessExecutor::new(cfg);
        ex.start_slot(2, 0).unwrap();
        // One warm-up window outside the clock.
        ex.execute_batch(2, 0, &noop_items(16, 0), &mut |_, r| {
            r.unwrap();
        });
        let items = noop_items(IPC_TASKS, 0);
        let t0 = std::time::Instant::now();
        ex.execute_batch(2, 0, &items, &mut |_, r| {
            r.unwrap();
        });
        let rate = IPC_TASKS as f64 / t0.elapsed().as_secs_f64();
        ex.stop_slot(2, 0);
        rate
    };
    let serial = throughput(1);
    let pipelined = throughput(4);
    let speedup = pipelined / serial;
    println!("  serial depth-1:    {serial:>9.0} tasks/s");
    println!("  pipelined depth-4: {pipelined:>9.0} tasks/s   ({speedup:.2}x)");
    harness::record("IPC serial tasks/s", serial, "tasks/s");
    harness::record("IPC pipelined depth-4 tasks/s", pipelined, "tasks/s");
    harness::record("IPC pipelined speedup", speedup, "ratio");
    assert!(
        pipelined >= 2.0 * serial,
        "pipelined depth-4 must be >= 2x serial on no-op payloads: \
         {pipelined:.0} vs {serial:.0} tasks/s"
    );

    harness::section("worker IPC — zero-clone dispatch (parent allocations vs input size)");
    // Noop never reads its input, so the trailer rides the wire untouched
    // and every reply stays tiny regardless of input size: the bytes the
    // parent allocates per exchange are pure protocol overhead. Inputs
    // themselves are built before the measurement window.
    let alloc_per_exchange = |input_bytes: usize| -> f64 {
        const EXCHANGES: usize = 200;
        let ex = ProcessExecutor::new(ProcessExecutorConfig::new(env!("CARGO_BIN_EXE_funcx")));
        ex.start_slot(3, 0).unwrap();
        // Warm up the channel, demux map, and write path first.
        ex.execute_batch(3, 0, &noop_items(16, input_bytes), &mut |_, r| {
            r.unwrap();
        });
        let items = noop_items(EXCHANGES, input_bytes);
        let before = ALLOC_BYTES.load(Ordering::SeqCst);
        ex.execute_batch(3, 0, &items, &mut |_, r| {
            r.unwrap();
        });
        let grew = ALLOC_BYTES.load(Ordering::SeqCst) - before;
        ex.stop_slot(3, 0);
        grew as f64 / EXCHANGES as f64
    };
    let small = alloc_per_exchange(1024);
    let big = alloc_per_exchange(256 * 1024);
    println!("  parent allocations/exchange: {small:>7.0} B @ 1 KB inputs, {big:>7.0} B @ 256 KB");
    harness::record("IPC alloc/exchange @1KB input", small, "bytes");
    harness::record("IPC alloc/exchange @256KB input", big, "bytes");
    assert!(
        big <= small + 16.0 * 1024.0,
        "parent-side allocations must not scale with input size: \
         {small:.0} B/exchange at 1 KB vs {big:.0} B/exchange at 256 KB"
    );

    harness::write_json("BENCH_container.json");
}
