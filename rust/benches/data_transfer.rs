//! E5 / Fig. 5 — the four intra-endpoint transfer approaches across
//! point-to-point, broadcast(20), and all-to-all(20) patterns, 1 kB–1 GB.
//! Also times the two *real* data channels on live I/O.

mod harness;

use funcx::data::{CommPattern, DataChannel, InMemoryChannel, SharedFsChannel, Transport};
use funcx::experiments as exp;

fn main() {
    harness::section("Fig. 5 — transport models (Theta parameterisation)");
    let sizes: Vec<usize> = (0..=10).map(|i| 1024usize << (2 * i)).collect();
    let pts = exp::fig5_transfer(&sizes);
    for pattern in [
        CommPattern::PointToPoint,
        CommPattern::Broadcast { nodes: 20 },
        CommPattern::AllToAll { nodes: 20 },
    ] {
        println!("{pattern:?}:");
        print!("  {:>12}", "size(B)");
        for t in Transport::ALL {
            print!(" {:>12}", t.name());
        }
        println!();
        for &size in &sizes {
            print!("  {size:>12}");
            for t in Transport::ALL {
                let p = pts
                    .iter()
                    .find(|p| p.transport == t && p.pattern == pattern && p.size_bytes == size)
                    .unwrap();
                print!(" {:>12.6}", p.time_s);
            }
            println!();
        }
    }
    println!("(paper: MPI best, ZMQ/Redis close, sharedFS worst; all converge at large sizes)");

    harness::section("real data channels (live I/O, 64 MB in 1 MB chunks)");
    let chunk = vec![0xA5u8; 1 << 20];
    let mem = InMemoryChannel::default();
    harness::bench("in-memory put+get 64x1MB", 5, || {
        for i in 0..64 {
            mem.put(&format!("k{i}"), &chunk).unwrap();
        }
        for i in 0..64 {
            mem.get(&format!("k{i}")).unwrap();
        }
    });
    let fs = SharedFsChannel::temp().unwrap();
    harness::bench("shared-fs put+get 64x1MB", 5, || {
        for i in 0..64 {
            fs.put(&format!("k{i}"), &chunk).unwrap();
        }
        for i in 0..64 {
            fs.get(&format!("k{i}")).unwrap();
        }
    });
}
