//! E9/E10 / Figs. 6–7 — warming-aware vs randomized routing: batch
//! completion time and container cold starts across batch sizes and
//! function durations (10 nodes × 10 workers, 10 container types).

mod harness;

use funcx::experiments as exp;

fn main() {
    harness::section("Figs. 6-7 — warming-aware vs random routing");
    println!(
        "{:>5} {:>6} | {:>11} {:>11} {:>7} | {:>9} {:>9}",
        "dur", "batch", "warming(s)", "random(s)", "gain", "wa-cold", "rnd-cold"
    );
    let pts = exp::fig6_fig7_routing(&[500, 1000, 2000, 3000], &[0.0, 1.0, 5.0, 20.0], 7);
    for p in &pts {
        let gain = 100.0 * (p.random_completion_s - p.warming_completion_s)
            / p.random_completion_s;
        println!(
            "{:>5.0} {:>6} | {:>11.1} {:>11.1} {:>6.1}% | {:>9} {:>9}",
            p.duration_s,
            p.batch,
            p.warming_completion_s,
            p.random_completion_s,
            gain,
            p.warming_cold_starts,
            p.random_cold_starts
        );
    }
    println!("(paper: up to 61% completion reduction at short durations; 22 cold");
    println!(" starts at 3000 tasks; benefit diminishes as duration grows)");

    harness::section("ablation — all four scheduler policies (batch 2000, dur 1s)");
    {
        use funcx::common::ids::ContainerId;
        use funcx::common::rng::Rng;
        use funcx::routing::{BinPacking, Randomized, RoundRobin, Scheduler, WarmingAware};
        use funcx::sim::{SimEndpoint, SimProfile, SimTask};
        let types: Vec<ContainerId> = (1..=10).map(ContainerId::from_bits).collect();
        let mut profile = SimProfile::theta();
        profile.workers_per_node = 10;
        let mut rng = Rng::new(5);
        let tasks: Vec<SimTask> = (0..2000)
            .map(|_| SimTask::with_container(types[rng.below(types.len())], 1.0))
            .collect();
        let scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(WarmingAware { prefetch: 10 }),
            Box::new(Randomized { prefetch: 10 }),
            Box::new(RoundRobin::default()),
            Box::new(BinPacking::default()),
        ];
        for sched in scheds {
            let name = sched.name();
            let r = SimEndpoint::new(profile, 10, sched, true, 21)
                .deterministic_cold(true)
                .run(&tasks);
            println!(
                "  {:<14} completion {:>8.1} s   colds {:>5}   warm hits {:>5}",
                name, r.completion_s, r.cold_starts, r.warm_hits
            );
        }
    }

    harness::section("routing decision cost (the agent's per-task hot path)");
    harness::bench("route 3000 tasks through the full sim", 5, || {
        let _ = exp::fig6_fig7_routing(&[3000], &[0.0], 3);
    });
}
