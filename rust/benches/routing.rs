//! E9/E10 / Figs. 6–7 — warming-aware vs randomized routing: batch
//! completion time and container cold starts across batch sizes and
//! function durations (10 nodes × 10 workers, 10 container types).

mod harness;

use funcx::experiments as exp;

fn main() {
    harness::section("Figs. 6-7 — warming-aware vs random routing");
    println!(
        "{:>5} {:>6} | {:>11} {:>11} {:>7} | {:>9} {:>9}",
        "dur", "batch", "warming(s)", "random(s)", "gain", "wa-cold", "rnd-cold"
    );
    let pts = exp::fig6_fig7_routing(&[500, 1000, 2000, 3000], &[0.0, 1.0, 5.0, 20.0], 7);
    for p in &pts {
        let gain = 100.0 * (p.random_completion_s - p.warming_completion_s)
            / p.random_completion_s;
        println!(
            "{:>5.0} {:>6} | {:>11.1} {:>11.1} {:>6.1}% | {:>9} {:>9}",
            p.duration_s,
            p.batch,
            p.warming_completion_s,
            p.random_completion_s,
            gain,
            p.warming_cold_starts,
            p.random_cold_starts
        );
    }
    println!("(paper: up to 61% completion reduction at short durations; 22 cold");
    println!(" starts at 3000 tasks; benefit diminishes as duration grows)");

    harness::section("ablation — all four scheduler policies (batch 2000, dur 1s)");
    {
        use funcx::common::ids::ContainerId;
        use funcx::common::rng::Rng;
        use funcx::routing::{BinPacking, Randomized, RoundRobin, Scheduler, WarmingAware};
        use funcx::sim::{SimEndpoint, SimProfile, SimTask};
        let types: Vec<ContainerId> = (1..=10).map(ContainerId::from_bits).collect();
        let mut profile = SimProfile::theta();
        profile.workers_per_node = 10;
        let mut rng = Rng::new(5);
        let tasks: Vec<SimTask> = (0..2000)
            .map(|_| SimTask::with_container(types[rng.below(types.len())], 1.0))
            .collect();
        let scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(WarmingAware { prefetch: 10 }),
            Box::new(Randomized { prefetch: 10 }),
            Box::new(RoundRobin::default()),
            Box::new(BinPacking::default()),
        ];
        for sched in scheds {
            let name = sched.name();
            let r = SimEndpoint::new(profile, 10, sched, true, 21)
                .deterministic_cold(true)
                .run(&tasks);
            println!(
                "  {:<14} completion {:>8.1} s   colds {:>5}   warm hits {:>5}",
                name, r.completion_s, r.cold_starts, r.warm_hits
            );
        }
    }

    harness::section("routing decision cost (the agent's per-task hot path)");
    harness::bench("route 3000 tasks through the full sim", 5, || {
        let _ = exp::fig6_fig7_routing(&[3000], &[0.0], 3);
    });

    harness::section("indexed routing sweep — O(M) scan vs RoutingTable, 100/1k/10k managers");
    {
        use funcx::common::ids::{ContainerId, ManagerId};
        use funcx::common::rng::Rng;
        use funcx::routing::{ManagerView, RoutingTable, Scheduler, WarmingAware};
        use std::collections::HashMap;

        let n_types = 10usize;
        let mk_views = |m: usize| -> Vec<ManagerView> {
            (0..m)
                .map(|i| {
                    let t = ContainerId::from_bits((i % n_types) as u128 + 1);
                    let mut warm = HashMap::new();
                    warm.insert(t, 2usize);
                    ManagerView {
                        id: ManagerId::from_bits(i as u128 + 1),
                        deployed: warm.clone(),
                        warm_idle: warm,
                        available_slots: 8,
                        total_slots: 10,
                        queued: 0,
                        endpoint: None,
                        cold_start_est_s: 0.0,
                    }
                })
                .collect()
        };
        println!(
            "{:>9} | {:>14} {:>14} | {:>8} {:>10}",
            "managers", "scan ns/route", "index ns/route", "speedup", "identical"
        );
        for &m in &[100usize, 1_000, 10_000] {
            let views = mk_views(m);
            let table = RoutingTable::with_views(0, views.clone());
            let mut wa = WarmingAware::default();
            let types: Vec<ContainerId> =
                (1..=n_types).map(|t| ContainerId::from_bits(t as u128)).collect();

            // Scan path: fewer routes at large M (it is the slow one).
            let r_scan = (2_000_000 / m).max(200);
            let mut rng = Rng::new(1);
            let t0 = std::time::Instant::now();
            for i in 0..r_scan {
                std::hint::black_box(wa.route(
                    Some(types[i % n_types]),
                    &views,
                    &mut rng,
                ));
            }
            let scan_ns = t0.elapsed().as_nanos() as f64 / r_scan as f64;

            // Indexed path.
            let r_idx = 200_000usize;
            let mut rng = Rng::new(1);
            let t0 = std::time::Instant::now();
            for i in 0..r_idx {
                std::hint::black_box(wa.route_indexed(
                    Some(types[i % n_types]),
                    &table,
                    &mut rng,
                ));
            }
            let idx_ns = t0.elapsed().as_nanos() as f64 / r_idx as f64;

            // Decision equality on a sample.
            let mut r1 = Rng::new(7);
            let mut r2 = Rng::new(7);
            let identical = (0..1000).all(|i| {
                wa.route(Some(types[i % n_types]), &views, &mut r1)
                    == wa.route_indexed(Some(types[i % n_types]), &table, &mut r2)
            });
            println!(
                "{:>9} | {:>14.0} {:>14.0} | {:>7.1}x {:>10}",
                m,
                scan_ns,
                idx_ns,
                scan_ns / idx_ns,
                identical
            );
        }
        println!("(indexed cost must stay ~flat as managers grow: sub-linear per-route growth)");
    }
}
