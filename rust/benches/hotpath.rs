//! Whole-stack hot-path micro-benches (the §Perf targets): per-task
//! dispatch cost through the live stack, serialization facade, store
//! queue ops, and PJRT artifact execution throughput.

mod harness;

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Counting allocator so the facade section reports allocations per op
/// (the scratch-reuse/zero-copy trajectory tracked across PRs via the
/// BENCH_hotpath.json artifact).
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_during(f: impl FnOnce()) -> usize {
    let before = ALLOCS.load(Ordering::SeqCst);
    f();
    ALLOCS.load(Ordering::SeqCst) - before
}

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::Payload;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::runtime::{PjrtRuntime, TensorArg};
use funcx::sdk::FuncXClient;
use funcx::serialize::{pack, unpack, Value};
use funcx::service::FuncXService;
use funcx::store::KvStore;

/// Minimal queue interface so the contention workload runs identically
/// over the sharded [`KvStore`] and the single-mutex baseline.
trait QueueOps: Clone + Send + 'static {
    fn push(&self, key: &str, v: Vec<u8>);
    /// Blocking batched pop; returns the number of items popped.
    fn pop_many(&self, key: &str, max: usize, timeout: Duration) -> usize;
}

impl QueueOps for KvStore {
    fn push(&self, key: &str, v: Vec<u8>) {
        self.rpush(key, v);
    }
    fn pop_many(&self, key: &str, max: usize, timeout: Duration) -> usize {
        self.blpop_n(key, max, timeout).len()
    }
}

/// Replica of the seed's store design: every queue op serializes behind
/// ONE global mutex — the baseline the sharded store is measured against.
#[derive(Clone)]
struct SingleMutexStore {
    inner: Arc<(Mutex<HashMap<String, VecDeque<Vec<u8>>>>, Condvar)>,
}

impl SingleMutexStore {
    fn new() -> Self {
        SingleMutexStore { inner: Arc::new((Mutex::new(HashMap::new()), Condvar::new())) }
    }
}

impl QueueOps for SingleMutexStore {
    fn push(&self, key: &str, v: Vec<u8>) {
        let mut g = self.inner.0.lock().unwrap();
        g.entry(key.to_string()).or_default().push_back(v);
        drop(g);
        self.inner.1.notify_all();
    }
    fn pop_many(&self, key: &str, max: usize, timeout: Duration) -> usize {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.0.lock().unwrap();
        loop {
            if let Some(l) = g.get_mut(key) {
                if !l.is_empty() {
                    let take = max.min(l.len());
                    l.drain(..take);
                    return take;
                }
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return 0;
            }
            let (guard, timed_out) = self.inner.1.wait_timeout(g, remaining).unwrap();
            g = guard;
            if timed_out.timed_out() {
                return 0;
            }
        }
    }
}

/// P producers × C consumers over `n_keys` queue keys (distinct keys ⇒
/// distinct endpoints' queues). Returns elapsed seconds for `total` items
/// through the store.
fn contention_run<Q: QueueOps>(
    q: &Q,
    producers: usize,
    consumers: usize,
    n_keys: usize,
    per_producer: usize,
) -> f64 {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let total = producers * per_producer;
    let consumed = Arc::new(AtomicUsize::new(0));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let q = q.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_producer {
                q.push(&format!("q{}", (p + i) % n_keys), vec![0u8; 64]);
            }
        }));
    }
    for c in 0..consumers {
        let q = q.clone();
        let consumed = consumed.clone();
        handles.push(std::thread::spawn(move || {
            // Each consumer drains the keys congruent to it mod `consumers`.
            let mut keys: Vec<String> = (0..n_keys)
                .filter(|k| k % consumers == c)
                .map(|k| format!("q{k}"))
                .collect();
            if keys.is_empty() {
                keys.push(format!("q{}", c % n_keys));
            }
            let mut i = 0usize;
            while consumed.load(Ordering::Relaxed) < total {
                let got = q.pop_many(&keys[i % keys.len()], 64, Duration::from_millis(1));
                if got > 0 {
                    consumed.fetch_add(got, Ordering::Relaxed);
                }
                i += 1;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    harness::section("serialization facade (§4.5)");
    let v = Value::map([
        ("inputs", Value::Str("image_000.h5".into())),
        ("pixels", Value::F32s(vec![1.5; 4096])),
        ("meta", Value::List(vec![Value::Int(1), Value::Bool(true)])),
    ]);
    harness::bench("pack+unpack 10k medium values", 5, || {
        for _ in 0..10_000 {
            let b = pack(&v, 7).unwrap();
            std::hint::black_box(unpack(&b).unwrap());
        }
    });
    // Allocations per op: pack should be ~1 (scratch reuse + one shared
    // frame); unpack only allocates what the decoded Value needs.
    let n = allocs_during(|| {
        for _ in 0..10_000 {
            std::hint::black_box(pack(&v, 7).unwrap());
        }
    });
    println!("  pack allocs/op:          {:.2}", n as f64 / 10_000.0);
    harness::record("pack allocs/op", n as f64 / 10_000.0, "allocs");
    let frame = pack(&v, 7).unwrap();
    let n = allocs_during(|| {
        for _ in 0..10_000 {
            std::hint::black_box(unpack(&frame).unwrap());
        }
    });
    println!("  unpack allocs/op:        {:.2}", n as f64 / 10_000.0);
    harness::record("unpack allocs/op", n as f64 / 10_000.0, "allocs");
    // Buffer clone: the per-hop cost on the dispatch path — a refcount
    // bump, zero allocations, O(1) in payload size.
    harness::bench("clone 1M packed buffers (16 KB frames)", 5, || {
        for _ in 0..1_000_000 {
            std::hint::black_box(frame.clone());
        }
    });
    let n = allocs_during(|| {
        for _ in 0..100_000 {
            std::hint::black_box(frame.clone());
        }
    });
    println!("  clone allocs/op:         {:.5}", n as f64 / 100_000.0);
    harness::record("clone allocs/op", n as f64 / 100_000.0, "allocs");

    harness::section("store queue ops (the broker hot path; §4.1)");
    let kv = KvStore::new();
    harness::bench("100k rpush + lpop_n(64)", 5, || {
        for i in 0..100_000u32 {
            kv.rpush("q", i.to_le_bytes().to_vec());
        }
        let mut n = 0;
        while n < 100_000 {
            n += kv.lpop_n("q", 64).len().max(1);
        }
    });

    harness::section("store contention — 4 producers × 4 consumers, 8 queue keys");
    {
        let (producers, consumers, n_keys, per) = (4usize, 4usize, 8usize, 100_000usize);
        let total = producers * per;
        // Warm-up + 3 timed runs each, keep the best (min) like harness::bench.
        let run_best = |f: &dyn Fn() -> f64| {
            f();
            (0..3).map(|_| f()).fold(f64::INFINITY, f64::min)
        };
        let single = run_best(&|| {
            contention_run(&SingleMutexStore::new(), producers, consumers, n_keys, per)
        });
        let sharded =
            run_best(&|| contention_run(&KvStore::new(), producers, consumers, n_keys, per));
        println!(
            "  single-mutex baseline: {:>8.0} items/s   ({:.3} s)",
            total as f64 / single,
            single
        );
        println!(
            "  sharded KvStore:       {:>8.0} items/s   ({:.3} s)",
            total as f64 / sharded,
            sharded
        );
        println!(
            "  => {:.2}x throughput vs single mutex (target: >= 2x)",
            single / sharded
        );
    }

    harness::section("watch wakeups per consumed frame (hot key; coalescing baseline)");
    {
        // ROADMAP "watch granularity" says measure before optimizing:
        // a KV watch signals on EVERY push to the watched key, but the
        // epoch protocol lets a consumer drain whole batches per wait —
        // so the number that matters is waits-woken per consumed frame,
        // not signals published. This section records both for a hot
        // key under a saturating producer, as the baseline any future
        // wakeup-coalescing PR must beat.
        const FRAMES: usize = 100_000;
        let kv = KvStore::new();
        let watch = Arc::new(funcx::common::sync::Notify::new());
        kv.add_watch("hotq", watch.clone());
        let producer = {
            let kv = kv.clone();
            std::thread::spawn(move || {
                for _ in 0..FRAMES {
                    kv.rpush("hotq", vec![0u8; 32]);
                }
            })
        };
        let mut consumed = 0usize;
        while consumed < FRAMES {
            let seen = watch.epoch();
            let got = kv.lpop_n("hotq", 256).len();
            if got == 0 {
                watch.wait_newer(seen, Duration::from_millis(10));
            } else {
                consumed += got;
            }
        }
        producer.join().unwrap();
        let notifies = watch.notify_count() as f64 / FRAMES as f64;
        let wakeups = watch.wakeup_count() as f64 / FRAMES as f64;
        println!(
            "  {FRAMES} frames: {notifies:.3} notifies/frame, {wakeups:.4} wakeups/frame"
        );
        harness::record("watch notifies per consumed frame (hot key)", notifies, "signals");
        harness::record("watch wakeups per consumed frame (hot key)", wakeups, "wakes");
    }

    harness::section("watch wakeups — coalesced producer, 64-frame bursts");
    {
        // Producer-side watch coalescing: the same hot key, but the
        // producer flushes whole bursts through `rpush_many`, which
        // appends the batch under one lock acquisition and publishes
        // ONE notify per flush. Pinned at <= 0.25 notifies per consumed
        // frame (a 64-frame burst should land near 1/64 ≈ 0.016) —
        // against ~1.0 for the frame-at-a-time baseline above.
        const FRAMES: usize = 100_000;
        const BURST: usize = 64;
        let kv = KvStore::new();
        let watch = Arc::new(funcx::common::sync::Notify::new());
        kv.add_watch("hotq", watch.clone());
        let producer = {
            let kv = kv.clone();
            std::thread::spawn(move || {
                for _ in 0..FRAMES / BURST {
                    let burst: Vec<funcx::serialize::Buffer> =
                        (0..BURST).map(|_| vec![0u8; 32].into()).collect();
                    kv.rpush_many("hotq", burst);
                }
            })
        };
        let mut consumed = 0usize;
        while consumed < FRAMES {
            let seen = watch.epoch();
            let got = kv.lpop_n("hotq", 256).len();
            if got == 0 {
                watch.wait_newer(seen, Duration::from_millis(10));
            } else {
                consumed += got;
            }
        }
        producer.join().unwrap();
        let notifies = watch.notify_count() as f64 / FRAMES as f64;
        let wakeups = watch.wakeup_count() as f64 / FRAMES as f64;
        println!(
            "  {FRAMES} frames in {BURST}-frame bursts: {notifies:.4} notifies/frame, {wakeups:.4} wakeups/frame"
        );
        harness::record(
            "watch notifies per consumed frame (coalesced 64-frame bursts)",
            notifies,
            "signals",
        );
        harness::record(
            "watch wakeups per consumed frame (coalesced 64-frame bursts)",
            wakeups,
            "wakes",
        );
        assert!(
            notifies <= 0.25,
            "producer-side coalescing regressed: {notifies:.4} notifies/frame under a \
             {BURST}-frame burst (pin: <= 0.25)"
        );
    }

    harness::section("live end-to-end dispatch overhead");
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("bench");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("local", "").unwrap();
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 2, workers_per_node: 4, ..Default::default() })
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = fc.register_function("noop", Payload::Noop).unwrap();
    let mean = harness::bench("2000 no-ops end-to-end (batch)", 3, || {
        let inputs: Vec<Value> = (0..2000).map(|_| Value::Null).collect();
        let tasks = fc.run_batch(f, ep, &inputs).unwrap();
        fc.get_batch_results(&tasks, Duration::from_secs(120)).unwrap();
    });
    println!(
        "  => {:.0} tasks/s end-to-end, {:.3} ms/task",
        2000.0 / mean,
        1e3 * mean / 2000.0
    );
    fh.shutdown();
    agent.join();

    harness::section("live fleet — 8 forwarders × 128 managers, concurrent submitters");
    {
        // One service, N endpoints each with its own forwarder + agent,
        // and each agent provisioning 16 nodes (managers) × 2 workers —
        // 128 managers fleet-wide, the §6 "hundreds of managers" scale
        // direction. Exercises store sharding (distinct queue keys),
        // the watch/latch wakeups, Arc task dispatch, and batched
        // result upload end to end — the topology the per-endpoint
        // benches can't.
        const ENDPOINTS: usize = 8;
        const NODES_PER_EP: usize = 16;
        const TASKS_PER_EP: usize = 2000;
        let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
        let (_u, tok) = svc.bootstrap_user("fleet");
        let fc = FuncXClient::new(svc.clone(), tok);
        let mut stacks = Vec::new();
        for i in 0..ENDPOINTS {
            let ep = fc.register_endpoint(&format!("ep{i}"), "").unwrap();
            let (fwd, agent_side) = link();
            let agent = EndpointBuilder::new()
                .config(EndpointConfig {
                    min_nodes: NODES_PER_EP,
                    workers_per_node: 2,
                    ..Default::default()
                })
                .heartbeat_period(0.05)
                .seed(100 + i as u64)
                .start(agent_side);
            let fh = svc.connect_endpoint(ep, fwd).unwrap();
            let f = fc.register_function(&format!("noop{i}"), Payload::Noop).unwrap();
            stacks.push((ep, f, fh, agent));
        }
        let run = || {
            let t0 = std::time::Instant::now();
            let handles: Vec<_> = stacks
                .iter()
                .map(|(ep, f, _, _)| {
                    let fc = fc.clone();
                    let (ep, f) = (*ep, *f);
                    std::thread::spawn(move || {
                        let inputs: Vec<Value> =
                            (0..TASKS_PER_EP).map(|_| Value::Null).collect();
                        let tasks = fc.run_batch(f, ep, &inputs).unwrap();
                        fc.get_batch_results(&tasks, Duration::from_secs(120)).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            t0.elapsed().as_secs_f64()
        };
        run(); // warm-up
        let secs = (0..3).map(|_| run()).fold(f64::INFINITY, f64::min);
        let total = (ENDPOINTS * TASKS_PER_EP) as f64;
        println!(
            "  {ENDPOINTS} endpoints x {NODES_PER_EP} nodes x {TASKS_PER_EP} no-ops: {:.3} s, {:>8.0} tasks/s fleet-wide",
            secs,
            total / secs
        );
        harness::record("multi-endpoint fleet throughput", total / secs, "tasks/s");
        // Forwarder-latch traffic per task across the fleet (queue
        // watches + link sends + result stores all multiplex onto one
        // latch): the live-stack companion to the hot-key watch
        // baseline above.
        let (notifies, wakeups) = stacks
            .iter()
            .map(|(_, _, fh, _)| fh.wake_counters())
            .fold((0u64, 0u64), |(n, w), (a, b)| (n + a, w + b));
        let per_task = 4.0 * total; // warm-up + 3 timed runs
        harness::record(
            "forwarder notifies per task (fleet)",
            notifies as f64 / per_task,
            "signals",
        );
        harness::record(
            "forwarder wakeups per task (fleet)",
            wakeups as f64 / per_task,
            "wakes",
        );
        for (_, _, fh, agent) in stacks {
            fh.shutdown();
            agent.join();
        }
    }

    harness::section("service-plane shard scaling (tasks/s per shard count)");
    {
        // Tentpole curve: the same fleet driven through a service plane
        // sharded N ways behind the consistent-hash ring. Each shard
        // owns its KV rows, fabric store, offload set, and result
        // latch, so the single-shard serializers — the "tasks"/
        // "task_state" hset stripes, the per-poll offload-set mutex,
        // and the one result `Notify` every waiter herds on — split N
        // ways. 32 submitter threads keep the service plane, not the
        // worker pool, the contended layer.
        const EPS: usize = 8;
        const SUBMITTERS_PER_EP: usize = 4;
        const TASKS_PER_SUBMITTER: usize = 500;
        const TOTAL: usize = EPS * SUBMITTERS_PER_EP * TASKS_PER_SUBMITTER;
        let run_n = |shards: usize| -> f64 {
            let svc = Arc::new(FuncXService::new(ServiceConfig {
                service_shards: shards,
                ..Default::default()
            }));
            let (_u, tok) = svc.bootstrap_user("scale");
            let fc = FuncXClient::new(svc.clone(), tok);
            let mut stacks = Vec::new();
            for i in 0..EPS {
                let ep = fc.register_endpoint(&format!("ep{i}"), "").unwrap();
                let (fwd, agent_side) = link();
                let agent = EndpointBuilder::new()
                    .config(EndpointConfig {
                        min_nodes: 2,
                        workers_per_node: 2,
                        ..Default::default()
                    })
                    .heartbeat_period(0.05)
                    .seed(500 + i as u64)
                    .start(agent_side);
                let fh = svc.connect_endpoint(ep, fwd).unwrap();
                let f = fc.register_function(&format!("noop{i}"), Payload::Noop).unwrap();
                stacks.push((ep, f, fh, agent));
            }
            let run = || {
                let t0 = std::time::Instant::now();
                let handles: Vec<_> = stacks
                    .iter()
                    .flat_map(|(ep, f, _, _)| {
                        (0..SUBMITTERS_PER_EP).map({
                            let fc = fc.clone();
                            let (ep, f) = (*ep, *f);
                            move |_| {
                                let fc = fc.clone();
                                std::thread::spawn(move || {
                                    let inputs: Vec<Value> = (0..TASKS_PER_SUBMITTER)
                                        .map(|_| Value::Null)
                                        .collect();
                                    let tasks = fc.run_batch(f, ep, &inputs).unwrap();
                                    fc.get_batch_results(&tasks, Duration::from_secs(120))
                                        .unwrap();
                                })
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                t0.elapsed().as_secs_f64()
            };
            run(); // warm-up
            let secs = (0..2).map(|_| run()).fold(f64::INFINITY, f64::min);
            for (_, _, fh, agent) in stacks {
                fh.shutdown();
                agent.join();
            }
            TOTAL as f64 / secs
        };
        let mut curve = Vec::new();
        for n in [1usize, 2, 4, 8] {
            let tps = run_n(n);
            println!(
                "  N={n}: {tps:>8.0} tasks/s fleet-wide  ({:>8.0} tasks/s per shard)",
                tps / n as f64
            );
            harness::record(&format!("fleet tasks/s @ {n} shards"), tps, "tasks/s");
            harness::record(
                &format!("fleet tasks/s per shard @ {n} shards"),
                tps / n as f64,
                "tasks/s",
            );
            curve.push((n, tps));
        }
        let t1 = curve[0].1;
        let t4 = curve[2].1;
        println!("  => N=4 vs N=1: {:.2}x (pin: >= 2.5x)", t4 / t1);
        harness::record("shard scaling N=4 over N=1", t4 / t1, "x");
        assert!(
            t4 >= 2.5 * t1,
            "shard scaling regressed: N=4 gives {t4:.0} tasks/s, \
             less than 2.5x the N=1 baseline of {t1:.0} tasks/s"
        );
    }

    harness::section("flight recorder overhead (tracing on vs off)");
    {
        // The observability acceptance pin: the registry + flight
        // recorder must stay off the hot path. Drive the same 4-endpoint
        // fleet twice — once with `trace_ring_capacity: 0` (the PR 7
        // baseline: no recorder anywhere) and once with the default
        // rings wired through service, forwarders, and agents — and
        // assert the traced run keeps >= 95% of baseline throughput.
        // The traced run's full registry exposition lands in
        // BENCH_metrics.json for the CI artifact.
        const EPS: usize = 4;
        const TASKS_PER_EP: usize = 2000;
        let run_cfg = |ring: usize| -> (f64, Option<String>) {
            let svc = Arc::new(FuncXService::new(ServiceConfig {
                trace_ring_capacity: ring,
                ..Default::default()
            }));
            let (_u, tok) = svc.bootstrap_user("trace");
            let fc = FuncXClient::new(svc.clone(), tok);
            let mut stacks = Vec::new();
            for i in 0..EPS {
                let ep = fc.register_endpoint(&format!("ep{i}"), "").unwrap();
                let (fwd, agent_side) = link();
                let mut builder = EndpointBuilder::new()
                    .config(EndpointConfig {
                        min_nodes: 2,
                        workers_per_node: 2,
                        ..Default::default()
                    })
                    .latency(svc.latency.clone())
                    .clock(svc.clock.clone())
                    .heartbeat_period(0.05)
                    .seed(900 + i as u64);
                if ring > 0 {
                    builder = builder.recorder(svc.recorder.clone());
                }
                let agent = builder.start(agent_side);
                let fh = svc.connect_endpoint(ep, fwd).unwrap();
                let f = fc.register_function(&format!("noop{i}"), Payload::Noop).unwrap();
                stacks.push((ep, f, fh, agent));
            }
            let run = || {
                let t0 = std::time::Instant::now();
                let handles: Vec<_> = stacks
                    .iter()
                    .map(|(ep, f, _, _)| {
                        let fc = fc.clone();
                        let (ep, f) = (*ep, *f);
                        std::thread::spawn(move || {
                            let inputs: Vec<Value> =
                                (0..TASKS_PER_EP).map(|_| Value::Null).collect();
                            let tasks = fc.run_batch(f, ep, &inputs).unwrap();
                            fc.get_batch_results(&tasks, Duration::from_secs(120)).unwrap();
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                t0.elapsed().as_secs_f64()
            };
            run(); // warm-up
            let secs = (0..3).map(|_| run()).fold(f64::INFINITY, f64::min);
            for (_, _, fh, agent) in stacks {
                fh.shutdown();
                agent.join();
            }
            let snapshot = (ring > 0).then(|| svc.metrics_snapshot().to_json());
            ((EPS * TASKS_PER_EP) as f64 / secs, snapshot)
        };
        let (off, _) = run_cfg(0);
        let (on, snapshot) = run_cfg(funcx::metrics::DEFAULT_RING_CAPACITY);
        println!("  tracing off: {off:>8.0} tasks/s");
        println!("  tracing on:  {on:>8.0} tasks/s  ({:.1}% of baseline)", 100.0 * on / off);
        harness::record("fleet tasks/s tracing off", off, "tasks/s");
        harness::record("fleet tasks/s tracing on", on, "tasks/s");
        harness::record("tracing throughput ratio (on/off)", on / off, "x");
        let json = snapshot.expect("traced run produces a snapshot");
        std::fs::write("BENCH_metrics.json", &json).unwrap();
        println!("  wrote BENCH_metrics.json ({} bytes)", json.len());
        assert!(
            on >= 0.95 * off,
            "flight recorder regressed the hot path: {on:.0} tasks/s traced vs \
             {off:.0} tasks/s baseline (pin: >= 0.95x)"
        );
    }

    harness::section("PJRT artifact execution (the compute hot path)");
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = PjrtRuntime::load_dir(dir).unwrap();
        let ids: Vec<i32> = (0..4096).map(|i| i % 256).collect();
        let vals = vec![1.0f32; 4096];
        harness::bench("reducer x100 (4096 -> 256 segment sum)", 5, || {
            for _ in 0..100 {
                rt.execute(
                    "reducer",
                    &[TensorArg::I32(ids.clone()), TensorArg::F32(vals.clone())],
                )
                .unwrap();
            }
        });
        let x = vec![0.1f32; 128 * 256];
        let w1 = vec![0.01f32; 256 * 512];
        let b1 = vec![0.0f32; 512];
        let w2 = vec![0.01f32; 512 * 128];
        let b2 = vec![0.0f32; 128];
        let m = harness::bench("surrogate x10 (128x256 MLP fwd)", 5, || {
            for _ in 0..10 {
                rt.execute(
                    "surrogate",
                    &[
                        TensorArg::F32(x.clone()),
                        TensorArg::F32(w1.clone()),
                        TensorArg::F32(b1.clone()),
                        TensorArg::F32(w2.clone()),
                        TensorArg::F32(b2.clone()),
                    ],
                )
                .unwrap();
            }
        });
        // 2 matmuls: 128x256x512 + 128x512x128 = 50.3 MFLOP x2 /inference
        let flops = 10.0 * 2.0 * (128.0 * 256.0 * 512.0 + 128.0 * 512.0 * 128.0);
        println!("  => {:.2} GFLOP/s through PJRT", flops / m / 1e9);
    } else {
        println!("artifacts missing — run `make artifacts` for PJRT benches");
    }

    harness::write_json("BENCH_hotpath.json");
}
