//! E7 / Table 2 — Colmena's four communication stages (1000 tasks, 1 MB
//! in / 1 MB out): paper-scale model plus real channel measurements.

mod harness;

use funcx::data::{DataChannel, InMemoryChannel, SharedFsChannel};
use funcx::experiments as exp;

fn main() {
    harness::section("Table 2 — Colmena stage model (1 MB payloads, 100 workers)");
    println!(
        "{:<12} {:>12} {:>12} {:>13} {:>12}",
        "transport", "input-write", "input-read", "result-write", "result-read"
    );
    for r in exp::table2_colmena() {
        println!(
            "{:<12} {:>10.2}ms {:>10.2}ms {:>11.2}ms {:>10.2}ms",
            r.transport.name(),
            1e3 * r.stages.input_write_s,
            1e3 * r.stages.input_read_s,
            1e3 * r.stages.result_write_s,
            1e3 * r.stages.result_read_s
        );
    }
    println!("(paper: Redis 7.15/0.70/18.04/0.11; SharedFS 32.31/11.36/244.72/3.50)");

    harness::section("real 1 MB task-payload round trips (live channels)");
    let payload = vec![0x42u8; 1 << 20];
    let mem = InMemoryChannel::default();
    harness::bench("in-memory 100x (write in, read in, write out, read out)", 5, || {
        for i in 0..100 {
            mem.put(&format!("in{i}"), &payload).unwrap();
            let x = mem.get(&format!("in{i}")).unwrap();
            mem.put(&format!("out{i}"), &x).unwrap();
            mem.get(&format!("out{i}")).unwrap();
        }
    });
    let fs = SharedFsChannel::temp().unwrap();
    harness::bench("shared-fs 100x (write in, read in, write out, read out)", 5, || {
        for i in 0..100 {
            fs.put(&format!("in{i}"), &payload).unwrap();
            let x = fs.get(&format!("in{i}")).unwrap();
            fs.put(&format!("out{i}"), &x).unwrap();
            fs.get(&format!("out{i}")).unwrap();
        }
    });
}
