//! Minimal bench harness shared by every bench target (criterion is
//! unavailable offline). Times closures over several iterations and
//! prints mean/min wall-clock alongside the experiment tables, and
//! records every number so a bench target can emit a machine-readable
//! JSON artifact (CI uploads `BENCH_hotpath.json` per run, giving the
//! facade/dispatch sections a trajectory across PRs).

#![allow(dead_code)]

use std::sync::Mutex;
use std::time::Instant;

/// (section, name, value, unit) records for the JSON artifact.
static RECORDS: Mutex<Vec<(String, String, f64, &'static str)>> = Mutex::new(Vec::new());
static SECTION: Mutex<String> = Mutex::new(String::new());

/// Time `f` `iters` times; print mean/min and return the mean seconds.
pub fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    // Warm-up run (not timed).
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("bench {name:<40} mean {:>10.4} s   min {:>10.4} s", mean, min);
    record(name, mean, "s");
    record(&format!("{name} (min)"), min, "s");
    mean
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
    *SECTION.lock().unwrap() = title.to_string();
}

/// Record a derived metric (throughput, allocs/op, …) under the current
/// section, for the JSON artifact.
pub fn record(name: &str, value: f64, unit: &'static str) {
    RECORDS.lock().unwrap().push((
        SECTION.lock().unwrap().clone(),
        name.to_string(),
        value,
        unit,
    ));
}

/// Write every recorded number as a JSON artifact at `path`.
pub fn write_json(path: &str) {
    let records = RECORDS.lock().unwrap();
    let mut out = String::from("{\n  \"benchmarks\": [\n");
    for (i, (section, name, value, unit)) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"section\": {}, \"name\": {}, \"value\": {}, \"unit\": \"{}\"}}{}\n",
            json_str(section),
            json_str(name),
            if value.is_finite() { format!("{value:.6}") } else { "null".into() },
            unit,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("\nwrote {path} ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
