//! Minimal bench harness shared by every bench target (criterion is
//! unavailable offline). Times closures over several iterations and
//! prints mean/min wall-clock alongside the experiment tables.

#![allow(dead_code)]

use std::time::Instant;

/// Time `f` `iters` times; print mean/min and return the mean seconds.
pub fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    // Warm-up run (not timed).
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("bench {name:<40} mean {:>10.4} s   min {:>10.4} s", mean, min);
    mean
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
