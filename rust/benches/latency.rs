//! E1 / Fig. 3 — latency decomposition of a warm-container task on the
//! live stack (service → forwarder → agent → manager → worker → back).

mod harness;

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::Payload;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::metrics::summarize;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

fn main() {
    harness::section("Fig. 3 — latency breakdown (live stack, warm containers)");
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("bench");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("local", "").unwrap();
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 4, ..Default::default() })
        .latency(svc.latency.clone())
        .clock(svc.clock.clone())
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = fc.register_function("noop", Payload::Noop).unwrap();

    // Warm the path.
    for _ in 0..20 {
        let t = fc.run(f, ep, &Value::Null).unwrap();
        fc.get_result(t, Duration::from_secs(10)).unwrap();
    }
    // Measured round trips.
    let mut rtts = Vec::new();
    for _ in 0..200 {
        let t0 = std::time::Instant::now();
        let t = fc.run(f, ep, &Value::Null).unwrap();
        fc.get_result(t, Duration::from_secs(10)).unwrap();
        rtts.push(t0.elapsed().as_secs_f64());
    }
    let s = summarize(&rtts);
    println!(
        "round trip (ms): mean {:.3}  p50 {:.3}  p99 {:.3}  min {:.3}",
        1e3 * s.mean,
        1e3 * s.p50,
        1e3 * s.p99,
        1e3 * s.min
    );
    let b = svc.latency.stage_summaries();
    println!(
        "stage means over {} tasks (ms): t_s {:.3}  t_f {:.3}  t_e {:.3}  t_w {:.3}",
        b.completed,
        1e3 * b.t_s.mean,
        1e3 * b.t_f.mean,
        1e3 * b.t_e.mean,
        1e3 * b.t_w.mean
    );
    println!(
        "stage p99 (ms):                 t_s {:.3}  t_f {:.3}  t_e {:.3}  t_w {:.3}",
        1e3 * b.t_s.p99,
        1e3 * b.t_f.p99,
        1e3 * b.t_e.p99,
        1e3 * b.t_w.p99
    );
    println!("(paper, Theta endpoint w/ 18 ms WAN: t_s ~ tens of ms dominated by auth; t_w smallest)");
    fh.shutdown();
    agent.join();
}
