//! Task flight recorder: typed per-hop trace events in bounded
//! per-component ring buffers, assembled on demand into one
//! cross-shard, cross-endpoint timeline.
//!
//! A [`TraceId`] is minted when a task is submitted and rides the wire
//! in the task's trailer meta (a `"trc"` field beside `"iref"`), so
//! every component that touches the task — shard, forwarder, agent,
//! worker, fabric, store — can stamp events against the same trace.
//! Components that run *under* a task but never see it (the fabric
//! resolve ladder, the store's put path) pick the identity up from a
//! thread-local [`TraceCtx`] set by the caller. Background work with no
//! task at all (the spiller, shed decisions) records key-only events;
//! [`FlightRecorder::assemble`] joins those in by data-ref key.
//!
//! Memory is bounded three ways: each component ring holds at most
//! `capacity` events (oldest dropped, drop count kept), the task→trace
//! index is a FIFO of [`INDEX_CAPACITY`] entries, and event payloads
//! are fixed-size apart from the ref key strings they already carried.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::common::ids::{EndpointId, TaskId, Uuid};
use crate::common::time::Time;

/// Identity of one task's journey through the system.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub Uuid);

impl TraceId {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        TraceId(Uuid::new())
    }
}

impl fmt::Display for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

impl std::str::FromStr for TraceId {
    type Err = <Uuid as std::str::FromStr>::Err;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(TraceId(s.parse()?))
    }
}

/// Where a ref resolve was satisfied in the fabric ladder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolveSource {
    /// Owner's local tiered store (memory or disk).
    Local,
    /// The fabric's byte-bounded frame cache.
    Cache,
    /// Fetched from a peer store.
    Peer,
    /// Served by a replica after the owner's copy was unreachable.
    Replica,
    /// Wide-area (Globus cost model) transfer.
    Globus,
}

impl ResolveSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            ResolveSource::Local => "local",
            ResolveSource::Cache => "cache",
            ResolveSource::Peer => "peer",
            ResolveSource::Replica => "replica",
            ResolveSource::Globus => "globus",
        }
    }
}

/// The typed per-hop event vocabulary (see docs/observability.md).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    /// Accepted by the service API (start of `t_s`).
    Submitted { endpoint: EndpointId },
    /// Persisted and appended to the owning shard's dispatch queue.
    ShardEnqueued { shard: u32 },
    /// Forwarder handed the task down the endpoint link.
    Forwarded { endpoint: EndpointId },
    /// Agent routed the task to a manager's queue.
    AgentDispatched { endpoint: EndpointId },
    /// A worker began executing (start of `t_w`).
    WorkerStarted { endpoint: EndpointId },
    /// The task's container slot was started cold before execution.
    /// `measured` distinguishes real executor-measured start costs from
    /// modeled (Table-3 sampled) ones.
    ColdStart { endpoint: EndpointId, seconds: f64, measured: bool },
    /// Predictive sizing warmed slots ahead of routed load.
    Prewarmed { endpoint: EndpointId, count: u32 },
    /// The worker finished (success or typed failure already decided).
    WorkerFinished { endpoint: EndpointId, success: bool },
    /// A data-ref resolve was satisfied, and where.
    RefResolved { key: String, source: ResolveSource },
    /// One bounded-backoff retry against a peer store.
    PeerRetry { key: String, attempt: u32 },
    /// The owner's copy was unreachable; a replica served the frame.
    ReplicaFailover { key: String },
    /// The resolve ladder was exhausted; `error` is the typed
    /// [`crate::Error`] variant name.
    ResolveFailed { key: String, error: &'static str },
    /// Background spiller moved the frame from memory to disk.
    Spilled { key: String },
    /// The store refused the put under spill backpressure.
    ShedPut { key: String },
    /// Agent lost; the task went back to the front of the shard queue.
    Redispatched { attempt: u32 },
    /// Requeued because its endpoint was decommissioned.
    DecommissionRequeued { endpoint: EndpointId },
    /// A frame was re-homed to a surviving store during decommission.
    FrameDrained { key: String },
    /// Terminal: the result was written to the owning shard's store.
    ResultStored { shard: u32, state: &'static str },
    /// Terminal: the task failed; `error` is the typed [`crate::Error`]
    /// variant name (or the task state for service-side abandons).
    TaskFailed { error: &'static str },
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Submitted { .. } => "Submitted",
            TraceKind::ShardEnqueued { .. } => "ShardEnqueued",
            TraceKind::Forwarded { .. } => "Forwarded",
            TraceKind::AgentDispatched { .. } => "AgentDispatched",
            TraceKind::WorkerStarted { .. } => "WorkerStarted",
            TraceKind::ColdStart { .. } => "ColdStart",
            TraceKind::Prewarmed { .. } => "Prewarmed",
            TraceKind::WorkerFinished { .. } => "WorkerFinished",
            TraceKind::RefResolved { .. } => "RefResolved",
            TraceKind::PeerRetry { .. } => "PeerRetry",
            TraceKind::ReplicaFailover { .. } => "ReplicaFailover",
            TraceKind::ResolveFailed { .. } => "ResolveFailed",
            TraceKind::Spilled { .. } => "Spilled",
            TraceKind::ShedPut { .. } => "ShedPut",
            TraceKind::Redispatched { .. } => "Redispatched",
            TraceKind::DecommissionRequeued { .. } => "DecommissionRequeued",
            TraceKind::FrameDrained { .. } => "FrameDrained",
            TraceKind::ResultStored { .. } => "ResultStored",
            TraceKind::TaskFailed { .. } => "TaskFailed",
        }
    }

    /// The data-ref key this event is about, if any (used to join
    /// key-only background events into a task's timeline).
    pub fn key(&self) -> Option<&str> {
        match self {
            TraceKind::RefResolved { key, .. }
            | TraceKind::PeerRetry { key, .. }
            | TraceKind::ReplicaFailover { key }
            | TraceKind::ResolveFailed { key, .. }
            | TraceKind::Spilled { key }
            | TraceKind::ShedPut { key }
            | TraceKind::FrameDrained { key } => Some(key),
            _ => None,
        }
    }

    /// Terminal events close a timeline.
    pub fn is_terminal(&self) -> bool {
        matches!(self, TraceKind::ResultStored { .. } | TraceKind::TaskFailed { .. })
    }

    fn detail(&self) -> String {
        match self {
            TraceKind::Submitted { endpoint } => format!("endpoint={endpoint}"),
            TraceKind::ShardEnqueued { shard } => format!("shard={shard}"),
            TraceKind::Forwarded { endpoint } => format!("endpoint={endpoint}"),
            TraceKind::AgentDispatched { endpoint } => format!("endpoint={endpoint}"),
            TraceKind::WorkerStarted { endpoint } => format!("endpoint={endpoint}"),
            TraceKind::ColdStart { endpoint, seconds, measured } => {
                format!("endpoint={endpoint} seconds={seconds:.3} measured={measured}")
            }
            TraceKind::Prewarmed { endpoint, count } => {
                format!("endpoint={endpoint} count={count}")
            }
            TraceKind::WorkerFinished { endpoint, success } => {
                format!("endpoint={endpoint} success={success}")
            }
            TraceKind::RefResolved { key, source } => {
                format!("key={key} source={}", source.as_str())
            }
            TraceKind::PeerRetry { key, attempt } => format!("key={key} attempt={attempt}"),
            TraceKind::ReplicaFailover { key } => format!("key={key}"),
            TraceKind::ResolveFailed { key, error } => format!("key={key} error={error}"),
            TraceKind::Spilled { key } => format!("key={key}"),
            TraceKind::ShedPut { key } => format!("key={key}"),
            TraceKind::Redispatched { attempt } => format!("attempt={attempt}"),
            TraceKind::DecommissionRequeued { endpoint } => format!("endpoint={endpoint}"),
            TraceKind::FrameDrained { key } => format!("key={key}"),
            TraceKind::ResultStored { shard, state } => format!("shard={shard} state={state}"),
            TraceKind::TaskFailed { error } => format!("error={error}"),
        }
    }
}

/// One recorded hop.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Global record order (monotone across all components).
    pub seq: u64,
    pub at: Time,
    /// Recording component, e.g. `shard-0`, `endpoint-<id>`,
    /// `fabric-<owner>`, `store-<owner>`.
    pub component: String,
    pub trace: Option<TraceId>,
    pub task: Option<TaskId>,
    pub kind: TraceKind,
}

struct Ring {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// Bounded task→trace FIFO index.
const INDEX_CAPACITY: usize = 65_536;

struct TraceIndex {
    map: HashMap<TaskId, TraceId>,
    order: VecDeque<TaskId>,
}

/// Default per-component ring capacity.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// The recorder: one bounded ring per component plus the task index.
pub struct FlightRecorder {
    capacity: usize,
    seq: AtomicU64,
    rings: Mutex<BTreeMap<String, Arc<Mutex<Ring>>>>,
    index: Mutex<TraceIndex>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_RING_CAPACITY)
    }
}

impl FlightRecorder {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn with_capacity(capacity: usize) -> Self {
        FlightRecorder {
            capacity,
            seq: AtomicU64::new(0),
            rings: Mutex::new(BTreeMap::new()),
            index: Mutex::new(TraceIndex { map: HashMap::new(), order: VecDeque::new() }),
        }
    }

    /// A recorder that drops everything (capacity 0) — the bench
    /// baseline for measuring recording overhead.
    pub fn disabled() -> Arc<Self> {
        Arc::new(Self::with_capacity(0))
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Mint a trace id for a freshly submitted task.
    pub fn mint(&self, task: TaskId) -> TraceId {
        let trace = TraceId::new();
        if self.capacity == 0 {
            return trace;
        }
        let mut idx = self.index.lock().unwrap();
        if idx.map.insert(task, trace).is_none() {
            idx.order.push_back(task);
        }
        while idx.map.len() > INDEX_CAPACITY {
            match idx.order.pop_front() {
                Some(old) => {
                    idx.map.remove(&old);
                }
                None => break,
            }
        }
        trace
    }

    /// The trace minted for a task, if still indexed.
    pub fn trace_id(&self, task: TaskId) -> Option<TraceId> {
        if self.capacity == 0 {
            return None;
        }
        self.index.lock().unwrap().map.get(&task).copied()
    }

    fn ring(&self, component: &str) -> Arc<Mutex<Ring>> {
        let mut g = self.rings.lock().unwrap();
        match g.get(component) {
            Some(r) => r.clone(),
            None => {
                let r = Arc::new(Mutex::new(Ring {
                    events: VecDeque::with_capacity(self.capacity.min(256)),
                    dropped: 0,
                }));
                g.insert(component.to_string(), r.clone());
                r
            }
        }
    }

    /// Append one event to a component's ring.
    pub fn record(
        &self,
        component: &str,
        trace: Option<TraceId>,
        task: Option<TaskId>,
        at: Time,
        kind: TraceKind,
    ) {
        if self.capacity == 0 {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ring = self.ring(component);
        let mut g = ring.lock().unwrap();
        if g.events.len() >= self.capacity {
            g.events.pop_front();
            g.dropped += 1;
        }
        g.events.push_back(TraceEvent {
            seq,
            at,
            component: component.to_string(),
            trace,
            task,
            kind,
        });
    }

    /// Record an event under the ambient thread-local [`TraceCtx`], if
    /// one is set (no-op otherwise — untraced background work).
    pub fn record_ctx(&self, component: &str, at: Time, kind: TraceKind) {
        if let Some((trace, task)) = TraceCtx::current() {
            self.record(component, trace, Some(task), at, kind);
        }
    }

    /// Record an event attributed to the ambient [`TraceCtx`] when one
    /// is set, and anonymously (task/trace `None`) otherwise — the
    /// anonymous form is what [`FlightRecorder::assemble`] later joins
    /// back into task timelines by ref key (spills, sheds, drains from
    /// background threads).
    pub fn record_ambient(&self, component: &str, at: Time, kind: TraceKind) {
        match TraceCtx::current() {
            Some((trace, task)) => self.record(component, trace, Some(task), at, kind),
            None => self.record(component, None, None, at, kind),
        }
    }

    /// Events dropped from rings so far (ring overflow, all components).
    pub fn dropped(&self) -> u64 {
        self.rings
            .lock()
            .unwrap()
            .values()
            .map(|r| r.lock().unwrap().dropped)
            .sum()
    }

    /// Total events currently resident across all rings.
    pub fn resident(&self) -> usize {
        self.rings
            .lock()
            .unwrap()
            .values()
            .map(|r| r.lock().unwrap().events.len())
            .sum()
    }

    /// Assemble one task's cross-component timeline: every event
    /// stamped with the task id or its trace id, plus key-only
    /// background events (spill/shed/drain) for any ref key the task's
    /// own events mention, ordered by global sequence.
    pub fn assemble(&self, task: TaskId) -> Option<TaskTrace> {
        let trace = self.trace_id(task);
        let rings: Vec<Arc<Mutex<Ring>>> =
            self.rings.lock().unwrap().values().cloned().collect();
        let mut events: Vec<TraceEvent> = Vec::new();
        for ring in &rings {
            let g = ring.lock().unwrap();
            for e in &g.events {
                let owned = e.task == Some(task)
                    || (trace.is_some() && e.trace == trace);
                if owned {
                    events.push(e.clone());
                }
            }
        }
        if events.is_empty() {
            return None;
        }
        let keys: BTreeSet<String> = events
            .iter()
            .filter_map(|e| e.kind.key().map(|k| k.to_string()))
            .collect();
        if !keys.is_empty() {
            for ring in &rings {
                let g = ring.lock().unwrap();
                for e in &g.events {
                    if e.task.is_none()
                        && e.trace.is_none()
                        && e.kind.key().is_some_and(|k| keys.contains(k))
                    {
                        events.push(e.clone());
                    }
                }
            }
        }
        events.sort_by_key(|e| e.seq);
        events.dedup_by_key(|e| e.seq);
        Some(TaskTrace { task, trace, events })
    }
}

/// Thread-local trace context: lets components that never see the task
/// (fabric resolve, store put) stamp events against it.
pub struct TraceCtx;

thread_local! {
    static CTX: std::cell::Cell<Option<(Option<TraceId>, TaskId)>> =
        const { std::cell::Cell::new(None) };
}

impl TraceCtx {
    /// Set the ambient (trace, task) for the current thread; restored
    /// to the previous value when the guard drops.
    pub fn enter(trace: Option<TraceId>, task: TaskId) -> TraceCtxGuard {
        let prev = CTX.with(|c| c.replace(Some((trace, task))));
        TraceCtxGuard { prev }
    }

    pub fn current() -> Option<(Option<TraceId>, TaskId)> {
        CTX.with(|c| c.get())
    }
}

pub struct TraceCtxGuard {
    prev: Option<(Option<TraceId>, TaskId)>,
}

impl Drop for TraceCtxGuard {
    fn drop(&mut self) {
        CTX.with(|c| c.set(self.prev));
    }
}

/// One task's assembled timeline.
#[derive(Clone, Debug)]
pub struct TaskTrace {
    pub task: TaskId,
    pub trace: Option<TraceId>,
    /// Events in global record order.
    pub events: Vec<TraceEvent>,
}

impl TaskTrace {
    /// Distinct components that contributed events.
    pub fn components(&self) -> BTreeSet<&str> {
        self.events.iter().map(|e| e.component.as_str()).collect()
    }

    /// The last terminal event, if the timeline closed.
    pub fn terminal(&self) -> Option<&TraceEvent> {
        self.events.iter().rev().find(|e| e.kind.is_terminal())
    }

    /// Pretty-print the timeline, times relative to the first event.
    pub fn render(&self) -> String {
        let t0 = self.events.first().map(|e| e.at).unwrap_or(0.0);
        let mut out = match self.trace {
            Some(t) => format!("trace {t} task {}\n", self.task),
            None => format!("trace (unminted) task {}\n", self.task),
        };
        for e in &self.events {
            out.push_str(&format!(
                "  +{:>9.3}ms  {:<22} {:<20} {}\n",
                1e3 * (e.at - t0),
                e.component,
                e.kind.name(),
                e.kind.detail()
            ));
        }
        match self.terminal() {
            Some(t) => out.push_str(&format!("  terminal: {} ({})\n", t.kind.name(), t.kind.detail())),
            None => out.push_str("  terminal: (still in flight)\n"),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_assemble_orders_by_seq() {
        let rec = FlightRecorder::new();
        let task = TaskId::new();
        let trc = rec.mint(task);
        let ep = EndpointId::new();
        rec.record("shard-0", Some(trc), Some(task), 0.0, TraceKind::Submitted { endpoint: ep });
        rec.record("shard-0", Some(trc), Some(task), 0.001, TraceKind::ShardEnqueued { shard: 0 });
        rec.record(
            "endpoint-x",
            Some(trc),
            Some(task),
            0.002,
            TraceKind::WorkerStarted { endpoint: ep },
        );
        rec.record(
            "shard-0",
            Some(trc),
            Some(task),
            0.003,
            TraceKind::ResultStored { shard: 0, state: "Success" },
        );
        let t = rec.assemble(task).expect("trace");
        assert_eq!(t.trace, Some(trc));
        assert_eq!(t.events.len(), 4);
        assert!(t.events.windows(2).all(|w| w[0].seq < w[1].seq));
        assert_eq!(t.components().len(), 2);
        assert_eq!(t.terminal().unwrap().kind.name(), "ResultStored");
        assert!(t.render().contains("ResultStored"));
    }

    #[test]
    fn key_only_events_join_by_ref_key() {
        let rec = FlightRecorder::new();
        let task = TaskId::new();
        let trc = rec.mint(task);
        // Background spill of the frame this task later resolves.
        rec.record("store-a", None, None, 0.5, TraceKind::Spilled { key: "k1".into() });
        rec.record("store-a", None, None, 0.6, TraceKind::Spilled { key: "other".into() });
        rec.record(
            "fabric-b",
            Some(trc),
            Some(task),
            1.0,
            TraceKind::RefResolved { key: "k1".into(), source: ResolveSource::Local },
        );
        let t = rec.assemble(task).unwrap();
        assert_eq!(t.events.len(), 2, "only k1's spill joins");
        assert_eq!(t.events[0].kind, TraceKind::Spilled { key: "k1".into() });
    }

    #[test]
    fn rings_are_bounded() {
        let rec = FlightRecorder::with_capacity(8);
        let task = TaskId::new();
        for i in 0..100 {
            rec.record("c", None, Some(task), i as f64, TraceKind::Redispatched { attempt: i });
        }
        assert_eq!(rec.resident(), 8);
        assert_eq!(rec.dropped(), 92);
        let t = rec.assemble(task).unwrap();
        assert_eq!(t.events.len(), 8);
        assert_eq!(t.events.last().unwrap().kind, TraceKind::Redispatched { attempt: 99 });
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = FlightRecorder::disabled();
        let task = TaskId::new();
        let _ = rec.mint(task);
        rec.record("c", None, Some(task), 0.0, TraceKind::Redispatched { attempt: 0 });
        assert!(!rec.enabled());
        assert_eq!(rec.resident(), 0);
        assert!(rec.assemble(task).is_none());
    }

    #[test]
    fn index_is_bounded_fifo() {
        let rec = FlightRecorder::with_capacity(4);
        let first = TaskId::new();
        rec.mint(first);
        for _ in 0..INDEX_CAPACITY {
            rec.mint(TaskId::new());
        }
        assert!(rec.trace_id(first).is_none(), "oldest entry evicted");
        assert_eq!(rec.index.lock().unwrap().map.len(), INDEX_CAPACITY);
    }

    #[test]
    fn trace_ctx_nests_and_restores() {
        let task = TaskId::new();
        assert!(TraceCtx::current().is_none());
        {
            let _g = TraceCtx::enter(None, task);
            assert_eq!(TraceCtx::current(), Some((None, task)));
            let inner = TaskId::new();
            {
                let _g2 = TraceCtx::enter(Some(TraceId::new()), inner);
                assert_eq!(TraceCtx::current().unwrap().1, inner);
            }
            assert_eq!(TraceCtx::current(), Some((None, task)));
        }
        assert!(TraceCtx::current().is_none());
    }

    #[test]
    fn trace_id_roundtrips_as_string() {
        let t = TraceId::new();
        let s = t.to_string();
        assert_eq!(s.parse::<TraceId>().unwrap(), t);
    }
}
