//! Dimensioned instrument registry: counters, gauges, and log-linear
//! histograms behind one snapshot/exposition facade.
//!
//! Hot paths keep writing their existing relaxed-atomic stats structs
//! ([`crate::metrics::Counters`], `TierStats`, `FabricStats`, …); the
//! registry owns *instruments* (created once, written via cheap atomic
//! handles) plus *sources* — collector closures over those legacy
//! structs that are polled only when [`MetricsRegistry::snapshot`] runs.
//! Nothing on the task hot path ever takes the registry lock.
//!
//! Histograms are fixed-bucket log-linear: the f64 exponent selects an
//! octave and the top 4 mantissa bits a sub-bucket (16 per octave,
//! ≤ ~4.4% relative error), covering 2^-40..2^40 in 1297 atomic
//! buckets (~10 KB, bounded, mergeable). Quantiles interpolate at the
//! continuous rank `q·(n-1)` — the same convention as
//! [`crate::metrics::summarize`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Summary;

/// Sub-buckets per octave (top 4 mantissa bits).
const SUB_BITS: u32 = 4;
const SUBS: usize = 1 << SUB_BITS;
/// Histogram value range: 2^MIN_EXP ..= 2^MAX_EXP (≈1e-12 .. 1e12).
const MIN_EXP: i64 = -40;
const MAX_EXP: i64 = 40;
const N_OCTAVES: usize = (MAX_EXP - MIN_EXP) as usize + 1;
/// Bucket 0 is the underflow bucket (zero, negative, < 2^MIN_EXP).
const N_BUCKETS: usize = 1 + N_OCTAVES * SUBS;

fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v < f64::powi(2.0, MIN_EXP as i32) {
        return 0;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i64 - 1023;
    let exp = exp.clamp(MIN_EXP, MAX_EXP);
    let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    1 + ((exp - MIN_EXP) as usize * SUBS + sub).min(N_OCTAVES * SUBS - 1)
}

/// `[lo, hi)` value bounds of a bucket index.
fn bucket_bounds(idx: usize) -> (f64, f64) {
    if idx == 0 {
        return (0.0, f64::powi(2.0, MIN_EXP as i32));
    }
    let i = idx - 1;
    let exp = MIN_EXP + (i / SUBS) as i64;
    let sub = (i % SUBS) as f64;
    let base = f64::powi(2.0, exp as i32);
    let lo = base * (1.0 + sub / SUBS as f64);
    let hi = base * (1.0 + (sub + 1.0) / SUBS as f64);
    (lo, hi)
}

/// A mergeable fixed-memory log-linear histogram. All writes are
/// relaxed atomics; `record` never allocates or locks.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bits, CAS-accumulated.
    sum: AtomicU64,
    /// f64 bits of the observed min/max (exact, not bucket-quantized).
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0f64.to_bits()),
            min: AtomicU64::new(f64::INFINITY.to_bits()),
            max: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        update_extreme(&self.min, v, |new, old| new < old);
        update_extreme(&self.max, v, |new, old| new > old);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fold another histogram's buckets into this one.
    pub fn merge(&self, other: &Histogram) {
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = o.load(Ordering::Relaxed);
            if n > 0 {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        let osum = f64::from_bits(other.sum.load(Ordering::Relaxed));
        let mut cur = self.sum.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + osum).to_bits();
            match self.sum.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        update_extreme(&self.min, f64::from_bits(other.min.load(Ordering::Relaxed)), |n, o| n < o);
        update_extreme(&self.max, f64::from_bits(other.max.load(Ordering::Relaxed)), |n, o| n > o);
    }

    /// Interpolated quantile at the continuous rank `q·(count-1)`,
    /// clamped to the exactly-observed [min, max].
    pub fn quantile(&self, q: f64) -> f64 {
        let count = self.count();
        if count == 0 {
            return 0.0;
        }
        let (min, max) = (
            f64::from_bits(self.min.load(Ordering::Relaxed)),
            f64::from_bits(self.max.load(Ordering::Relaxed)),
        );
        let rank = q.clamp(0.0, 1.0) * (count - 1) as f64;
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 > rank {
                let (lo, hi) = bucket_bounds(idx);
                let frac = (rank - cum as f64) / c as f64;
                return (lo + frac * (hi - lo)).clamp(min, max);
            }
            cum += c;
        }
        max
    }

    pub fn summary(&self) -> Summary {
        let count = self.count();
        if count == 0 {
            return Summary::default();
        }
        Summary {
            count: count as usize,
            mean: f64::from_bits(self.sum.load(Ordering::Relaxed)) / count as f64,
            min: f64::from_bits(self.min.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max.load(Ordering::Relaxed)),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
        }
    }
}

fn update_extreme(slot: &AtomicU64, v: f64, better: impl Fn(f64, f64) -> bool) {
    let mut cur = slot.load(Ordering::Relaxed);
    while better(v, f64::from_bits(cur)) {
        match slot.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// Write handle for a registry counter. Clones share the cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn incr(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Write handle for a registry gauge (a settable signed level).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instrument identity: name plus sorted `(dimension, value)` pairs.
type Key = (String, Vec<(String, String)>);

fn key_of(name: &str, dims: &[(&str, &str)]) -> Key {
    let mut d: Vec<(String, String)> =
        dims.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    d.sort();
    (name.to_string(), d)
}

/// One exported value in a snapshot.
#[derive(Clone, Debug)]
pub enum SampleValue {
    Counter(u64),
    Gauge(i64),
    Histogram(Summary),
}

/// One named, dimensioned sample.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub dims: Vec<(String, String)>,
    pub value: SampleValue,
}

/// Accumulates samples during a snapshot; sources push their stats
/// struct reads through this.
#[derive(Default)]
pub struct SnapshotBuilder {
    samples: Vec<Sample>,
}

impl SnapshotBuilder {
    pub fn counter(&mut self, name: &str, dims: &[(&str, &str)], v: u64) {
        let (name, dims) = key_of(name, dims);
        self.samples.push(Sample { name, dims, value: SampleValue::Counter(v) });
    }

    pub fn gauge(&mut self, name: &str, dims: &[(&str, &str)], v: i64) {
        let (name, dims) = key_of(name, dims);
        self.samples.push(Sample { name, dims, value: SampleValue::Gauge(v) });
    }

    pub fn histogram(&mut self, name: &str, dims: &[(&str, &str)], s: Summary) {
        let (name, dims) = key_of(name, dims);
        self.samples.push(Sample { name, dims, value: SampleValue::Histogram(s) });
    }
}

type Source = Box<dyn Fn(&mut SnapshotBuilder) + Send + Sync>;

/// Registry of named, dimensioned instruments plus snapshot-time
/// collector sources. `snapshot()` is the only operation that walks
/// everything; instrument writes go through the returned handles.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<Key, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<Key, Arc<AtomicI64>>>,
    histograms: Mutex<BTreeMap<Key, Arc<Histogram>>>,
    sources: Mutex<Vec<Source>>,
}

impl MetricsRegistry {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Get-or-create a counter. Cache the handle; creation locks.
    pub fn counter(&self, name: &str, dims: &[(&str, &str)]) -> Counter {
        let mut g = self.counters.lock().unwrap();
        Counter(g.entry(key_of(name, dims)).or_default().clone())
    }

    pub fn gauge(&self, name: &str, dims: &[(&str, &str)]) -> Gauge {
        let mut g = self.gauges.lock().unwrap();
        Gauge(g.entry(key_of(name, dims)).or_default().clone())
    }

    pub fn histogram(&self, name: &str, dims: &[(&str, &str)]) -> Arc<Histogram> {
        let mut g = self.histograms.lock().unwrap();
        g.entry(key_of(name, dims)).or_default().clone()
    }

    /// Register a collector polled at every `snapshot()`. Sources adapt
    /// the pre-existing hot-path stats structs (Counters, TierStats,
    /// FabricStats, LocalityStats, AgentStats) into the one facade.
    pub fn register_source(&self, f: impl Fn(&mut SnapshotBuilder) + Send + Sync + 'static) {
        self.sources.lock().unwrap().push(Box::new(f));
    }

    /// Read every owned instrument and poll every source.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut b = SnapshotBuilder::default();
        for ((name, dims), c) in self.counters.lock().unwrap().iter() {
            b.samples.push(Sample {
                name: name.clone(),
                dims: dims.clone(),
                value: SampleValue::Counter(c.load(Ordering::Relaxed)),
            });
        }
        for ((name, dims), g) in self.gauges.lock().unwrap().iter() {
            b.samples.push(Sample {
                name: name.clone(),
                dims: dims.clone(),
                value: SampleValue::Gauge(g.load(Ordering::Relaxed)),
            });
        }
        for ((name, dims), h) in self.histograms.lock().unwrap().iter() {
            b.samples.push(Sample {
                name: name.clone(),
                dims: dims.clone(),
                value: SampleValue::Histogram(h.summary()),
            });
        }
        for src in self.sources.lock().unwrap().iter() {
            src(&mut b);
        }
        let mut samples = b.samples;
        samples.sort_by(|a, b| (&a.name, &a.dims).cmp(&(&b.name, &b.dims)));
        MetricsSnapshot { samples }
    }
}

/// A point-in-time serializable reading of the whole registry.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub samples: Vec<Sample>,
}

impl MetricsSnapshot {
    pub fn get(&self, name: &str, dims: &[(&str, &str)]) -> Option<&SampleValue> {
        let (n, d) = key_of(name, dims);
        self.samples.iter().find(|s| s.name == n && s.dims == d).map(|s| &s.value)
    }

    /// Counter value summed across all dimension combinations.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .filter_map(|s| match s.value {
                SampleValue::Counter(v) => Some(v),
                _ => None,
            })
            .sum()
    }

    /// Gauge value summed across all dimension combinations.
    pub fn gauge_total(&self, name: &str) -> i64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .filter_map(|s| match s.value {
                SampleValue::Gauge(v) => Some(v),
                _ => None,
            })
            .sum()
    }

    /// JSON exposition: `{"metrics": [{"name": .., "dims": {..}, ..}]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"metrics\": [\n");
        for (i, s) in self.samples.iter().enumerate() {
            out.push_str("    {\"name\": ");
            out.push_str(&json_str(&s.name));
            out.push_str(", \"dims\": {");
            for (j, (k, v)) in s.dims.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&json_str(k));
                out.push_str(": ");
                out.push_str(&json_str(v));
            }
            out.push_str("}, ");
            match &s.value {
                SampleValue::Counter(v) => {
                    out.push_str(&format!("\"type\": \"counter\", \"value\": {v}"))
                }
                SampleValue::Gauge(v) => {
                    out.push_str(&format!("\"type\": \"gauge\", \"value\": {v}"))
                }
                SampleValue::Histogram(h) => out.push_str(&format!(
                    "\"type\": \"histogram\", \"count\": {}, \"mean\": {:.9}, \"min\": {:.9}, \
                     \"max\": {:.9}, \"p50\": {:.9}, \"p90\": {:.9}, \"p99\": {:.9}, \
                     \"p999\": {:.9}",
                    h.count, h.mean, h.min, h.max, h.p50, h.p90, h.p99, h.p999
                )),
            }
            out.push('}');
            if i + 1 < self.samples.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Text exposition, one `name{dim="v",..} value` line per sample;
    /// histograms expand into `_count`/`_mean`/`_p50`… lines.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let dims_str = |dims: &[(String, String)]| -> String {
            if dims.is_empty() {
                return String::new();
            }
            let body: Vec<String> =
                dims.iter().map(|(k, v)| format!("{k}={}", json_str(v))).collect();
            format!("{{{}}}", body.join(","))
        };
        for s in &self.samples {
            let d = dims_str(&s.dims);
            match &s.value {
                SampleValue::Counter(v) => out.push_str(&format!("{}{d} {v}\n", s.name)),
                SampleValue::Gauge(v) => out.push_str(&format!("{}{d} {v}\n", s.name)),
                SampleValue::Histogram(h) => {
                    out.push_str(&format!("{}_count{d} {}\n", s.name, h.count));
                    for (suffix, v) in [
                        ("mean", h.mean),
                        ("min", h.min),
                        ("max", h.max),
                        ("p50", h.p50),
                        ("p90", h.p90),
                        ("p99", h.p99),
                        ("p999", h.p999),
                    ] {
                        out.push_str(&format!("{}_{suffix}{d} {v:.9}\n", s.name));
                    }
                }
            }
        }
        out
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_bounded() {
        let mut last = 0;
        for v in [0.0, 1e-15, 1e-9, 1e-6, 0.5, 1.0, 1.5, 2.0, 1e3, 1e9, 1e15] {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of({v}) went backwards");
            assert!(b < N_BUCKETS);
            last = b;
        }
        // Bounds invert the index mapping.
        for v in [1e-6, 0.37, 1.0, 42.0, 9e8] {
            let (lo, hi) = bucket_bounds(bucket_of(v));
            assert!(lo <= v && v < hi, "{v} outside [{lo},{hi})");
        }
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // uniform on (0, 1]
        }
        let s = h.summary();
        assert_eq!(s.count, 1000);
        assert!((s.mean - 0.5005).abs() < 1e-9);
        assert!((s.p50 - 0.5).abs() < 0.05, "p50 {}", s.p50);
        assert!((s.p90 - 0.9).abs() < 0.09, "p90 {}", s.p90);
        assert!((s.p99 - 0.99).abs() < 0.1, "p99 {}", s.p99);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.p999);
        assert!(s.p999 <= s.max && s.min <= s.p50);
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let h = Histogram::new();
        h.record(0.125);
        let s = h.summary();
        // One sample: every quantile clamps to the observed value.
        assert_eq!(s.p50, 0.125);
        assert_eq!(s.p99, 0.125);
        assert_eq!(s.min, 0.125);
        assert_eq!(s.max, 0.125);
    }

    #[test]
    fn histogram_merge_adds() {
        let (a, b) = (Histogram::new(), Histogram::new());
        for i in 0..100 {
            a.record(1.0 + i as f64);
            b.record(1000.0 + i as f64);
        }
        a.merge(&b);
        let s = a.summary();
        assert_eq!(s.count, 200);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 1099.0);
        assert!(s.p90 > 900.0, "p90 {}", s.p90);
    }

    #[test]
    fn registry_snapshot_and_exposition() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("funcx_tasks_submitted_total", &[]);
        c.add(7);
        let g = reg.gauge("funcx_tasks_in_flight", &[("shard", "0")]);
        g.set(3);
        reg.histogram("funcx_stage_seconds", &[("stage", "t_w")]).record(0.25);
        reg.register_source(|b| b.counter("funcx_tier_puts_total", &[("shard", "1")], 11));

        let snap = reg.snapshot();
        assert!(matches!(snap.get("funcx_tasks_submitted_total", &[]), Some(SampleValue::Counter(7))));
        assert_eq!(snap.counter_total("funcx_tier_puts_total"), 11);
        assert_eq!(snap.gauge_total("funcx_tasks_in_flight"), 3);
        let json = snap.to_json();
        assert!(json.contains("\"funcx_stage_seconds\""));
        assert!(json.contains("\"type\": \"histogram\""));
        let text = snap.to_text();
        assert!(text.contains("funcx_tasks_submitted_total 7"));
        assert!(text.contains("funcx_tasks_in_flight{shard=\"0\"} 3"));
        assert!(text.contains("funcx_stage_seconds_count{stage=\"t_w\"} 1"));
    }

    #[test]
    fn same_key_shares_the_cell() {
        let reg = MetricsRegistry::new();
        reg.counter("x", &[("a", "1"), ("b", "2")]).incr();
        // Dimension order must not matter.
        reg.counter("x", &[("b", "2"), ("a", "1")]).incr();
        assert_eq!(reg.counter("x", &[("a", "1"), ("b", "2")]).get(), 2);
    }

    #[test]
    fn histogram_memory_is_fixed() {
        // The bucket vector never grows with sample count or range.
        let h = Histogram::new();
        let before = h.buckets.len();
        for i in 0..100_000 {
            h.record((i as f64).exp().min(1e300));
        }
        assert_eq!(h.buckets.len(), before);
        assert_eq!(h.count(), 100_000);
    }
}
