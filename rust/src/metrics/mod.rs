//! Instrumentation: the Fig. 3 latency decomposition and system counters.
//!
//! Fig. 3 splits a task's round trip into:
//! * `t_s` — web-service latency (auth + Redis store + queue append),
//! * `t_f` — forwarder latency (queue read, dispatch, result write),
//! * `t_e` — endpoint latency (agent/manager queuing + dispatch),
//! * `t_w` — function execution on the worker.
//!
//! Stages are recorded per task; [`LatencyBreakdown`] aggregates them.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::common::ids::TaskId;
use crate::common::time::Time;

/// One task's per-stage timings, seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimes {
    pub t_s: f64,
    pub t_f: f64,
    pub t_e: f64,
    pub t_w: f64,
}

impl StageTimes {
    pub fn total(&self) -> f64 {
        self.t_s + self.t_f + self.t_e + self.t_w
    }
}

/// Aggregated stats over many tasks.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p99: f64,
}

/// Compute summary stats for a sample.
pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[((sorted.len() - 1) as f64 * p).round() as usize];
    Summary {
        count: sorted.len(),
        mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: pct(0.50),
        p99: pct(0.99),
    }
}

/// Lock stripes for the stage-timing map: six stamps land per task from
/// submitter, forwarder, and agent threads across every service shard,
/// so one global mutex here would quietly re-serialize a sharded
/// service plane.
const N_STRIPES: usize = 16;

/// Collects per-task stage timings (Fig. 3 harness). Internally striped
/// by task-id hash; the public API is unchanged.
#[derive(Clone)]
pub struct LatencyBreakdown {
    stripes: Arc<Vec<Mutex<HashMap<TaskId, StageRecord>>>>,
}

impl Default for LatencyBreakdown {
    fn default() -> Self {
        LatencyBreakdown {
            stripes: Arc::new((0..N_STRIPES).map(|_| Mutex::default()).collect()),
        }
    }
}

#[derive(Default, Clone, Copy)]
struct StageRecord {
    submit: Option<Time>,
    queued: Option<Time>,
    forwarded: Option<Time>,
    started: Option<Time>,
    finished: Option<Time>,
    result_stored: Option<Time>,
}

impl LatencyBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    fn stripe(&self, t: TaskId) -> &Mutex<HashMap<TaskId, StageRecord>> {
        let x = (t.0 .0 as u64) ^ ((t.0 .0 >> 64) as u64);
        &self.stripes[(x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % N_STRIPES]
    }

    pub fn on_submit(&self, t: TaskId, now: Time) {
        self.stripe(t).lock().unwrap().entry(t).or_default().submit = Some(now);
    }

    /// Task persisted + appended to the endpoint queue (end of t_s).
    pub fn on_queued(&self, t: TaskId, now: Time) {
        self.stripe(t).lock().unwrap().entry(t).or_default().queued = Some(now);
    }

    /// Forwarder handed the task to the agent (end of forwarder's send half).
    pub fn on_forwarded(&self, t: TaskId, now: Time) {
        self.stripe(t).lock().unwrap().entry(t).or_default().forwarded = Some(now);
    }

    /// Worker began executing (end of t_e's dispatch half).
    pub fn on_started(&self, t: TaskId, now: Time) {
        self.stripe(t).lock().unwrap().entry(t).or_default().started = Some(now);
    }

    /// Worker finished (t_w = started..finished).
    pub fn on_finished(&self, t: TaskId, now: Time) {
        self.stripe(t).lock().unwrap().entry(t).or_default().finished = Some(now);
    }

    /// Result written back to the store (closes t_f's return half).
    pub fn on_result_stored(&self, t: TaskId, now: Time) {
        self.stripe(t).lock().unwrap().entry(t).or_default().result_stored = Some(now);
    }

    /// Stage decomposition for one task, if all stamps are present.
    pub fn breakdown(&self, t: TaskId) -> Option<StageTimes> {
        let g = self.stripe(t).lock().unwrap();
        let r = g.get(&t)?;
        let (submit, queued, forwarded, started, finished, stored) = (
            r.submit?,
            r.queued?,
            r.forwarded?,
            r.started?,
            r.finished?,
            r.result_stored?,
        );
        Some(StageTimes {
            t_s: queued - submit,
            t_f: (forwarded - queued) + (stored - finished).max(0.0),
            t_e: started - forwarded,
            t_w: finished - started,
        })
    }

    pub fn all_breakdowns(&self) -> Vec<StageTimes> {
        let keys: Vec<TaskId> = self
            .stripes
            .iter()
            .flat_map(|s| s.lock().unwrap().keys().copied().collect::<Vec<_>>())
            .collect();
        keys.into_iter().filter_map(|k| self.breakdown(k)).collect()
    }
}

/// Cheap global counters (tasks dispatched, cold starts, heartbeats, …).
#[derive(Default)]
pub struct Counters {
    pub tasks_submitted: AtomicU64,
    pub tasks_completed: AtomicU64,
    pub tasks_failed: AtomicU64,
    pub tasks_redispatched: AtomicU64,
    /// Tasks whose oversized input was offloaded to the data fabric and
    /// dispatched as a `DataRef` (§5 pass-by-reference).
    pub tasks_ref_dispatched: AtomicU64,
    /// Input bytes kept *out* of the service queues by ref dispatch.
    pub bytes_offloaded: AtomicU64,
    /// Tasks submitted with a prior result's `DataRef` as their input
    /// (ref forwarding — the service never touched the bytes).
    pub tasks_ref_forwarded: AtomicU64,
    /// Completed results whose output came back as a `DataRef`
    /// (`"rref"`) instead of inline bytes (§5 result offload).
    pub results_ref_offloaded: AtomicU64,
    /// Offloaded result frames (`task-result:*`) reclaimed eagerly —
    /// on retrieval (`get_result`) or when the chain task consuming the
    /// ref completed — instead of lingering until TTL.
    pub result_frames_reclaimed: AtomicU64,
    pub cold_starts: AtomicU64,
    pub warm_hits: AtomicU64,
    pub heartbeats: AtomicU64,
    pub bytes_through_service: AtomicU64,
    /// Result-payload bytes stored inline in the service result queue
    /// (by-ref results contribute only their empty placeholder, so this
    /// stays near zero for offloaded chains — pinned in
    /// `tests/data_fabric.rs`).
    pub result_bytes_through_service: AtomicU64,
    /// Replica copies of hot result frames pushed to peer stores (§5
    /// survivability: a ref outlives its owner endpoint).
    pub replicas_created: AtomicU64,
    /// Ref resolutions that completed via a replica (or the replica
    /// scan) after the owner's copy was unreachable — the failover half
    /// of replication.
    pub failover_resolutions: AtomicU64,
    /// Puts refused by a store under spill backpressure (memory tier at
    /// its shed limit over a persistently failing spool).
    pub shed_puts: AtomicU64,
    /// Frames re-homed to replica stores while decommissioning their
    /// owner endpoint.
    pub frames_drained: AtomicU64,
}

impl Counters {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn incr(counter: &AtomicU64) -> u64 {
        counter.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn add(counter: &AtomicU64, n: u64) -> u64 {
        counter.fetch_add(n, Ordering::Relaxed) + n
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(summarize(&[]).count, 0);
    }

    #[test]
    fn breakdown_stages() {
        let lb = LatencyBreakdown::new();
        let t = TaskId::new();
        lb.on_submit(t, 0.0);
        lb.on_queued(t, 0.010); // t_s = 10 ms
        lb.on_forwarded(t, 0.015); // forward leg 5 ms
        lb.on_started(t, 0.035); // t_e = 20 ms
        lb.on_finished(t, 0.055); // t_w = 20 ms
        lb.on_result_stored(t, 0.060); // return leg 5 ms
        let b = lb.breakdown(t).unwrap();
        assert!((b.t_s - 0.010).abs() < 1e-9);
        assert!((b.t_f - 0.010).abs() < 1e-9);
        assert!((b.t_e - 0.020).abs() < 1e-9);
        assert!((b.t_w - 0.020).abs() < 1e-9);
        assert!((b.total() - 0.060).abs() < 1e-9);
    }

    #[test]
    fn incomplete_breakdown_is_none() {
        let lb = LatencyBreakdown::new();
        let t = TaskId::new();
        lb.on_submit(t, 0.0);
        assert!(lb.breakdown(t).is_none());
        assert!(lb.breakdown(TaskId::new()).is_none());
    }

    #[test]
    fn counters_work() {
        let c = Counters::new();
        Counters::incr(&c.tasks_submitted);
        Counters::incr(&c.tasks_submitted);
        Counters::add(&c.bytes_through_service, 100);
        assert_eq!(Counters::get(&c.tasks_submitted), 2);
        assert_eq!(Counters::get(&c.bytes_through_service), 100);
    }
}
