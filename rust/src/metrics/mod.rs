//! Instrumentation: the Fig. 3 latency decomposition, system counters,
//! the dimensioned instrument registry, and the task flight recorder.
//!
//! Fig. 3 splits a task's round trip into:
//! * `t_s` — web-service latency (auth + Redis store + queue append),
//! * `t_f` — forwarder latency (queue read, dispatch, result write),
//! * `t_e` — endpoint latency (agent/manager queuing + dispatch),
//! * `t_w` — function execution on the worker.
//!
//! Stages are recorded per task; [`LatencyBreakdown`] folds completed
//! tasks into per-stage [`registry::Histogram`]s and evicts the
//! record, so a long-running fleet holds O(in-flight) records instead
//! of O(all-time tasks). See `docs/observability.md`.

pub mod registry;
pub mod trace;

pub use registry::{
    Counter, Gauge, Histogram, MetricsRegistry, MetricsSnapshot, Sample, SampleValue,
    SnapshotBuilder,
};
pub use trace::{
    FlightRecorder, ResolveSource, TaskTrace, TraceCtx, TraceEvent, TraceId, TraceKind,
    DEFAULT_RING_CAPACITY,
};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::common::ids::TaskId;
use crate::common::time::Time;

/// One task's per-stage timings, seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimes {
    pub t_s: f64,
    pub t_f: f64,
    pub t_e: f64,
    pub t_w: f64,
}

impl StageTimes {
    pub fn total(&self) -> f64 {
        self.t_s + self.t_f + self.t_e + self.t_w
    }
}

/// Aggregated stats over many tasks.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
}

/// Compute summary stats for a sample. Percentiles interpolate at the
/// continuous rank `p·(n-1)` — the same convention as
/// [`registry::Histogram::quantile`] — so small samples are not
/// misreported (nearest-rank rounding made p99 of 4 samples == max).
pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| {
        let rank = (sorted.len() - 1) as f64 * p;
        let lo = rank.floor() as usize;
        let frac = rank - lo as f64;
        if frac == 0.0 || lo + 1 >= sorted.len() {
            sorted[lo]
        } else {
            sorted[lo] + frac * (sorted[lo + 1] - sorted[lo])
        }
    };
    Summary {
        count: sorted.len(),
        mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: pct(0.50),
        p90: pct(0.90),
        p99: pct(0.99),
        p999: pct(0.999),
    }
}

/// Lock stripes for the stage-timing map: six stamps land per task from
/// submitter, forwarder, and agent threads across every service shard,
/// so one global mutex here would quietly re-serialize a sharded
/// service plane.
const N_STRIPES: usize = 16;

/// Cap on records per stripe. A record is ~100 bytes, so the whole
/// tracker tops out near `16 × 4096` records (~6 MB) no matter how
/// many tasks ever ran: completed tasks fold into the stage histograms
/// and evict; stale incomplete records (a crashed component never
/// stamped the terminal) are FIFO-evicted past the cap.
pub const MAX_TRACKED_PER_STRIPE: usize = 4096;

/// Per-stage aggregate histograms (bounded, mergeable).
struct StageHists {
    t_s: Histogram,
    t_f: Histogram,
    t_e: Histogram,
    t_w: Histogram,
    total: Histogram,
    completed: AtomicU64,
}

/// The per-stage summaries a fleet keeps after folding.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageSummaries {
    pub t_s: Summary,
    pub t_f: Summary,
    pub t_e: Summary,
    pub t_w: Summary,
    pub total: Summary,
    /// Tasks folded (had all six stamps at terminal time).
    pub completed: u64,
}

#[derive(Default)]
struct Stripe {
    map: HashMap<TaskId, StageRecord>,
    /// FIFO insertion order; may hold ids already folded out of `map`.
    order: VecDeque<TaskId>,
}

/// Collects per-task stage timings (Fig. 3 harness). Internally striped
/// by task-id hash; completed tasks fold into per-stage histograms and
/// evict, bounding the tracker at O(in-flight).
#[derive(Clone)]
pub struct LatencyBreakdown {
    stripes: Arc<Vec<Mutex<Stripe>>>,
    hists: Arc<StageHists>,
}

impl Default for LatencyBreakdown {
    fn default() -> Self {
        LatencyBreakdown {
            stripes: Arc::new((0..N_STRIPES).map(|_| Mutex::default()).collect()),
            hists: Arc::new(StageHists {
                t_s: Histogram::new(),
                t_f: Histogram::new(),
                t_e: Histogram::new(),
                t_w: Histogram::new(),
                total: Histogram::new(),
                completed: AtomicU64::new(0),
            }),
        }
    }
}

#[derive(Default, Clone, Copy)]
struct StageRecord {
    submit: Option<Time>,
    queued: Option<Time>,
    forwarded: Option<Time>,
    started: Option<Time>,
    finished: Option<Time>,
    result_stored: Option<Time>,
}

impl StageRecord {
    fn breakdown(&self) -> Option<StageTimes> {
        let (submit, queued, forwarded, started, finished, stored) = (
            self.submit?,
            self.queued?,
            self.forwarded?,
            self.started?,
            self.finished?,
            self.result_stored?,
        );
        Some(StageTimes {
            t_s: queued - submit,
            t_f: (forwarded - queued) + (stored - finished).max(0.0),
            t_e: started - forwarded,
            t_w: finished - started,
        })
    }
}

impl LatencyBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    fn stripe(&self, t: TaskId) -> &Mutex<Stripe> {
        let x = (t.0 .0 as u64) ^ ((t.0 .0 >> 64) as u64);
        &self.stripes[(x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % N_STRIPES]
    }

    fn stamp(&self, t: TaskId, f: impl FnOnce(&mut StageRecord)) {
        let mut g = self.stripe(t).lock().unwrap();
        if !g.map.contains_key(&t) {
            g.order.push_back(t);
        }
        f(g.map.entry(t).or_default());
        // Evict oldest live records past the cap; folded ids in
        // `order` pop through without effect (amortized O(1)).
        while g.map.len() > MAX_TRACKED_PER_STRIPE {
            match g.order.pop_front() {
                Some(old) => {
                    g.map.remove(&old);
                }
                None => break,
            }
        }
        // Folded/evicted tasks leave stale ids behind in `order`;
        // compact once it doubles so it too stays O(in-flight).
        if g.order.len() > 2 * MAX_TRACKED_PER_STRIPE {
            let Stripe { map, order } = &mut *g;
            order.retain(|id| map.contains_key(id));
        }
    }

    pub fn on_submit(&self, t: TaskId, now: Time) {
        self.stamp(t, |r| r.submit = Some(now));
    }

    /// Task persisted + appended to the endpoint queue (end of t_s).
    pub fn on_queued(&self, t: TaskId, now: Time) {
        self.stamp(t, |r| r.queued = Some(now));
    }

    /// Forwarder handed the task to the agent (end of forwarder's send half).
    pub fn on_forwarded(&self, t: TaskId, now: Time) {
        self.stamp(t, |r| r.forwarded = Some(now));
    }

    /// Worker began executing (end of t_e's dispatch half).
    pub fn on_started(&self, t: TaskId, now: Time) {
        self.stamp(t, |r| r.started = Some(now));
    }

    /// Worker finished (t_w = started..finished).
    pub fn on_finished(&self, t: TaskId, now: Time) {
        self.stamp(t, |r| r.finished = Some(now));
    }

    /// Result written back to the store (closes t_f's return half).
    /// Terminal: folds the completed decomposition into the per-stage
    /// histograms, evicts the record, and returns the decomposition.
    pub fn on_result_stored(&self, t: TaskId, now: Time) -> Option<StageTimes> {
        let record = {
            let mut g = self.stripe(t).lock().unwrap();
            let mut r = g.map.remove(&t).unwrap_or_default();
            r.result_stored = Some(now);
            r
        };
        let b = record.breakdown()?;
        self.hists.t_s.record(b.t_s);
        self.hists.t_f.record(b.t_f);
        self.hists.t_e.record(b.t_e);
        self.hists.t_w.record(b.t_w);
        self.hists.total.record(b.total());
        self.hists.completed.fetch_add(1, Ordering::Relaxed);
        Some(b)
    }

    /// Stage decomposition for one still-tracked task, if all stamps
    /// are present (terminal tasks have folded and evicted).
    pub fn breakdown(&self, t: TaskId) -> Option<StageTimes> {
        self.stripe(t).lock().unwrap().map.get(&t)?.breakdown()
    }

    /// Records still tracked — exactly the submitted-but-unterminated
    /// tasks (every terminal `store_result` folds and evicts), which
    /// makes this the fleet's in-flight gauge.
    pub fn in_flight(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    /// Per-stage summaries over every task folded so far.
    pub fn stage_summaries(&self) -> StageSummaries {
        StageSummaries {
            t_s: self.hists.t_s.summary(),
            t_f: self.hists.t_f.summary(),
            t_e: self.hists.t_e.summary(),
            t_w: self.hists.t_w.summary(),
            total: self.hists.total.summary(),
            completed: self.hists.completed.load(Ordering::Relaxed),
        }
    }

    /// Export the stage histograms + in-flight gauge into a snapshot.
    pub fn fill(&self, b: &mut SnapshotBuilder) {
        b.histogram("funcx_stage_seconds", &[("stage", "t_s")], self.hists.t_s.summary());
        b.histogram("funcx_stage_seconds", &[("stage", "t_f")], self.hists.t_f.summary());
        b.histogram("funcx_stage_seconds", &[("stage", "t_e")], self.hists.t_e.summary());
        b.histogram("funcx_stage_seconds", &[("stage", "t_w")], self.hists.t_w.summary());
        b.histogram("funcx_stage_seconds", &[("stage", "total")], self.hists.total.summary());
        b.gauge("funcx_tasks_in_flight", &[], self.in_flight() as i64);
    }
}

/// Cheap global counters (tasks dispatched, cold starts, heartbeats, …).
#[derive(Default)]
pub struct Counters {
    pub tasks_submitted: AtomicU64,
    pub tasks_completed: AtomicU64,
    pub tasks_failed: AtomicU64,
    pub tasks_redispatched: AtomicU64,
    /// Tasks whose oversized input was offloaded to the data fabric and
    /// dispatched as a `DataRef` (§5 pass-by-reference).
    pub tasks_ref_dispatched: AtomicU64,
    /// Input bytes kept *out* of the service queues by ref dispatch.
    pub bytes_offloaded: AtomicU64,
    /// Tasks submitted with a prior result's `DataRef` as their input
    /// (ref forwarding — the service never touched the bytes).
    pub tasks_ref_forwarded: AtomicU64,
    /// Completed results whose output came back as a `DataRef`
    /// (`"rref"`) instead of inline bytes (§5 result offload).
    pub results_ref_offloaded: AtomicU64,
    /// Offloaded result frames (`task-result:*`) reclaimed eagerly —
    /// on retrieval (`get_result`) or when the chain task consuming the
    /// ref completed — instead of lingering until TTL.
    pub result_frames_reclaimed: AtomicU64,
    pub cold_starts: AtomicU64,
    pub warm_hits: AtomicU64,
    pub heartbeats: AtomicU64,
    pub bytes_through_service: AtomicU64,
    /// Result-payload bytes stored inline in the service result queue
    /// (by-ref results contribute only their empty placeholder, so this
    /// stays near zero for offloaded chains — pinned in
    /// `tests/data_fabric.rs`).
    pub result_bytes_through_service: AtomicU64,
    /// Replica copies of hot result frames pushed to peer stores (§5
    /// survivability: a ref outlives its owner endpoint).
    pub replicas_created: AtomicU64,
    /// Ref resolutions that completed via a replica (or the replica
    /// scan) after the owner's copy was unreachable — the failover half
    /// of replication.
    pub failover_resolutions: AtomicU64,
    /// Puts refused by a store under spill backpressure (memory tier at
    /// its shed limit over a persistently failing spool).
    pub shed_puts: AtomicU64,
    /// Frames re-homed to replica stores while decommissioning their
    /// owner endpoint.
    pub frames_drained: AtomicU64,
}

impl Counters {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn incr(counter: &AtomicU64) -> u64 {
        counter.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn add(counter: &AtomicU64, n: u64) -> u64 {
        counter.fetch_add(n, Ordering::Relaxed) + n
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Export every counter into a snapshot under its registry name.
    pub fn fill(&self, b: &mut SnapshotBuilder) {
        let dims: &[(&str, &str)] = &[];
        for (name, cell) in [
            ("funcx_tasks_submitted_total", &self.tasks_submitted),
            ("funcx_tasks_completed_total", &self.tasks_completed),
            ("funcx_tasks_failed_total", &self.tasks_failed),
            ("funcx_tasks_redispatched_total", &self.tasks_redispatched),
            ("funcx_tasks_ref_dispatched_total", &self.tasks_ref_dispatched),
            ("funcx_bytes_offloaded_total", &self.bytes_offloaded),
            ("funcx_tasks_ref_forwarded_total", &self.tasks_ref_forwarded),
            ("funcx_results_ref_offloaded_total", &self.results_ref_offloaded),
            ("funcx_result_frames_reclaimed_total", &self.result_frames_reclaimed),
            ("funcx_cold_starts_total", &self.cold_starts),
            ("funcx_warm_hits_total", &self.warm_hits),
            ("funcx_heartbeats_total", &self.heartbeats),
            ("funcx_bytes_through_service_total", &self.bytes_through_service),
            ("funcx_result_bytes_through_service_total", &self.result_bytes_through_service),
            ("funcx_replicas_created_total", &self.replicas_created),
            ("funcx_failover_resolutions_total", &self.failover_resolutions),
            ("funcx_shed_puts_total", &self.shed_puts),
            ("funcx_frames_drained_total", &self.frames_drained),
        ] {
            b.counter(name, dims, Self::get(cell));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(summarize(&[]).count, 0);
    }

    #[test]
    fn summarize_interpolates_percentiles() {
        // 4 samples: p50 sits between the middle two, p99 is *not*
        // simply the max (the old nearest-rank round() bug).
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.p50 - 2.5).abs() < 1e-12, "p50 {}", s.p50);
        assert!((s.p90 - 3.7).abs() < 1e-12, "p90 {}", s.p90);
        assert!((s.p99 - 3.97).abs() < 1e-12, "p99 {}", s.p99);
        assert!(s.p99 < s.max);
        assert!(s.p999 < s.max && s.p999 > s.p99);
        // Degenerate cases stay exact.
        let one = summarize(&[5.0]);
        assert_eq!(one.p50, 5.0);
        assert_eq!(one.p999, 5.0);
    }

    #[test]
    fn breakdown_stages() {
        let lb = LatencyBreakdown::new();
        let t = TaskId::new();
        lb.on_submit(t, 0.0);
        lb.on_queued(t, 0.010); // t_s = 10 ms
        lb.on_forwarded(t, 0.015); // forward leg 5 ms
        lb.on_started(t, 0.035); // t_e = 20 ms
        lb.on_finished(t, 0.055); // t_w = 20 ms
        let b = lb.on_result_stored(t, 0.060).unwrap(); // return leg 5 ms
        assert!((b.t_s - 0.010).abs() < 1e-9);
        assert!((b.t_f - 0.010).abs() < 1e-9);
        assert!((b.t_e - 0.020).abs() < 1e-9);
        assert!((b.t_w - 0.020).abs() < 1e-9);
        assert!((b.total() - 0.060).abs() < 1e-9);
        // Terminal folded + evicted: no per-task record remains, the
        // aggregate histograms hold the stages.
        assert!(lb.breakdown(t).is_none());
        assert_eq!(lb.in_flight(), 0);
        let s = lb.stage_summaries();
        assert_eq!(s.completed, 1);
        assert!((s.t_w.mean - 0.020).abs() < 1e-9);
        assert!((s.total.mean - 0.060).abs() < 1e-9);
    }

    #[test]
    fn incomplete_breakdown_is_none() {
        let lb = LatencyBreakdown::new();
        let t = TaskId::new();
        lb.on_submit(t, 0.0);
        assert!(lb.breakdown(t).is_none());
        assert!(lb.breakdown(TaskId::new()).is_none());
        assert_eq!(lb.in_flight(), 1);
        // A terminal without the middle stamps still evicts the record
        // (conservation: submitted == completed + failed + in-flight).
        assert!(lb.on_result_stored(t, 1.0).is_none());
        assert_eq!(lb.in_flight(), 0);
        assert_eq!(lb.stage_summaries().completed, 0);
    }

    #[test]
    fn tracker_is_bounded() {
        let lb = LatencyBreakdown::new();
        // Submit far more never-completing tasks than the cap.
        for _ in 0..(N_STRIPES * MAX_TRACKED_PER_STRIPE + 10_000) {
            lb.on_submit(TaskId::new(), 0.0);
        }
        assert!(lb.in_flight() <= N_STRIPES * MAX_TRACKED_PER_STRIPE);
    }

    #[test]
    fn counters_work() {
        let c = Counters::new();
        Counters::incr(&c.tasks_submitted);
        Counters::incr(&c.tasks_submitted);
        Counters::add(&c.bytes_through_service, 100);
        assert_eq!(Counters::get(&c.tasks_submitted), 2);
        assert_eq!(Counters::get(&c.bytes_through_service), 100);
    }

    #[test]
    fn counters_fill_exports_all() {
        let c = Counters::new();
        Counters::incr(&c.tasks_submitted);
        let reg = MetricsRegistry::new();
        reg.register_source(move |b| c.fill(b));
        let snap = reg.snapshot();
        assert_eq!(snap.counter_total("funcx_tasks_submitted_total"), 1);
        assert_eq!(snap.counter_total("funcx_frames_drained_total"), 0);
    }
}
