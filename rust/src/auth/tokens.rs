//! Identities, scoped bearer tokens, delegation, groups.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, RwLock};

use crate::common::error::{Error, Result};
use crate::common::ids::{EndpointId, FunctionId, UserId, Uuid};
use crate::common::time::Time;

/// funcX OAuth scopes (§4.7, e.g.
/// `urn:globus:auth:scope:funcx:register_function`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Scope {
    RegisterFunction,
    RunFunction,
    RegisterEndpoint,
    ManageEndpoint,
    Transfer,
    All,
}

impl Scope {
    pub const ALL: [Scope; 6] = [
        Scope::RegisterFunction,
        Scope::RunFunction,
        Scope::RegisterEndpoint,
        Scope::ManageEndpoint,
        Scope::Transfer,
        Scope::All,
    ];

    pub fn urn(&self) -> &'static str {
        match self {
            Scope::RegisterFunction => "urn:globus:auth:scope:funcx:register_function",
            Scope::RunFunction => "urn:globus:auth:scope:funcx:run_function",
            Scope::RegisterEndpoint => "urn:globus:auth:scope:funcx:register_endpoint",
            Scope::ManageEndpoint => "urn:globus:auth:scope:funcx:manage_endpoint",
            Scope::Transfer => "urn:globus:auth:scope:transfer.api.globus.org:all",
            Scope::All => "urn:globus:auth:scope:funcx:all",
        }
    }
}

/// A bearer token: opaque id + subject + scopes + expiry.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub id: Uuid,
    pub subject: UserId,
    pub scopes: Vec<Scope>,
    pub expires_at: Time,
}

struct Identity {
    #[allow(dead_code)]
    username: String,
    groups: HashSet<Uuid>,
}

#[derive(Default)]
struct AuthState {
    identities: HashMap<UserId, Identity>,
    tokens: HashMap<Uuid, Token>,
    /// function -> users allowed to invoke (owner implicit).
    function_grants: HashMap<FunctionId, HashSet<UserId>>,
    /// function -> groups allowed to invoke.
    function_group_grants: HashMap<FunctionId, HashSet<Uuid>>,
    /// endpoint -> users allowed to target it.
    endpoint_grants: HashMap<EndpointId, HashSet<UserId>>,
    groups: HashMap<Uuid, HashSet<UserId>>,
}

/// The IAM service. Clone-shareable.
#[derive(Clone, Default)]
pub struct AuthService {
    state: Arc<RwLock<AuthState>>,
}

impl AuthService {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an identity (institution account, ORCID, …).
    pub fn register_identity(&self, username: &str) -> UserId {
        let id = UserId::new();
        self.state.write().unwrap().identities.insert(
            id,
            Identity { username: username.to_string(), groups: HashSet::new() },
        );
        id
    }

    /// Mint a bearer token for `user` with the given scopes and TTL.
    pub fn issue_token(
        &self,
        user: UserId,
        scopes: &[Scope],
        ttl_s: f64,
        now: Time,
    ) -> Result<Token> {
        let mut st = self.state.write().unwrap();
        if !st.identities.contains_key(&user) {
            return Err(Error::Unauthenticated(format!("unknown identity {user}")));
        }
        let tok = Token {
            id: Uuid::new(),
            subject: user,
            scopes: scopes.to_vec(),
            expires_at: now + ttl_s,
        };
        st.tokens.insert(tok.id, tok.clone());
        Ok(tok)
    }

    /// Validate a token and check it carries `scope` (or `Scope::All`).
    pub fn check(&self, token: &Token, scope: Scope, now: Time) -> Result<UserId> {
        let st = self.state.read().unwrap();
        let stored = st
            .tokens
            .get(&token.id)
            .ok_or_else(|| Error::Unauthenticated("unknown token".into()))?;
        if stored.subject != token.subject {
            return Err(Error::Unauthenticated("token subject mismatch".into()));
        }
        if now >= stored.expires_at {
            return Err(Error::Unauthenticated("token expired".into()));
        }
        if !stored.scopes.contains(&scope) && !stored.scopes.contains(&Scope::All) {
            return Err(Error::Forbidden(format!("missing scope {}", scope.urn())));
        }
        Ok(stored.subject)
    }

    /// Revoke a token (logout / endpoint deregistration).
    pub fn revoke(&self, token: &Token) -> bool {
        self.state.write().unwrap().tokens.remove(&token.id).is_some()
    }

    // ---- groups & delegation (§4.7 "grant access to others") ------------

    pub fn create_group(&self, members: &[UserId]) -> Uuid {
        let gid = Uuid::new();
        let mut st = self.state.write().unwrap();
        st.groups.insert(gid, members.iter().copied().collect());
        for m in members {
            if let Some(idn) = st.identities.get_mut(m) {
                idn.groups.insert(gid);
            }
        }
        gid
    }

    pub fn add_to_group(&self, group: Uuid, user: UserId) {
        let mut st = self.state.write().unwrap();
        st.groups.entry(group).or_default().insert(user);
        if let Some(idn) = st.identities.get_mut(&user) {
            idn.groups.insert(group);
        }
    }

    /// Share a function with a specific user (§3 "users, or groups of
    /// users, who may be authorized to invoke the function").
    pub fn grant_function(&self, function: FunctionId, user: UserId) {
        self.state.write().unwrap().function_grants.entry(function).or_default().insert(user);
    }

    pub fn grant_function_to_group(&self, function: FunctionId, group: Uuid) {
        self.state
            .write().unwrap()
            .function_group_grants
            .entry(function)
            .or_default()
            .insert(group);
    }

    pub fn grant_endpoint(&self, endpoint: EndpointId, user: UserId) {
        self.state.write().unwrap().endpoint_grants.entry(endpoint).or_default().insert(user);
    }

    /// May `user` invoke `function` owned by `owner`?
    pub fn may_invoke_function(
        &self,
        user: UserId,
        owner: UserId,
        function: FunctionId,
    ) -> bool {
        if user == owner {
            return true;
        }
        let st = self.state.read().unwrap();
        if st.function_grants.get(&function).is_some_and(|g| g.contains(&user)) {
            return true;
        }
        if let Some(groups) = st.function_group_grants.get(&function) {
            if let Some(idn) = st.identities.get(&user) {
                if groups.iter().any(|g| idn.groups.contains(g)) {
                    return true;
                }
            }
        }
        false
    }

    /// May `user` target `endpoint` owned by `owner`?
    pub fn may_use_endpoint(
        &self,
        user: UserId,
        owner: UserId,
        endpoint: EndpointId,
    ) -> bool {
        user == owner
            || self
                .state
                .read().unwrap()
                .endpoint_grants
                .get(&endpoint)
                .is_some_and(|g| g.contains(&user))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_lifecycle() {
        let auth = AuthService::new();
        let u = auth.register_identity("alice@uchicago.edu");
        let tok = auth.issue_token(u, &[Scope::RunFunction], 100.0, 0.0).unwrap();
        assert_eq!(auth.check(&tok, Scope::RunFunction, 50.0).unwrap(), u);
        assert!(auth.check(&tok, Scope::RegisterEndpoint, 50.0).is_err());
        assert!(auth.check(&tok, Scope::RunFunction, 100.0).is_err()); // expired
        assert!(auth.revoke(&tok));
        assert!(auth.check(&tok, Scope::RunFunction, 50.0).is_err());
    }

    #[test]
    fn all_scope_is_wildcard() {
        let auth = AuthService::new();
        let u = auth.register_identity("u");
        let tok = auth.issue_token(u, &[Scope::All], 100.0, 0.0).unwrap();
        for s in Scope::ALL {
            assert!(auth.check(&tok, s, 0.0).is_ok());
        }
    }

    #[test]
    fn unknown_identity_rejected() {
        let auth = AuthService::new();
        assert!(auth.issue_token(UserId::new(), &[Scope::All], 10.0, 0.0).is_err());
    }

    #[test]
    fn forged_subject_rejected() {
        let auth = AuthService::new();
        let u = auth.register_identity("u");
        let v = auth.register_identity("v");
        let mut tok = auth.issue_token(u, &[Scope::All], 100.0, 0.0).unwrap();
        tok.subject = v; // forge
        assert!(auth.check(&tok, Scope::RunFunction, 0.0).is_err());
    }

    #[test]
    fn function_sharing_user_and_group() {
        let auth = AuthService::new();
        let owner = auth.register_identity("owner");
        let friend = auth.register_identity("friend");
        let stranger = auth.register_identity("stranger");
        let group_member = auth.register_identity("gm");
        let f = FunctionId::new();

        assert!(auth.may_invoke_function(owner, owner, f));
        assert!(!auth.may_invoke_function(friend, owner, f));
        auth.grant_function(f, friend);
        assert!(auth.may_invoke_function(friend, owner, f));
        assert!(!auth.may_invoke_function(stranger, owner, f));

        let g = auth.create_group(&[group_member]);
        auth.grant_function_to_group(f, g);
        assert!(auth.may_invoke_function(group_member, owner, f));
        // Joining the group later also grants access.
        auth.add_to_group(g, stranger);
        assert!(auth.may_invoke_function(stranger, owner, f));
    }

    #[test]
    fn endpoint_sharing() {
        let auth = AuthService::new();
        let owner = auth.register_identity("owner");
        let other = auth.register_identity("other");
        let e = EndpointId::new();
        assert!(auth.may_use_endpoint(owner, owner, e));
        assert!(!auth.may_use_endpoint(other, owner, e));
        auth.grant_endpoint(e, other);
        assert!(auth.may_use_endpoint(other, owner, e));
    }

    #[test]
    fn scope_urns() {
        assert!(Scope::RegisterFunction.urn().contains("register_function"));
        assert!(Scope::Transfer.urn().contains("transfer"));
    }
}
