//! §4.7 — Globus-Auth-like identity and access management substrate.
//!
//! funcX registers with Globus Auth as a resource server; users hold
//! OAuth2 tokens scoped to funcX operations; endpoints are native clients
//! that depend on funcX scopes; users may delegate access (share
//! functions/endpoints with users or groups). We reproduce the model —
//! identities, scoped bearer tokens with expiry, delegation grants, and
//! group membership — as an in-process service.

mod tokens;

pub use tokens::{AuthService, Scope, Token};

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn scopes_are_exact() {
        // A token authorizes exactly the scopes it was minted with
        // (Scope::All excepted — it is the wildcard by definition).
        check("auth-scopes-exact", 100, |g| {
            let auth = AuthService::new();
            let user = auth.register_identity("u@example.org");
            let n = g.usize(1, 6);
            let mut granted = std::collections::BTreeSet::new();
            for _ in 0..n {
                granted.insert(g.usize(0, 5)); // skip index 5 = Scope::All
            }
            let scopes: Vec<Scope> = granted.iter().map(|i| Scope::ALL[*i]).collect();
            let tok = auth.issue_token(user, &scopes, 3600.0, 0.0).unwrap();
            for (i, s) in Scope::ALL.iter().enumerate().take(5) {
                let ok = auth.check(&tok, *s, 1.0).is_ok();
                assert_eq!(ok, granted.contains(&i), "scope {s:?}");
            }
        });
    }

    #[test]
    fn token_expiry_strict_boundary() {
        check("auth-expiry", 200, |g| {
            let auth = AuthService::new();
            let user = auth.register_identity("u@example.org");
            let ttl = g.f64(1.0, 1000.0);
            let probe = g.f64(0.0, 2000.0);
            let tok = auth.issue_token(user, &[Scope::RunFunction], ttl, 0.0).unwrap();
            let ok = auth.check(&tok, Scope::RunFunction, probe).is_ok();
            assert_eq!(ok, probe < ttl);
        });
    }
}
