//! §6.3 — the elastic provisioning strategy (monitoring + scaling).
//!
//! Every `strategy_period_s` the agent feeds the strategy a load
//! snapshot; the strategy returns how many nodes to request and which
//! idle nodes to release. Pure function of its inputs → trivially
//! testable, shared verbatim by the live engine and the simulator.

use crate::common::config::EndpointConfig;
use crate::common::time::Time;
use crate::provider::NodeHandle;

/// Load snapshot handed to the strategy (§6.3 "the monitoring component
/// ... fetch[es] the current endpoint load, including the active and idle
/// resources and the number of pending function requests").
#[derive(Clone, Debug)]
pub struct StrategyInputs {
    pub now: Time,
    /// Tasks waiting at the agent (not yet dispatched to managers).
    pub pending_tasks: usize,
    /// Idle worker slots across connected managers.
    pub idle_workers: usize,
    /// Nodes currently active (hosting managers).
    pub active_nodes: usize,
    /// Nodes requested but not yet active.
    pub pending_nodes: usize,
    /// Nodes idle (no busy workers) with their idle-since stamps.
    pub idle_nodes: Vec<(NodeHandle, Time)>,
}

/// The strategy's verdict for this tick.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScaleDecision {
    pub request_nodes: usize,
    pub release: Vec<NodeHandle>,
}

/// The paper's default strategy:
/// * scale **out** when pending tasks exceed idle workers, requesting one
///   node per `tasks_per_node_scaling` excess pending tasks (§6.3
///   "request one more resource when there are ten waiting requests"),
///   clamped by `max_nodes`;
/// * scale **in** by releasing nodes idle longer than
///   `node_idle_timeout_s` (default 2 min), clamped by `min_nodes`.
#[derive(Clone, Debug)]
pub struct Strategy {
    pub cfg: EndpointConfig,
}

impl Strategy {
    pub fn new(cfg: EndpointConfig) -> Self {
        Strategy { cfg }
    }

    pub fn decide(&self, inputs: &StrategyInputs) -> ScaleDecision {
        let mut d = ScaleDecision::default();
        let total = inputs.active_nodes + inputs.pending_nodes;

        // Scale out.
        if inputs.pending_tasks > inputs.idle_workers {
            let excess = inputs.pending_tasks - inputs.idle_workers;
            let per = self.cfg.tasks_per_node_scaling.max(1);
            let want = excess.div_ceil(per);
            let headroom = self.cfg.max_nodes.saturating_sub(total);
            d.request_nodes = want.min(headroom);
        }

        // Scale in: release idle-timed-out nodes, but never below min and
        // never while work is queued (they'd be re-requested immediately).
        if inputs.pending_tasks == 0 {
            let releasable = inputs.active_nodes.saturating_sub(self.cfg.min_nodes);
            let mut victims: Vec<(NodeHandle, Time)> = inputs
                .idle_nodes
                .iter()
                .filter(|(_, since)| inputs.now - since >= self.cfg.node_idle_timeout_s)
                .copied()
                .collect();
            // Longest-idle first.
            victims.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            d.release = victims.into_iter().take(releasable).map(|(h, _)| h).collect();
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EndpointConfig {
        EndpointConfig {
            min_nodes: 1,
            max_nodes: 8,
            tasks_per_node_scaling: 10,
            node_idle_timeout_s: 120.0,
            ..Default::default()
        }
    }

    fn inputs() -> StrategyInputs {
        StrategyInputs {
            now: 1000.0,
            pending_tasks: 0,
            idle_workers: 0,
            active_nodes: 2,
            pending_nodes: 0,
            idle_nodes: vec![],
        }
    }

    #[test]
    fn scales_out_one_node_per_ten_pending() {
        let s = Strategy::new(cfg());
        let mut i = inputs();
        i.pending_tasks = 25;
        i.idle_workers = 0;
        assert_eq!(s.decide(&i).request_nodes, 3); // ceil(25/10)
        i.pending_tasks = 10;
        assert_eq!(s.decide(&i).request_nodes, 1);
        i.pending_tasks = 1;
        assert_eq!(s.decide(&i).request_nodes, 1);
    }

    #[test]
    fn no_scale_out_when_idle_capacity_covers() {
        let s = Strategy::new(cfg());
        let mut i = inputs();
        i.pending_tasks = 5;
        i.idle_workers = 5;
        assert_eq!(s.decide(&i).request_nodes, 0);
    }

    #[test]
    fn max_nodes_clamps() {
        let s = Strategy::new(cfg());
        let mut i = inputs();
        i.pending_tasks = 1000;
        i.active_nodes = 6;
        i.pending_nodes = 1;
        assert_eq!(s.decide(&i).request_nodes, 1); // 8 - 7
        i.active_nodes = 8;
        assert_eq!(s.decide(&i).request_nodes, 0);
    }

    #[test]
    fn releases_idle_timed_out_nodes() {
        let s = Strategy::new(cfg());
        let mut i = inputs();
        i.active_nodes = 3;
        i.idle_nodes = vec![
            (NodeHandle(1), 800.0),  // idle 200s -> release
            (NodeHandle(2), 950.0),  // idle 50s -> keep
            (NodeHandle(3), 700.0),  // idle 300s -> release
        ];
        let d = s.decide(&i);
        assert_eq!(d.release, vec![NodeHandle(3), NodeHandle(1)]); // longest idle first
    }

    #[test]
    fn never_releases_below_min() {
        let s = Strategy::new(cfg());
        let mut i = inputs();
        i.active_nodes = 2;
        i.idle_nodes = vec![(NodeHandle(1), 0.0), (NodeHandle(2), 0.0)];
        let d = s.decide(&i);
        assert_eq!(d.release.len(), 1); // min_nodes = 1
    }

    #[test]
    fn no_release_while_tasks_pending() {
        let s = Strategy::new(cfg());
        let mut i = inputs();
        i.pending_tasks = 3;
        i.idle_workers = 50; // plenty idle, no scale-out
        i.active_nodes = 3;
        i.idle_nodes = vec![(NodeHandle(1), 0.0)];
        let d = s.decide(&i);
        assert_eq!(d.request_nodes, 0);
        assert!(d.release.is_empty());
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn bounds_always_respected() {
        // min <= active - released, active + pending + requested <= max.
        check("strategy-bounds", 300, |g| {
            let cfg = EndpointConfig {
                min_nodes: g.usize(0, 4),
                max_nodes: g.usize(4, 64),
                tasks_per_node_scaling: g.usize(1, 20),
                node_idle_timeout_s: g.f64(1.0, 300.0),
                ..Default::default()
            };
            let active = g.usize(0, 32);
            let idle_n = g.usize(0, active + 1);
            let now = g.f64(1000.0, 2000.0);
            let inputs = StrategyInputs {
                now,
                pending_tasks: g.usize(0, 2000),
                idle_workers: g.usize(0, 512),
                active_nodes: active,
                pending_nodes: g.usize(0, 8),
                idle_nodes: (0..idle_n)
                    .map(|i| (NodeHandle(i as u64), g.f64(0.0, now)))
                    .collect(),
            };
            let d = Strategy::new(cfg.clone()).decide(&inputs);
            let total_after =
                inputs.active_nodes + inputs.pending_nodes + d.request_nodes;
            assert!(
                total_after <= cfg.max_nodes.max(inputs.active_nodes + inputs.pending_nodes),
                "scale-out exceeded max: {total_after} > {}",
                cfg.max_nodes
            );
            assert!(
                inputs.active_nodes - d.release.len() >= cfg.min_nodes.min(inputs.active_nodes),
                "released below min"
            );
            // Released nodes must all have timed out.
            for h in &d.release {
                let (_, since) =
                    inputs.idle_nodes.iter().find(|(n, _)| n == h).expect("released unknown node");
                assert!(inputs.now - since >= cfg.node_idle_timeout_s);
            }
        });
    }
}
