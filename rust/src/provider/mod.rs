//! §4.4 + §6.3 — the Parsl-like provider interface and the elastic
//! provisioning strategy.
//!
//! funcX uses Parsl's provider interface to provision nodes uniformly
//! across batch schedulers (Slurm, PBS, Cobalt, SGE, Condor), clouds
//! (AWS, Azure, GCP), and Kubernetes, with a pilot-job model. The
//! *strategy* monitors endpoint load every second and scales between
//! user-configured min/max bounds, releasing nodes idle longer than the
//! max idle time (default 2 min).

mod strategy;

pub use strategy::{ScaleDecision, Strategy, StrategyInputs};

use crate::common::rng::Rng;
use crate::common::time::Time;

/// A provisioned-node handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NodeHandle(pub u64);

/// State of one provisioning request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NodeState {
    /// In the scheduler queue / instance booting.
    Pending { ready_at: Time },
    /// Running and available to host a manager.
    Active,
    /// Released.
    Released,
}

/// Uniform interface over batch schedulers, clouds and K8s (§4.4).
pub trait Provider: Send {
    /// Request `n` nodes; returns handles immediately (pilot-job style);
    /// nodes become active after the provider's queue/boot delay.
    fn request_nodes(&mut self, n: usize, now: Time) -> Vec<NodeHandle>;

    /// Release a node.
    fn release_node(&mut self, h: NodeHandle, now: Time);

    /// Advance provider-internal state; returns nodes that became active
    /// since the last poll.
    fn poll(&mut self, now: Time) -> Vec<NodeHandle>;

    fn state(&self, h: NodeHandle) -> Option<NodeState>;

    fn active_count(&self) -> usize;

    fn pending_count(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Queue-delay profile for a simulated provider.
#[derive(Clone, Copy, Debug)]
pub struct DelayProfile {
    /// Median queue/boot delay in seconds.
    pub median_s: f64,
    /// Log-normal sigma (spread). 0 = deterministic.
    pub sigma: f64,
}

impl DelayProfile {
    fn sample(&self, rng: &mut Rng) -> f64 {
        if self.sigma == 0.0 {
            self.median_s
        } else {
            // median of lognormal(mu, sigma) is exp(mu).
            rng.lognormal(self.median_s.max(1e-9).ln(), self.sigma)
        }
    }
}

/// A simulated resource provider with a queue-delay model. One type
/// covers all schedulers; the constructors encode per-system profiles.
pub struct SimProvider {
    name: &'static str,
    delay: DelayProfile,
    rng: Rng,
    nodes: std::collections::HashMap<NodeHandle, NodeState>,
    next_id: u64,
}

impl SimProvider {
    pub fn new(name: &'static str, delay: DelayProfile, seed: u64) -> Self {
        SimProvider {
            name,
            delay,
            rng: Rng::new(seed),
            nodes: Default::default(),
            next_id: 0,
        }
    }

    /// HPC batch scheduler (Slurm/PBS/Cobalt): minutes-scale queue waits.
    pub fn slurm(seed: u64) -> Self {
        Self::new("slurm", DelayProfile { median_s: 120.0, sigma: 0.8 }, seed)
    }

    pub fn pbs(seed: u64) -> Self {
        Self::new("pbs", DelayProfile { median_s: 180.0, sigma: 0.9 }, seed)
    }

    pub fn cobalt(seed: u64) -> Self {
        Self::new("cobalt", DelayProfile { median_s: 150.0, sigma: 0.8 }, seed)
    }

    /// Cloud instances: tens of seconds to boot.
    pub fn cloud(seed: u64) -> Self {
        Self::new("cloud", DelayProfile { median_s: 30.0, sigma: 0.3 }, seed)
    }

    /// Kubernetes pods: seconds.
    pub fn kubernetes(seed: u64) -> Self {
        Self::new("kubernetes", DelayProfile { median_s: 2.0, sigma: 0.3 }, seed)
    }

    /// Local processes: effectively instant (used by the live engine).
    pub fn local(seed: u64) -> Self {
        Self::new("local", DelayProfile { median_s: 0.0, sigma: 0.0 }, seed)
    }
}

impl Provider for SimProvider {
    fn request_nodes(&mut self, n: usize, now: Time) -> Vec<NodeHandle> {
        (0..n)
            .map(|_| {
                let h = NodeHandle(self.next_id);
                self.next_id += 1;
                let ready_at = now + self.delay.sample(&mut self.rng);
                self.nodes.insert(h, NodeState::Pending { ready_at });
                h
            })
            .collect()
    }

    fn release_node(&mut self, h: NodeHandle, _now: Time) {
        self.nodes.insert(h, NodeState::Released);
    }

    fn poll(&mut self, now: Time) -> Vec<NodeHandle> {
        let mut activated = Vec::new();
        for (h, st) in self.nodes.iter_mut() {
            if let NodeState::Pending { ready_at } = st {
                if now >= *ready_at {
                    *st = NodeState::Active;
                    activated.push(*h);
                }
            }
        }
        activated.sort_by_key(|h| h.0);
        activated
    }

    fn state(&self, h: NodeHandle) -> Option<NodeState> {
        self.nodes.get(&h).copied()
    }

    fn active_count(&self) -> usize {
        self.nodes.values().filter(|s| matches!(s, NodeState::Active)).count()
    }

    fn pending_count(&self) -> usize {
        self.nodes.values().filter(|s| matches!(s, NodeState::Pending { .. })).count()
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_nodes_activate_immediately() {
        let mut p = SimProvider::local(1);
        let hs = p.request_nodes(3, 0.0);
        assert_eq!(hs.len(), 3);
        assert_eq!(p.pending_count(), 3);
        let active = p.poll(0.0);
        assert_eq!(active.len(), 3);
        assert_eq!(p.active_count(), 3);
    }

    #[test]
    fn slurm_nodes_wait_in_queue() {
        let mut p = SimProvider::slurm(2);
        p.request_nodes(4, 0.0);
        assert!(p.poll(1.0).is_empty(), "no node should clear a batch queue in 1s");
        // All eventually activate (give a generous horizon).
        let activated = p.poll(1e6);
        assert_eq!(activated.len(), 4);
    }

    #[test]
    fn release_is_terminal() {
        let mut p = SimProvider::local(3);
        let h = p.request_nodes(1, 0.0)[0];
        p.poll(0.0);
        p.release_node(h, 1.0);
        assert_eq!(p.state(h), Some(NodeState::Released));
        assert_eq!(p.active_count(), 0);
        assert!(p.poll(2.0).is_empty());
    }

    #[test]
    fn provider_profiles_ordered() {
        // Queue-delay medians: HPC > cloud > k8s > local.
        let mut slurm = SimProvider::slurm(4);
        let mut cloud = SimProvider::cloud(4);
        let mut k8s = SimProvider::kubernetes(4);
        let sample = |p: &mut SimProvider| {
            let hs = p.request_nodes(200, 0.0);
            let mut times: Vec<f64> = hs
                .iter()
                .map(|h| match p.state(*h).unwrap() {
                    NodeState::Pending { ready_at } => ready_at,
                    _ => 0.0,
                })
                .collect();
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            times[times.len() / 2]
        };
        let (s, c, k) = (sample(&mut slurm), sample(&mut cloud), sample(&mut k8s));
        assert!(s > c && c > k, "medians: slurm {s} cloud {c} k8s {k}");
    }
}
