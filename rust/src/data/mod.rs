//! §5.2 — intra-endpoint data management.
//!
//! Functions on one endpoint exchange intermediate data through a data
//! channel. The paper evaluates four approaches (Fig. 5) — MPI, ZeroMQ
//! sockets, an in-memory store (Redis), and the shared file system — and
//! adopts the last two for generality.
//!
//! This module provides:
//! * [`DataChannel`] — the runtime interface workers use, with two *real*
//!   implementations: [`InMemoryChannel`] (our Redis-subset store) and
//!   [`SharedFsChannel`] (actual files under a spool directory);
//! * [`TransportModel`] — calibrated latency/bandwidth cost models for
//!   all four approaches and the three communication patterns, used by
//!   the Fig. 5 / Table 1 / Table 2 benches at paper scale (30 GB
//!   shuffles don't fit a CI machine; the models preserve the ordering
//!   and convergence the paper reports).

mod channel;
mod model;

pub use channel::{DataChannel, InMemoryChannel, SharedFsChannel};
pub use model::{CommPattern, Transport, TransportModel};
