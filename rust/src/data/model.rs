//! Calibrated transport cost models for the Fig. 5 / Table 1 / Table 2
//! experiments at paper scale.
//!
//! Parameters follow the latency/bandwidth (α-β) model with a per-file
//! metadata cost for the shared FS. Values are first-principles numbers
//! for a KNL cluster with a Cray Aries-class interconnect (the paper's
//! Theta testbed): the absolute times are ours, the *ordering* and the
//! large-transfer convergence are the paper's claims (Fig. 5):
//!
//! * MPI is fastest at small sizes (µs-scale software latency),
//! * ZeroMQ and the in-memory store trail closely (extra copies / a
//!   broker hop),
//! * sharedFS is worst, ms-scale metadata ops and FS contention,
//! * as transfer size grows, all approaches converge to the network
//!   bandwidth (the same wire for everyone).

use crate::common::rng::Rng;

/// The four §5.2 transports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Transport {
    Mpi,
    ZeroMq,
    InMemoryStore,
    SharedFs,
}

impl Transport {
    pub const ALL: [Transport; 4] =
        [Transport::Mpi, Transport::ZeroMq, Transport::InMemoryStore, Transport::SharedFs];

    pub fn name(&self) -> &'static str {
        match self {
            Transport::Mpi => "mpi",
            Transport::ZeroMq => "zeromq",
            Transport::InMemoryStore => "in-memory",
            Transport::SharedFs => "shared-fs",
        }
    }
}

/// Communication patterns measured in Fig. 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// One sender, one receiver.
    PointToPoint,
    /// One sender to `n` receivers.
    Broadcast { nodes: usize },
    /// Every node sends a share to every other node.
    AllToAll { nodes: usize },
}

/// α-β(+metadata) cost model for one transport.
#[derive(Clone, Copy, Debug)]
pub struct TransportModel {
    pub transport: Transport,
    /// Per-message software latency, seconds.
    pub alpha_s: f64,
    /// Sustained point-to-point bandwidth, bytes/s.
    pub beta_bps: f64,
    /// Per-file/metadata operation cost (FS open/close, broker RTT).
    pub meta_s: f64,
    /// Shared-bottleneck bandwidth (the network fabric / OSS pool) that
    /// concurrent flows divide, bytes/s.
    pub fabric_bps: f64,
}

impl TransportModel {
    /// Theta-like parameterisation of the four transports.
    pub fn theta(transport: Transport) -> Self {
        match transport {
            // mpi4py over Aries: ~10 µs latency, ~8 GB/s effective p2p.
            Transport::Mpi => TransportModel {
                transport,
                alpha_s: 10e-6,
                beta_bps: 8.0e9,
                meta_s: 0.0,
                fabric_bps: 8.0e9,
            },
            // ZeroMQ: extra copies + TCP stack: ~35 µs, ~7 GB/s.
            Transport::ZeroMq => TransportModel {
                transport,
                alpha_s: 35e-6,
                beta_bps: 7.0e9,
                meta_s: 0.0,
                fabric_bps: 7.5e9,
            },
            // Redis: client->server->client (two hops through one broker
            // node): ~60 µs RTT, per-hop bandwidth halves the effective
            // rate for one flow but the fabric still bounds aggregate.
            Transport::InMemoryStore => TransportModel {
                transport,
                alpha_s: 60e-6,
                beta_bps: 3.5e9,
                meta_s: 20e-6,
                fabric_bps: 7.0e9,
            },
            // Lustre: ms-scale metadata (open/create on the MDS), good
            // streaming bandwidth per OST but heavy contention under
            // many-file workloads.
            Transport::SharedFs => TransportModel {
                transport,
                alpha_s: 200e-6,
                beta_bps: 2.0e9,
                meta_s: 4e-3,
                fabric_bps: 5.0e9,
            },
        }
    }

    /// Time for one message of `size` bytes, single flow.
    pub fn message_time(&self, size: usize) -> f64 {
        // Write + read legs for store/FS are folded into alpha/meta.
        self.alpha_s + self.meta_s + size as f64 / self.beta_bps
    }

    /// Time to complete a whole pattern with `size` bytes per message.
    /// Concurrent flows share the fabric bandwidth, which is what makes
    /// the approaches converge at large sizes (Fig. 5's observation).
    pub fn pattern_time(&self, pattern: CommPattern, size: usize) -> f64 {
        match pattern {
            CommPattern::PointToPoint => self.message_time(size),
            CommPattern::Broadcast { nodes } => {
                let n = nodes.max(1);
                match self.transport {
                    // MPI broadcast: binomial tree, log2(n) rounds.
                    Transport::Mpi => {
                        let rounds = (n as f64).log2().ceil().max(1.0);
                        rounds * self.message_time(size)
                    }
                    // ZMQ: sender pushes n copies out one NIC (serialised
                    // on the sender's bandwidth).
                    Transport::ZeroMq => {
                        self.alpha_s + n as f64 * size as f64 / self.beta_bps
                    }
                    // Store: one write, n concurrent reads bounded by the
                    // broker's fabric share.
                    Transport::InMemoryStore => {
                        let write = self.message_time(size);
                        let read_bw = (self.fabric_bps / n as f64).min(self.beta_bps);
                        write + self.alpha_s + self.meta_s + size as f64 / read_bw
                    }
                    // FS: one write, n reads hammering the same OST.
                    Transport::SharedFs => {
                        let write = self.message_time(size);
                        let read_bw = (self.fabric_bps / n as f64).min(self.beta_bps);
                        write + self.meta_s * n as f64 / 4.0 + size as f64 / read_bw
                    }
                }
            }
            CommPattern::AllToAll { nodes } => {
                let n = nodes.max(1) as f64;
                let msgs = n * (n - 1.0);
                match self.transport {
                    // MPI alltoall: n rounds of pairwise exchange, fabric
                    // bisection shared.
                    Transport::Mpi => {
                        n * self.alpha_s
                            + msgs * size as f64 / self.fabric_bps.min(n * self.beta_bps)
                    }
                    Transport::ZeroMq => {
                        // Pairwise sockets, n(n-1) messages over the fabric.
                        n * self.alpha_s + msgs * size as f64 / self.fabric_bps
                    }
                    Transport::InMemoryStore => {
                        // Everything funnels through the broker twice.
                        msgs * (self.alpha_s + self.meta_s)
                            + 2.0 * msgs * size as f64 / self.fabric_bps
                    }
                    Transport::SharedFs => {
                        // n(n-1) files created + read: metadata storm plus
                        // shared OST bandwidth both ways.
                        msgs * self.meta_s + 2.0 * msgs * size as f64 / self.fabric_bps
                    }
                }
            }
        }
    }

    /// Sampled variant with ±10 % multiplicative jitter (for plots).
    pub fn pattern_time_sampled(
        &self,
        pattern: CommPattern,
        size: usize,
        rng: &mut Rng,
    ) -> f64 {
        self.pattern_time(pattern, size) * rng.range_f64(0.95, 1.10)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KB: usize = 1024;
    const MB: usize = 1024 * 1024;

    fn t(tr: Transport, p: CommPattern, size: usize) -> f64 {
        TransportModel::theta(tr).pattern_time(p, size)
    }

    #[test]
    fn small_p2p_ordering_matches_paper() {
        // Fig. 5 top: at small sizes MPI < ZMQ <= Redis << sharedFS.
        let p = CommPattern::PointToPoint;
        let s = 4 * KB;
        let (mpi, zmq, mem, fs) = (
            t(Transport::Mpi, p, s),
            t(Transport::ZeroMq, p, s),
            t(Transport::InMemoryStore, p, s),
            t(Transport::SharedFs, p, s),
        );
        assert!(mpi < zmq, "mpi {mpi} < zmq {zmq}");
        assert!(zmq < mem, "zmq {zmq} < mem {mem}");
        assert!(mem < fs, "mem {mem} < fs {fs}");
        assert!(fs / mpi > 50.0, "sharedFS dominated by metadata at small sizes");
    }

    #[test]
    fn large_sizes_converge() {
        // Fig. 5: "As data volume increases, the performance difference
        // ... diminishes" — bandwidth-bound regime.
        let p = CommPattern::PointToPoint;
        let s = 1024 * MB;
        let mpi = t(Transport::Mpi, p, s);
        let fs = t(Transport::SharedFs, p, s);
        let ratio = fs / mpi;
        assert!(
            ratio < 6.0,
            "large-transfer ratio should collapse vs the >50x small-size gap, got {ratio}"
        );
    }

    #[test]
    fn broadcast_scales_with_fanout() {
        for tr in Transport::ALL {
            let one = t(tr, CommPattern::Broadcast { nodes: 2 }, MB);
            let many = t(tr, CommPattern::Broadcast { nodes: 20 }, MB);
            assert!(many > one, "{tr:?}: broadcast must cost more with more nodes");
        }
    }

    #[test]
    fn all_to_all_quadratic_pressure() {
        for tr in Transport::ALL {
            let small = t(tr, CommPattern::AllToAll { nodes: 5 }, 64 * KB);
            let large = t(tr, CommPattern::AllToAll { nodes: 20 }, 64 * KB);
            assert!(
                large / small > 5.0,
                "{tr:?}: all-to-all grows superlinearly in node count"
            );
        }
    }

    #[test]
    fn monotone_in_size() {
        for tr in Transport::ALL {
            for pat in [
                CommPattern::PointToPoint,
                CommPattern::Broadcast { nodes: 20 },
                CommPattern::AllToAll { nodes: 20 },
            ] {
                let mut prev = 0.0;
                for size in [KB, 32 * KB, MB, 32 * MB, 1024 * MB] {
                    let v = t(tr, pat, size);
                    assert!(v > prev, "{tr:?}/{pat:?} not monotone at {size}");
                    prev = v;
                }
            }
        }
    }

    #[test]
    fn sampled_jitter_bounded() {
        let m = TransportModel::theta(Transport::Mpi);
        let base = m.pattern_time(CommPattern::PointToPoint, MB);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = m.pattern_time_sampled(CommPattern::PointToPoint, MB, &mut rng);
            assert!(v >= base * 0.95 && v <= base * 1.10);
        }
    }
}
