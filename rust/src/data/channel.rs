//! Runtime data channels used by workers (real I/O, not models).

use std::path::PathBuf;

use crate::common::error::{Error, Result};
use crate::store::KvStore;

/// Key-value data plane for intermediate data (Listing 3's
/// `get_redis_client()` equivalent).
pub trait DataChannel: Send + Sync {
    fn put(&self, key: &str, data: &[u8]) -> Result<()>;
    fn get(&self, key: &str) -> Result<Vec<u8>>;
    fn delete(&self, key: &str) -> Result<bool>;
    fn name(&self) -> &'static str;
}

/// In-memory store channel (the endpoint-deployed Redis cluster; §5.2).
#[derive(Clone)]
pub struct InMemoryChannel {
    store: KvStore,
}

impl InMemoryChannel {
    pub fn new(store: KvStore) -> Self {
        InMemoryChannel { store }
    }
}

impl Default for InMemoryChannel {
    fn default() -> Self {
        Self::new(KvStore::new())
    }
}

impl DataChannel for InMemoryChannel {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.store.set(key, data);
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        self.store
            .get(key)
            .map(|b| b.to_vec())
            .ok_or_else(|| Error::Data(format!("key not found: {key}")))
    }

    fn delete(&self, key: &str) -> Result<bool> {
        Ok(self.store.del(key))
    }

    fn name(&self) -> &'static str {
        "in-memory"
    }
}

/// Shared-file-system channel: keys are files under a spool directory
/// (Lustre/GPFS stand-in — real file I/O).
pub struct SharedFsChannel {
    root: PathBuf,
}

impl SharedFsChannel {
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        Ok(SharedFsChannel { root })
    }

    /// A channel under the system temp dir with a unique suffix.
    pub fn temp() -> Result<Self> {
        let dir = std::env::temp_dir()
            .join(format!("funcx-sharedfs-{}", crate::Uuid::new()));
        Self::new(dir)
    }

    fn path_for(&self, key: &str) -> PathBuf {
        // Sanitize: keys may contain separators from namespacing.
        let safe: String = key
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.root.join(safe)
    }

    pub fn root(&self) -> &PathBuf {
        &self.root
    }
}

impl DataChannel for SharedFsChannel {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        Ok(std::fs::write(self.path_for(key), data)?)
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        std::fs::read(self.path_for(key))
            .map_err(|e| Error::Data(format!("key not found: {key} ({e})")))
    }

    fn delete(&self, key: &str) -> Result<bool> {
        match std::fs::remove_file(self.path_for(key)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    fn name(&self) -> &'static str {
        "shared-fs"
    }
}

impl Drop for SharedFsChannel {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(ch: &dyn DataChannel) {
        ch.put("shuffle/part-0", b"hello").unwrap();
        assert_eq!(ch.get("shuffle/part-0").unwrap(), b"hello");
        ch.put("shuffle/part-0", b"overwritten").unwrap();
        assert_eq!(ch.get("shuffle/part-0").unwrap(), b"overwritten");
        assert!(ch.get("missing").is_err());
        assert!(ch.delete("shuffle/part-0").unwrap());
        assert!(!ch.delete("shuffle/part-0").unwrap());
        assert!(ch.get("shuffle/part-0").is_err());
    }

    #[test]
    fn in_memory_contract() {
        exercise(&InMemoryChannel::default());
    }

    #[test]
    fn shared_fs_contract() {
        exercise(&SharedFsChannel::temp().unwrap());
    }

    #[test]
    fn shared_fs_cleans_up_on_drop() {
        let root;
        {
            let ch = SharedFsChannel::temp().unwrap();
            root = ch.root().clone();
            ch.put("k", b"v").unwrap();
            assert!(root.exists());
        }
        assert!(!root.exists());
    }

    #[test]
    fn large_payload_roundtrip() {
        let ch = InMemoryChannel::default();
        let blob = vec![0xA5u8; 4 << 20]; // 4 MB
        ch.put("big", &blob).unwrap();
        assert_eq!(ch.get("big").unwrap().len(), blob.len());
    }
}
