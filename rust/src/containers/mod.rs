//! §4.2, §6.1 — container technologies, instantiation cost models, and
//! the warm pool.
//!
//! funcX adopts Docker (cloud/local), Singularity (ALCF) and Shifter
//! (NERSC). Cold instantiation is expensive on HPC systems (Table 3:
//! ~10 s on Theta vs ~1.2–1.8 s on EC2), which motivates warming (§6.1)
//! and warming-aware routing (§6.2).

mod pool;
mod tech;

pub use pool::{Acquire, ContainerSlot, SlotState, WarmPool};
pub use tech::{ContainerTech, StartCostModel, SystemProfile, TABLE3_MODELS};

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::common::ids::ContainerId;
    use crate::testing::check;

    #[test]
    fn pool_never_exceeds_capacity() {
        check("pool-capacity", 100, |g| {
            let cap = g.usize(1, 12);
            let mut pool = WarmPool::new(cap, 600.0);
            let types: Vec<ContainerId> =
                (0..4).map(|i| ContainerId::from_bits(i as u128 + 1)).collect();
            let ops = g.usize(1, 100);
            let mut now = 0.0;
            for _ in 0..ops {
                now += g.f64(0.0, 5.0);
                match g.usize(0, 3) {
                    0 => {
                        let c = *g.choose(&types);
                        let _ = pool.acquire(c, now);
                    }
                    1 => {
                        // release something busy if any
                        if let Some(slot) = pool.busy_slots().first().copied() {
                            pool.release(slot, now).unwrap();
                        }
                    }
                    _ => {
                        pool.reap_idle(now);
                    }
                }
                assert!(pool.total() <= cap, "pool grew past capacity");
            }
        });
    }

    #[test]
    fn warm_acquire_never_cold_starts() {
        // If a warm idle container of the right type exists, acquire()
        // must reuse it (the §6.1 invariant warming exists to provide).
        check("pool-warm-reuse", 100, |g| {
            let mut pool = WarmPool::new(4, 600.0);
            let c = ContainerId::from_bits(1);
            let now = g.f64(0.0, 100.0);
            let slot = pool.acquire(c, now).expect("capacity available");
            pool.release(slot, now).unwrap(); // now warm+idle
            let warm_before = pool.warm_idle_count(c);
            assert_eq!(warm_before, 1);
            let (slot2, cold) = pool.acquire_with_origin(c, now + 1.0).unwrap();
            assert!(!cold, "acquire must reuse the warm container");
            assert_eq!(slot2, slot);
        });
    }
}
