//! The per-manager warm container pool (§6.1–§6.2 manager side).
//!
//! A manager owns a fixed number of worker slots. Each slot may host a
//! container of some type; the pool keeps finished containers *warm*
//! until capacity pressure or an idle timeout (default 10 min) reaps
//! them. When a task arrives for a type with no warm container, the pool
//! cold-starts one — evicting the least-recently-used idle container of
//! another type if the pool is full.

use std::collections::HashMap;

use crate::common::ids::ContainerId;
use crate::common::time::Time;

/// Slot index within a manager.
pub type ContainerSlot = usize;

/// Outcome of a container acquisition.
#[derive(Clone, Copy, Debug)]
pub struct Acquire {
    pub slot: ContainerSlot,
    pub cold: bool,
    /// Warm container type evicted to make room, if any.
    pub evicted: Option<ContainerId>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SlotState {
    /// No container in this slot.
    Empty,
    /// Container warm and idle since the given time.
    WarmIdle { since: Time },
    /// Container executing a task.
    Busy,
}

#[derive(Clone, Copy, Debug)]
struct Slot {
    ctype: Option<ContainerId>,
    state: SlotState,
}

/// Warm-container bookkeeping for one manager.
#[derive(Clone, Debug)]
pub struct WarmPool {
    slots: Vec<Slot>,
    idle_timeout_s: f64,
    cold_starts: u64,
    warm_hits: u64,
    evictions: u64,
}

impl WarmPool {
    pub fn new(capacity: usize, idle_timeout_s: f64) -> Self {
        WarmPool {
            slots: vec![Slot { ctype: None, state: SlotState::Empty }; capacity],
            idle_timeout_s,
            cold_starts: 0,
            warm_hits: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of non-empty slots.
    pub fn total(&self) -> usize {
        self.slots.iter().filter(|s| s.state != SlotState::Empty).count()
    }

    /// Warm idle containers of the given type.
    pub fn warm_idle_count(&self, ctype: ContainerId) -> usize {
        self.slots
            .iter()
            .filter(|s| {
                s.ctype == Some(ctype) && matches!(s.state, SlotState::WarmIdle { .. })
            })
            .count()
    }

    /// All currently-busy slots.
    pub fn busy_slots(&self) -> Vec<ContainerSlot> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state == SlotState::Busy)
            .map(|(i, _)| i)
            .collect()
    }

    /// Idle (warm) + empty slots — the capacity advertised to the agent.
    pub fn available_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.state != SlotState::Busy).count()
    }

    /// Warm-idle census by type.
    pub fn warm_census(&self) -> HashMap<ContainerId, usize> {
        let mut m = HashMap::new();
        for s in &self.slots {
            if let (Some(c), SlotState::WarmIdle { .. }) = (s.ctype, s.state) {
                *m.entry(c).or_insert(0) += 1;
            }
        }
        m
    }

    /// Deployed-container census by type — busy AND idle ("Each manager
    /// advertises its deployed container types"; §6.2). This is what the
    /// agent routes on.
    pub fn deployed_census(&self) -> HashMap<ContainerId, usize> {
        let mut m = HashMap::new();
        for s in &self.slots {
            if let (Some(c), state) = (s.ctype, s.state) {
                if state != SlotState::Empty {
                    *m.entry(c).or_insert(0) += 1;
                }
            }
        }
        m
    }

    /// Acquire a container of `ctype` for a task. Returns the slot, or
    /// `None` if every slot is busy.
    pub fn acquire(&mut self, ctype: ContainerId, now: Time) -> Option<ContainerSlot> {
        self.acquire_with_origin(ctype, now).map(|(s, _)| s)
    }

    /// Like [`WarmPool::acquire`] but also reports whether the start was
    /// cold (`true`) or reused a warm container (`false`).
    pub fn acquire_with_origin(
        &mut self,
        ctype: ContainerId,
        now: Time,
    ) -> Option<(ContainerSlot, bool)> {
        self.acquire_detailed(ctype, now).map(|o| (o.slot, o.cold))
    }

    /// Full acquisition outcome, including which warm container type was
    /// evicted (if any) — lets callers maintain O(1) incremental views
    /// (the simulator's hot path).
    pub fn acquire_detailed(&mut self, ctype: ContainerId, now: Time) -> Option<Acquire> {
        self.acquire_protected(ctype, now, |_| false)
    }

    /// Like [`WarmPool::acquire_detailed`], but when eviction is needed,
    /// prefer evicting warm containers whose type is NOT `protected`
    /// (types with queued demand are protected so their tasks are not
    /// orphaned — the warming-aware manager's coordination rule).
    pub fn acquire_protected(
        &mut self,
        ctype: ContainerId,
        now: Time,
        protected: impl Fn(ContainerId) -> bool,
    ) -> Option<Acquire> {
        let _ = now;
        // 1. Prefer a warm idle container of the right type (§6.2).
        if let Some(i) = self.slots.iter().position(|s| {
            s.ctype == Some(ctype) && matches!(s.state, SlotState::WarmIdle { .. })
        }) {
            self.slots[i].state = SlotState::Busy;
            self.warm_hits += 1;
            return Some(Acquire { slot: i, cold: false, evicted: None });
        }
        // 2. Otherwise take an empty slot (cold start).
        if let Some(i) = self.slots.iter().position(|s| s.state == SlotState::Empty) {
            self.slots[i] = Slot { ctype: Some(ctype), state: SlotState::Busy };
            self.cold_starts += 1;
            return Some(Acquire { slot: i, cold: true, evicted: None });
        }
        // 3. Otherwise evict the least-recently-used warm idle container
        //    of *any* type ("insufficient resources to process pending
        //    workloads"; §6.1) and cold-start in its place.
        let lru = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match (s.ctype, s.state) {
                (Some(c), SlotState::WarmIdle { since }) => Some((i, since, protected(c))),
                _ => None,
            })
            // Unprotected types first, then least-recently-used.
            .min_by(|a, b| a.2.cmp(&b.2).then(a.1.partial_cmp(&b.1).unwrap()))
            .map(|(i, since, _)| (i, since));
        if let Some((i, _)) = lru {
            self.evictions += 1;
            let evicted = self.slots[i].ctype;
            self.slots[i] = Slot { ctype: Some(ctype), state: SlotState::Busy };
            self.cold_starts += 1;
            return Some(Acquire { slot: i, cold: true, evicted });
        }
        None // all busy
    }

    /// Container type currently hosted in a slot.
    pub fn slot_type(&self, slot: ContainerSlot) -> Option<ContainerId> {
        self.slots[slot].ctype
    }

    /// Pre-warm every slot with containers of the given types,
    /// round-robin (the paper pre-warms all containers for the scaling
    /// runs; §7.2 "We pre-warmed all containers in these experiments").
    pub fn prewarm(&mut self, types: &[ContainerId], now: Time) {
        if types.is_empty() {
            return;
        }
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.state == SlotState::Empty {
                *s = Slot {
                    ctype: Some(types[i % types.len()]),
                    state: SlotState::WarmIdle { since: now },
                };
            }
        }
    }

    /// Mark a slot's task finished; the container stays warm (§6.1).
    pub fn release(&mut self, slot: ContainerSlot, now: Time) {
        let s = &mut self.slots[slot];
        debug_assert_eq!(s.state, SlotState::Busy, "release of non-busy slot");
        s.state = SlotState::WarmIdle { since: now };
    }

    /// Tear down warm containers idle longer than the timeout (§6.1).
    /// Returns how many were reaped.
    pub fn reap_idle(&mut self, now: Time) -> usize {
        let timeout = self.idle_timeout_s;
        let mut reaped = 0;
        for s in &mut self.slots {
            if let SlotState::WarmIdle { since } = s.state {
                if now - since >= timeout {
                    *s = Slot { ctype: None, state: SlotState::Empty };
                    reaped += 1;
                }
            }
        }
        reaped
    }

    /// Fair spawn plan (§6.2 manager side): given the type histogram of
    /// received tasks, distribute the pool capacity proportionally
    /// ("if 30% of the tasks are type A and the manager can spawn at most
    /// 10 containers, spawn 3 of type A"). Largest-remainder rounding so
    /// counts sum to capacity (when demand covers it).
    pub fn fair_spawn_plan(
        capacity: usize,
        demand: &HashMap<ContainerId, usize>,
    ) -> HashMap<ContainerId, usize> {
        let total: usize = demand.values().sum();
        if total == 0 || capacity == 0 {
            return HashMap::new();
        }
        let mut plan: Vec<(ContainerId, usize, f64)> = demand
            .iter()
            .map(|(c, n)| {
                let exact = capacity as f64 * *n as f64 / total as f64;
                // Never plan more containers of a type than its demand.
                let base = (exact.floor() as usize).min(*n);
                (*c, base, exact - exact.floor())
            })
            .collect();
        let assigned: usize = plan.iter().map(|(_, n, _)| n).sum();
        let mut leftover = capacity.saturating_sub(assigned);
        // Hand leftovers to the largest remainders (stable by id for
        // determinism).
        plan.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0 .0.cmp(&b.0 .0)));
        for p in plan.iter_mut() {
            if leftover == 0 {
                break;
            }
            // Never plan more containers of a type than it has demand.
            if p.1 < *demand.get(&p.0).unwrap_or(&0) {
                p.1 += 1;
                leftover -= 1;
            }
        }
        plan.into_iter()
            .filter(|(_, n, _)| *n > 0)
            .map(|(c, n, _)| (c, n))
            .collect()
    }

    pub fn cold_starts(&self) -> u64 {
        self.cold_starts
    }

    pub fn warm_hits(&self) -> u64 {
        self.warm_hits
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ct(i: u128) -> ContainerId {
        ContainerId::from_bits(i)
    }

    #[test]
    fn cold_then_warm() {
        let mut p = WarmPool::new(2, 600.0);
        let (s, cold) = p.acquire_with_origin(ct(1), 0.0).unwrap();
        assert!(cold);
        p.release(s, 1.0);
        let (s2, cold2) = p.acquire_with_origin(ct(1), 2.0).unwrap();
        assert!(!cold2);
        assert_eq!(s, s2);
        assert_eq!(p.cold_starts(), 1);
        assert_eq!(p.warm_hits(), 1);
    }

    #[test]
    fn eviction_when_full() {
        let mut p = WarmPool::new(2, 600.0);
        let a = p.acquire(ct(1), 0.0).unwrap();
        let b = p.acquire(ct(1), 0.0).unwrap();
        p.release(a, 1.0); // idle since 1.0 (LRU)
        p.release(b, 2.0); // idle since 2.0
        // Different type: must evict LRU (slot a).
        let (s, cold) = p.acquire_with_origin(ct(2), 3.0).unwrap();
        assert!(cold);
        assert_eq!(s, a);
        assert_eq!(p.evictions(), 1);
        // One warm type-1 container remains.
        assert_eq!(p.warm_idle_count(ct(1)), 1);
    }

    #[test]
    fn all_busy_returns_none() {
        let mut p = WarmPool::new(1, 600.0);
        p.acquire(ct(1), 0.0).unwrap();
        assert!(p.acquire(ct(1), 0.0).is_none());
        assert!(p.acquire(ct(2), 0.0).is_none());
    }

    #[test]
    fn idle_reaping() {
        let mut p = WarmPool::new(3, 10.0);
        let a = p.acquire(ct(1), 0.0).unwrap();
        let b = p.acquire(ct(2), 0.0).unwrap();
        p.release(a, 0.0);
        p.release(b, 5.0);
        assert_eq!(p.reap_idle(9.9), 0);
        assert_eq!(p.reap_idle(10.0), 1); // a idle 10s
        assert_eq!(p.reap_idle(15.0), 1); // b idle 10s
        assert_eq!(p.total(), 0);
    }

    #[test]
    fn census_and_availability() {
        let mut p = WarmPool::new(4, 600.0);
        let a = p.acquire(ct(1), 0.0).unwrap();
        let _b = p.acquire(ct(2), 0.0).unwrap();
        p.release(a, 1.0);
        let census = p.warm_census();
        assert_eq!(census.get(&ct(1)), Some(&1));
        assert_eq!(census.get(&ct(2)), None); // busy, not idle
        assert_eq!(p.available_slots(), 3); // 2 empty + 1 warm idle
    }

    #[test]
    fn fair_spawn_proportional() {
        // Paper's example: 30% of tasks type A, capacity 10 -> 3 of A.
        let mut demand = HashMap::new();
        demand.insert(ct(1), 30);
        demand.insert(ct(2), 70);
        let plan = WarmPool::fair_spawn_plan(10, &demand);
        assert_eq!(plan.get(&ct(1)), Some(&3));
        assert_eq!(plan.get(&ct(2)), Some(&7));
    }

    #[test]
    fn fair_spawn_rounding_sums_to_capacity() {
        let mut demand = HashMap::new();
        demand.insert(ct(1), 1);
        demand.insert(ct(2), 1);
        demand.insert(ct(3), 1);
        let plan = WarmPool::fair_spawn_plan(10, &demand);
        // Demand (3 tasks) is below capacity; plan can't exceed demand.
        let total: usize = plan.values().sum();
        assert_eq!(total, 3);

        let mut demand = HashMap::new();
        demand.insert(ct(1), 5);
        demand.insert(ct(2), 5);
        demand.insert(ct(3), 5);
        let plan = WarmPool::fair_spawn_plan(10, &demand);
        let total: usize = plan.values().sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn fair_spawn_empty_demand() {
        assert!(WarmPool::fair_spawn_plan(10, &HashMap::new()).is_empty());
    }
}
