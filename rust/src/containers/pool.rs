//! The per-manager warm container pool (§6.1–§6.2 manager side).
//!
//! A manager owns a fixed number of worker slots. Each slot may host a
//! container of some type; the pool keeps finished containers *warm*
//! until capacity pressure or an idle timeout (default 10 min) reaps
//! them. When a task arrives for a type with no warm container, the pool
//! cold-starts one — evicting the least-recently-used idle container of
//! another type if the pool is full.

use std::collections::HashMap;

use crate::common::error::{Error, Result};
use crate::common::ids::ContainerId;
use crate::common::time::Time;

/// EWMA smoothing for the measured start-cost estimate fed back by the
/// executor backend (§6.1 economics: predictive sizing works off what
/// starts *actually* cost here, not the Table-3 prior).
const START_COST_ALPHA: f64 = 0.3;

/// Slot index within a manager.
pub type ContainerSlot = usize;

/// Outcome of a container acquisition.
#[derive(Clone, Copy, Debug)]
pub struct Acquire {
    pub slot: ContainerSlot,
    pub cold: bool,
    /// Warm container type evicted to make room, if any.
    pub evicted: Option<ContainerId>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SlotState {
    /// No container in this slot.
    Empty,
    /// Container warm and idle since the given time.
    WarmIdle { since: Time },
    /// Container executing a task.
    Busy,
}

#[derive(Clone, Copy, Debug)]
struct Slot {
    ctype: Option<ContainerId>,
    state: SlotState,
    /// Outstanding task leases while busy: batched dispatch claims K
    /// queued tasks for one slot ([`WarmPool::add_lease`]) and releases
    /// one lease per completed task; the slot turns warm-idle only when
    /// the last lease is released.
    leases: usize,
}

/// Warm-container bookkeeping for one manager.
#[derive(Clone, Debug)]
pub struct WarmPool {
    slots: Vec<Slot>,
    idle_timeout_s: f64,
    cold_starts: u64,
    warm_hits: u64,
    evictions: u64,
    /// Releases of non-busy/out-of-range slots refused (would have
    /// minted typeless "warm" zombies; see [`WarmPool::release`]).
    bad_releases: u64,
    /// Slots warmed ahead of demand ([`WarmPool::prewarm`] /
    /// [`WarmPool::warm_slot`]).
    prewarmed: u64,
    /// EWMA of start costs reported by the executor backend (seconds);
    /// `None` until the first cold start is observed.
    start_cost_ewma: Option<f64>,
}

impl WarmPool {
    pub fn new(capacity: usize, idle_timeout_s: f64) -> Self {
        WarmPool {
            slots: vec![Slot { ctype: None, state: SlotState::Empty, leases: 0 }; capacity],
            idle_timeout_s,
            cold_starts: 0,
            warm_hits: 0,
            evictions: 0,
            bad_releases: 0,
            prewarmed: 0,
            start_cost_ewma: None,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of non-empty slots.
    pub fn total(&self) -> usize {
        self.slots.iter().filter(|s| s.state != SlotState::Empty).count()
    }

    /// Warm idle containers of the given type.
    pub fn warm_idle_count(&self, ctype: ContainerId) -> usize {
        self.slots
            .iter()
            .filter(|s| {
                s.ctype == Some(ctype) && matches!(s.state, SlotState::WarmIdle { .. })
            })
            .count()
    }

    /// All currently-busy slots.
    pub fn busy_slots(&self) -> Vec<ContainerSlot> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state == SlotState::Busy)
            .map(|(i, _)| i)
            .collect()
    }

    /// Idle (warm) + empty slots — the capacity advertised to the agent.
    pub fn available_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.state != SlotState::Busy).count()
    }

    /// Warm-idle census by type.
    pub fn warm_census(&self) -> HashMap<ContainerId, usize> {
        let mut m = HashMap::new();
        for s in &self.slots {
            if let (Some(c), SlotState::WarmIdle { .. }) = (s.ctype, s.state) {
                *m.entry(c).or_insert(0) += 1;
            }
        }
        m
    }

    /// Deployed-container census by type — busy AND idle ("Each manager
    /// advertises its deployed container types"; §6.2). This is what the
    /// agent routes on.
    pub fn deployed_census(&self) -> HashMap<ContainerId, usize> {
        let mut m = HashMap::new();
        for s in &self.slots {
            if let (Some(c), state) = (s.ctype, s.state) {
                if state != SlotState::Empty {
                    *m.entry(c).or_insert(0) += 1;
                }
            }
        }
        m
    }

    /// Acquire a container of `ctype` for a task. Returns the slot, or
    /// `None` if every slot is busy.
    pub fn acquire(&mut self, ctype: ContainerId, now: Time) -> Option<ContainerSlot> {
        self.acquire_with_origin(ctype, now).map(|(s, _)| s)
    }

    /// Like [`WarmPool::acquire`] but also reports whether the start was
    /// cold (`true`) or reused a warm container (`false`).
    pub fn acquire_with_origin(
        &mut self,
        ctype: ContainerId,
        now: Time,
    ) -> Option<(ContainerSlot, bool)> {
        self.acquire_detailed(ctype, now).map(|o| (o.slot, o.cold))
    }

    /// Full acquisition outcome, including which warm container type was
    /// evicted (if any) — lets callers maintain O(1) incremental views
    /// (the simulator's hot path).
    pub fn acquire_detailed(&mut self, ctype: ContainerId, now: Time) -> Option<Acquire> {
        self.acquire_protected(ctype, now, |_| false)
    }

    /// Like [`WarmPool::acquire_detailed`], but when eviction is needed,
    /// prefer evicting warm containers whose type is NOT `protected`
    /// (types with queued demand are protected so their tasks are not
    /// orphaned — the warming-aware manager's coordination rule).
    pub fn acquire_protected(
        &mut self,
        ctype: ContainerId,
        now: Time,
        protected: impl Fn(ContainerId) -> bool,
    ) -> Option<Acquire> {
        let _ = now;
        // 1. Prefer a warm idle container of the right type (§6.2).
        if let Some(i) = self.slots.iter().position(|s| {
            s.ctype == Some(ctype) && matches!(s.state, SlotState::WarmIdle { .. })
        }) {
            self.slots[i].state = SlotState::Busy;
            self.slots[i].leases = 1;
            self.warm_hits += 1;
            return Some(Acquire { slot: i, cold: false, evicted: None });
        }
        // 2. Otherwise take an empty slot (cold start).
        if let Some(i) = self.slots.iter().position(|s| s.state == SlotState::Empty) {
            self.slots[i] = Slot { ctype: Some(ctype), state: SlotState::Busy, leases: 1 };
            self.cold_starts += 1;
            return Some(Acquire { slot: i, cold: true, evicted: None });
        }
        // 3. Otherwise evict the least-recently-used warm idle container
        //    of *any* type ("insufficient resources to process pending
        //    workloads"; §6.1) and cold-start in its place.
        let lru = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match (s.ctype, s.state) {
                (Some(c), SlotState::WarmIdle { since }) => Some((i, since, protected(c))),
                _ => None,
            })
            // Unprotected types first, then least-recently-used.
            // total_cmp: a NaN idle timestamp must not panic the worker
            // holding the pool lock (it orders last instead).
            .min_by(|a, b| a.2.cmp(&b.2).then(a.1.total_cmp(&b.1)))
            .map(|(i, since, _)| (i, since));
        if let Some((i, _)) = lru {
            self.evictions += 1;
            let evicted = self.slots[i].ctype;
            self.slots[i] = Slot { ctype: Some(ctype), state: SlotState::Busy, leases: 1 };
            self.cold_starts += 1;
            return Some(Acquire { slot: i, cold: true, evicted });
        }
        None // all busy
    }

    /// Container type currently hosted in a slot.
    pub fn slot_type(&self, slot: ContainerSlot) -> Option<ContainerId> {
        self.slots[slot].ctype
    }

    /// Pre-warm every slot with containers of the given types,
    /// round-robin (the paper pre-warms all containers for the scaling
    /// runs; §7.2 "We pre-warmed all containers in these experiments").
    /// Round-robin is over the *filled count*, not the absolute slot
    /// index: indexing by slot position skewed the type mix whenever
    /// the pool was partially occupied (busy slots skipped a type's
    /// turn without consuming it).
    pub fn prewarm(&mut self, types: &[ContainerId], now: Time) {
        if types.is_empty() {
            return;
        }
        let mut filled = 0usize;
        for s in self.slots.iter_mut() {
            if s.state == SlotState::Empty {
                *s = Slot {
                    ctype: Some(types[filled % types.len()]),
                    state: SlotState::WarmIdle { since: now },
                    leases: 0,
                };
                filled += 1;
            }
        }
        self.prewarmed += filled as u64;
    }

    /// Warm one empty slot with `ctype` ahead of demand (predictive
    /// prewarm). Returns the slot, or `None` when no slot is empty.
    pub fn warm_slot(&mut self, ctype: ContainerId, now: Time) -> Option<ContainerSlot> {
        let i = self.slots.iter().position(|s| s.state == SlotState::Empty)?;
        self.slots[i] =
            Slot { ctype: Some(ctype), state: SlotState::WarmIdle { since: now }, leases: 0 };
        self.prewarmed += 1;
        Some(i)
    }

    /// Empty a slot without counting an eviction — the undo for a
    /// [`WarmPool::warm_slot`] / cold acquire whose backend start
    /// failed (the slot never actually hosted a container).
    pub fn vacate(&mut self, slot: ContainerSlot) {
        if let Some(s) = self.slots.get_mut(slot) {
            *s = Slot { ctype: None, state: SlotState::Empty, leases: 0 };
        }
    }

    /// Stack one more task lease onto an already-busy slot: batched
    /// dispatch claims several queued tasks for one slot and flushes
    /// them down the backend's pipeline, releasing one lease per
    /// completed task. Leasing a non-busy or out-of-range slot is a
    /// typed refusal — the pool's state machine only pipelines on top
    /// of a legitimately acquired slot.
    pub fn add_lease(&mut self, slot: ContainerSlot) -> Result<()> {
        match self.slots.get_mut(slot) {
            Some(s) if s.state == SlotState::Busy => {
                s.leases += 1;
                Ok(())
            }
            Some(s) => Err(Error::InvalidArgument(format!(
                "lease on non-busy slot {slot} (state {:?})",
                s.state
            ))),
            None => Err(Error::InvalidArgument(format!(
                "lease on out-of-range slot {slot} (capacity {})",
                self.slots.len()
            ))),
        }
    }

    /// Outstanding task leases on a slot (0 when idle or empty).
    pub fn slot_leases(&self, slot: ContainerSlot) -> usize {
        self.slots.get(slot).map_or(0, |s| s.leases)
    }

    /// Mark one of a slot's tasks finished (drop one lease); the
    /// container turns warm-idle when its last lease is released (§6.1).
    ///
    /// Releasing a slot that is not busy is a hard, typed error — the
    /// seed's `debug_assert_eq!` compiled out in release builds, so a
    /// double release (or a stale slot index) silently overwrote an
    /// `Empty` slot with `WarmIdle`, minting a typeless "warm" zombie
    /// that matched no acquire and pinned a capacity slot forever. The
    /// state is left untouched and the refusal counted.
    pub fn release(&mut self, slot: ContainerSlot, now: Time) -> Result<()> {
        match self.slots.get_mut(slot) {
            Some(s) if s.state == SlotState::Busy => {
                s.leases = s.leases.saturating_sub(1);
                if s.leases == 0 {
                    s.state = SlotState::WarmIdle { since: now };
                }
                Ok(())
            }
            Some(s) => {
                self.bad_releases += 1;
                Err(Error::InvalidArgument(format!(
                    "release of non-busy slot {slot} (state {:?})",
                    s.state
                )))
            }
            None => {
                self.bad_releases += 1;
                Err(Error::InvalidArgument(format!(
                    "release of out-of-range slot {slot} (capacity {})",
                    self.slots.len()
                )))
            }
        }
    }

    /// Tear down warm containers idle longer than the timeout (§6.1).
    /// Returns how many were reaped.
    pub fn reap_idle(&mut self, now: Time) -> usize {
        self.reap_idle_slots(now).len()
    }

    /// Like [`WarmPool::reap_idle`], but reports which slots (and
    /// container types) were torn down so an executor backend can stop
    /// the processes behind them.
    pub fn reap_idle_slots(&mut self, now: Time) -> Vec<(ContainerSlot, ContainerId)> {
        let timeout = self.idle_timeout_s;
        let mut reaped = Vec::new();
        for (i, s) in self.slots.iter_mut().enumerate() {
            if let (Some(c), SlotState::WarmIdle { since }) = (s.ctype, s.state) {
                if now - since >= timeout {
                    *s = Slot { ctype: None, state: SlotState::Empty, leases: 0 };
                    reaped.push((i, c));
                }
            }
        }
        reaped
    }

    /// Predictive reap (the scale-in half of EWMA pool sizing): tear
    /// down warm-idle containers *in excess of the per-type floor*,
    /// oldest first, keeping anything idle for less than `grace_s`
    /// (protects just-released containers from flapping). Types absent
    /// from `floors` have floor 0. Returns the reaped slots so the
    /// executor backend can stop their processes.
    pub fn reap_excess(
        &mut self,
        floors: &HashMap<ContainerId, usize>,
        grace_s: f64,
        now: Time,
    ) -> Vec<(ContainerSlot, ContainerId)> {
        // Oldest-first per type: collect idle slots, sort by since.
        let mut idle: Vec<(ContainerSlot, ContainerId, Time)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match (s.ctype, s.state) {
                (Some(c), SlotState::WarmIdle { since }) if now - since >= grace_s => {
                    Some((i, c, since))
                }
                _ => None,
            })
            .collect();
        idle.sort_by(|a, b| a.2.total_cmp(&b.2));
        let mut keep: HashMap<ContainerId, usize> = HashMap::new();
        for s in &self.slots {
            if let (Some(c), SlotState::WarmIdle { .. }) = (s.ctype, s.state) {
                *keep.entry(c).or_insert(0) += 1;
            }
        }
        let mut reaped = Vec::new();
        for (i, c, _) in idle {
            let floor = floors.get(&c).copied().unwrap_or(0);
            let have = keep.get(&c).copied().unwrap_or(0);
            if have > floor {
                self.slots[i] = Slot { ctype: None, state: SlotState::Empty, leases: 0 };
                *keep.get_mut(&c).unwrap() -= 1;
                reaped.push((i, c));
            }
        }
        reaped
    }

    /// Fold a measured (or charged) start cost into the pool's EWMA.
    pub fn note_start_cost(&mut self, seconds: f64) {
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        self.start_cost_ewma = Some(match self.start_cost_ewma {
            Some(prev) => prev + START_COST_ALPHA * (seconds - prev),
            None => seconds,
        });
    }

    /// Smoothed observed start cost, once at least one start was noted.
    pub fn start_cost_estimate(&self) -> Option<f64> {
        self.start_cost_ewma
    }

    /// Fair spawn plan (§6.2 manager side): given the type histogram of
    /// received tasks, distribute the pool capacity proportionally
    /// ("if 30% of the tasks are type A and the manager can spawn at most
    /// 10 containers, spawn 3 of type A"). Largest-remainder rounding so
    /// counts sum to capacity (when demand covers it).
    pub fn fair_spawn_plan(
        capacity: usize,
        demand: &HashMap<ContainerId, usize>,
    ) -> HashMap<ContainerId, usize> {
        let total: usize = demand.values().sum();
        if total == 0 || capacity == 0 {
            return HashMap::new();
        }
        let mut plan: Vec<(ContainerId, usize, f64)> = demand
            .iter()
            .map(|(c, n)| {
                let exact = capacity as f64 * *n as f64 / total as f64;
                // Never plan more containers of a type than its demand.
                let base = (exact.floor() as usize).min(*n);
                (*c, base, exact - exact.floor())
            })
            .collect();
        let assigned: usize = plan.iter().map(|(_, n, _)| n).sum();
        let mut leftover = capacity.saturating_sub(assigned);
        // Hand leftovers to the largest remainders (stable by id for
        // determinism; total_cmp so a NaN remainder cannot panic).
        // Loop until nothing is eligible: a single pass hands each type
        // at most +1, stranding capacity whenever a high-remainder type
        // is demand-capped while another type still has headroom.
        plan.sort_by(|a, b| b.2.total_cmp(&a.2).then(a.0 .0.cmp(&b.0 .0)));
        while leftover > 0 {
            let mut gave = false;
            for p in plan.iter_mut() {
                if leftover == 0 {
                    break;
                }
                // Never plan more containers of a type than it has demand.
                if p.1 < *demand.get(&p.0).unwrap_or(&0) {
                    p.1 += 1;
                    leftover -= 1;
                    gave = true;
                }
            }
            if !gave {
                break; // every type demand-capped; remaining capacity unusable
            }
        }
        plan.into_iter()
            .filter(|(_, n, _)| *n > 0)
            .map(|(c, n, _)| (c, n))
            .collect()
    }

    pub fn cold_starts(&self) -> u64 {
        self.cold_starts
    }

    pub fn warm_hits(&self) -> u64 {
        self.warm_hits
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn bad_releases(&self) -> u64 {
        self.bad_releases
    }

    pub fn prewarmed(&self) -> u64 {
        self.prewarmed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ct(i: u128) -> ContainerId {
        ContainerId::from_bits(i)
    }

    #[test]
    fn cold_then_warm() {
        let mut p = WarmPool::new(2, 600.0);
        let (s, cold) = p.acquire_with_origin(ct(1), 0.0).unwrap();
        assert!(cold);
        p.release(s, 1.0).unwrap();
        let (s2, cold2) = p.acquire_with_origin(ct(1), 2.0).unwrap();
        assert!(!cold2);
        assert_eq!(s, s2);
        assert_eq!(p.cold_starts(), 1);
        assert_eq!(p.warm_hits(), 1);
    }

    #[test]
    fn eviction_when_full() {
        let mut p = WarmPool::new(2, 600.0);
        let a = p.acquire(ct(1), 0.0).unwrap();
        let b = p.acquire(ct(1), 0.0).unwrap();
        p.release(a, 1.0).unwrap(); // idle since 1.0 (LRU)
        p.release(b, 2.0).unwrap(); // idle since 2.0
        // Different type: must evict LRU (slot a).
        let (s, cold) = p.acquire_with_origin(ct(2), 3.0).unwrap();
        assert!(cold);
        assert_eq!(s, a);
        assert_eq!(p.evictions(), 1);
        // One warm type-1 container remains.
        assert_eq!(p.warm_idle_count(ct(1)), 1);
    }

    #[test]
    fn all_busy_returns_none() {
        let mut p = WarmPool::new(1, 600.0);
        p.acquire(ct(1), 0.0).unwrap();
        assert!(p.acquire(ct(1), 0.0).is_none());
        assert!(p.acquire(ct(2), 0.0).is_none());
    }

    #[test]
    fn idle_reaping() {
        let mut p = WarmPool::new(3, 10.0);
        let a = p.acquire(ct(1), 0.0).unwrap();
        let b = p.acquire(ct(2), 0.0).unwrap();
        p.release(a, 0.0).unwrap();
        p.release(b, 5.0).unwrap();
        assert_eq!(p.reap_idle(9.9), 0);
        assert_eq!(p.reap_idle(10.0), 1); // a idle 10s
        assert_eq!(p.reap_idle(15.0), 1); // b idle 10s
        assert_eq!(p.total(), 0);
    }

    #[test]
    fn census_and_availability() {
        let mut p = WarmPool::new(4, 600.0);
        let a = p.acquire(ct(1), 0.0).unwrap();
        let _b = p.acquire(ct(2), 0.0).unwrap();
        p.release(a, 1.0).unwrap();
        let census = p.warm_census();
        assert_eq!(census.get(&ct(1)), Some(&1));
        assert_eq!(census.get(&ct(2)), None); // busy, not idle
        assert_eq!(p.available_slots(), 3); // 2 empty + 1 warm idle
    }

    #[test]
    fn fair_spawn_proportional() {
        // Paper's example: 30% of tasks type A, capacity 10 -> 3 of A.
        let mut demand = HashMap::new();
        demand.insert(ct(1), 30);
        demand.insert(ct(2), 70);
        let plan = WarmPool::fair_spawn_plan(10, &demand);
        assert_eq!(plan.get(&ct(1)), Some(&3));
        assert_eq!(plan.get(&ct(2)), Some(&7));
    }

    #[test]
    fn fair_spawn_rounding_sums_to_capacity() {
        let mut demand = HashMap::new();
        demand.insert(ct(1), 1);
        demand.insert(ct(2), 1);
        demand.insert(ct(3), 1);
        let plan = WarmPool::fair_spawn_plan(10, &demand);
        // Demand (3 tasks) is below capacity; plan can't exceed demand.
        let total: usize = plan.values().sum();
        assert_eq!(total, 3);

        let mut demand = HashMap::new();
        demand.insert(ct(1), 5);
        demand.insert(ct(2), 5);
        demand.insert(ct(3), 5);
        let plan = WarmPool::fair_spawn_plan(10, &demand);
        let total: usize = plan.values().sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn fair_spawn_empty_demand() {
        assert!(WarmPool::fair_spawn_plan(10, &HashMap::new()).is_empty());
    }

    /// The leftover loop invariant: the plan always totals
    /// `min(capacity, total demand)` — no capacity stranded while some
    /// type still has unmet demand — and never over-plans any type.
    #[test]
    fn fair_spawn_never_strands_capacity() {
        let mut g = crate::testing::Gen::new(11);
        for _ in 0..500 {
            let capacity = g.usize(0, 40);
            let ntypes = g.usize(1, 6);
            let mut demand = HashMap::new();
            for i in 0..ntypes {
                demand.insert(ct(i as u128 + 1), g.usize(0, 30));
            }
            let total: usize = demand.values().sum();
            let plan = WarmPool::fair_spawn_plan(capacity, &demand);
            let planned: usize = plan.values().sum();
            assert_eq!(
                planned,
                capacity.min(total),
                "stranded capacity: cap={capacity} demand={demand:?} plan={plan:?}"
            );
            for (c, n) in &plan {
                assert!(n <= demand.get(c).unwrap(), "over-planned {c:?}");
            }
        }
    }

    /// Release of a non-busy or out-of-range slot is a typed error that
    /// leaves the pool untouched (no typeless "warm" zombie) and counts
    /// the refusal; a legal release still works afterwards.
    #[test]
    fn bad_release_is_typed_and_harmless() {
        let mut p = WarmPool::new(2, 600.0);
        // Empty slot: refused.
        assert!(p.release(0, 1.0).is_err());
        assert_eq!(p.total(), 0, "refused release must not mint a warm slot");
        // Out of range: refused, no panic.
        assert!(p.release(7, 1.0).is_err());
        // Double release: first ok, second refused.
        let s = p.acquire(ct(1), 0.0).unwrap();
        p.release(s, 1.0).unwrap();
        let err = p.release(s, 2.0).unwrap_err();
        assert_eq!(err.kind(), "InvalidArgument");
        assert_eq!(p.bad_releases(), 3);
        assert_eq!(p.warm_idle_count(ct(1)), 1, "state unchanged by bad releases");
        // The pool still works.
        let (s2, cold) = p.acquire_with_origin(ct(1), 3.0).unwrap();
        assert!(!cold);
        p.release(s2, 4.0).unwrap();
    }

    /// Prewarm round-robins over the *filled count*: with busy slots in
    /// the way, the absolute-index version skewed the type mix (e.g.
    /// busy slots 0 and 2 left types [a, b] warming as [b, b]).
    #[test]
    fn prewarm_balances_types_in_partially_busy_pool() {
        let mut p = WarmPool::new(4, 600.0);
        // Occupy slots 0 and 2, leaving 1 and 3 empty (acquire fills
        // lowest empty first; vacate empties slot 1 again).
        let _s0 = p.acquire(ct(9), 0.0).unwrap();
        let s1 = p.acquire(ct(9), 0.0).unwrap();
        let _s2 = p.acquire(ct(9), 0.0).unwrap();
        p.release(s1, 0.5).unwrap();
        p.vacate(s1);
        // Empty slots are 1 and 3 — both odd. The absolute-index
        // round-robin warmed types[1] twice ([b, b]); filled-count
        // round-robin warms [a, b].
        p.prewarm(&[ct(1), ct(2)], 1.0);
        assert_eq!(p.warm_idle_count(ct(1)), 1, "first empty slot warms type 1");
        assert_eq!(p.warm_idle_count(ct(2)), 1, "second empty slot warms type 2");
        assert!(p.prewarmed() >= 2);
    }

    /// Lease stacking (batched dispatch): K leases keep the slot busy
    /// through K-1 releases, the last release turns it warm-idle, and
    /// leasing non-busy or out-of-range slots is a typed refusal.
    #[test]
    fn lease_stacking_keeps_slot_busy_until_last_release() {
        let mut p = WarmPool::new(1, 600.0);
        let s = p.acquire(ct(1), 0.0).unwrap();
        assert_eq!(p.slot_leases(s), 1, "acquire grants the first lease");
        p.add_lease(s).unwrap();
        p.add_lease(s).unwrap();
        assert_eq!(p.slot_leases(s), 3);
        p.release(s, 1.0).unwrap();
        p.release(s, 1.1).unwrap();
        assert_eq!(p.busy_slots(), vec![s], "still busy with one lease left");
        assert!(p.acquire(ct(1), 1.2).is_none(), "leased slot is not acquirable");
        p.release(s, 1.3).unwrap();
        assert_eq!(p.warm_idle_count(ct(1)), 1, "last release turns warm-idle");
        assert_eq!(p.slot_leases(s), 0);
        // A fourth release is a bad release, exactly as before leases.
        assert!(p.release(s, 1.4).is_err());
        // Leases only stack on busy slots.
        assert_eq!(p.add_lease(s).unwrap_err().kind(), "InvalidArgument");
        assert_eq!(p.add_lease(9).unwrap_err().kind(), "InvalidArgument");
        // Vacate clears leases outright.
        let s = p.acquire(ct(1), 2.0).unwrap();
        p.add_lease(s).unwrap();
        p.vacate(s);
        assert_eq!(p.slot_leases(s), 0);
    }

    #[test]
    fn warm_slot_and_vacate() {
        let mut p = WarmPool::new(2, 600.0);
        let s = p.warm_slot(ct(1), 0.0).unwrap();
        assert_eq!(p.warm_idle_count(ct(1)), 1);
        // A warm acquire hits the prewarmed slot.
        let (s2, cold) = p.acquire_with_origin(ct(1), 1.0).unwrap();
        assert!(!cold);
        assert_eq!(s, s2);
        p.release(s2, 2.0).unwrap();
        p.vacate(s2);
        assert_eq!(p.total(), 0);
        // Full pool: no empty slot to warm.
        let _a = p.acquire(ct(3), 3.0).unwrap();
        let _b = p.acquire(ct(3), 3.0).unwrap();
        assert!(p.warm_slot(ct(1), 3.0).is_none());
    }

    /// Predictive reap: warm-idle beyond the per-type floor is torn
    /// down oldest-first; the floor and anything inside the grace
    /// window survive.
    #[test]
    fn reap_excess_respects_floors_and_grace() {
        let mut p = WarmPool::new(6, 600.0);
        // Four type-1 containers idle since 0, 2, 3, 4 (acquire all
        // first so each lands in its own slot).
        let slots: Vec<_> = (0..4).map(|_| p.acquire(ct(1), 0.0).unwrap()).collect();
        for (i, s) in slots.iter().enumerate() {
            let since = if i == 0 { 0.0 } else { (i + 1) as f64 };
            p.release(*s, since).unwrap();
        }
        let s = p.acquire(ct(2), 0.0).unwrap();
        p.release(s, 2.0).unwrap();
        let mut floors = HashMap::new();
        floors.insert(ct(1), 2);
        floors.insert(ct(2), 1);
        // Grace 5s at now=6: slots idle since >1 are protected.
        let reaped = p.reap_excess(&floors, 5.0, 6.0);
        assert_eq!(reaped.len(), 1, "only the oldest excess slot is past grace");
        assert_eq!(p.warm_idle_count(ct(1)), 3);
        // No grace: reap down to the floors exactly, oldest first.
        let reaped = p.reap_excess(&floors, 0.0, 6.0);
        assert_eq!(reaped.len(), 1);
        assert_eq!(p.warm_idle_count(ct(1)), 2);
        assert_eq!(p.warm_idle_count(ct(2)), 1);
        // Types with no floor entry reap to zero.
        let reaped = p.reap_excess(&HashMap::new(), 0.0, 7.0);
        assert_eq!(reaped.len(), 3);
        assert_eq!(p.total(), 0);
    }

    #[test]
    fn start_cost_ewma_tracks_measured_costs() {
        let mut p = WarmPool::new(2, 600.0);
        assert!(p.start_cost_estimate().is_none());
        p.note_start_cost(1.0);
        assert_eq!(p.start_cost_estimate(), Some(1.0));
        p.note_start_cost(2.0);
        let e = p.start_cost_estimate().unwrap();
        assert!(e > 1.0 && e < 2.0, "EWMA between old and new: {e}");
        // Garbage is ignored.
        p.note_start_cost(f64::NAN);
        p.note_start_cost(-1.0);
        assert_eq!(p.start_cost_estimate(), Some(e));
    }
}
