//! Container technologies and the Table-3 instantiation cost models.

use crate::common::rng::Rng;

/// Supported container technologies (§4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ContainerTech {
    /// Local/cloud deployments.
    Docker,
    /// HPC; supported at ALCF (Theta).
    Singularity,
    /// HPC; supported at NERSC (Cori).
    Shifter,
    /// Bare worker environment (no container registered).
    None,
}

impl ContainerTech {
    pub fn name(&self) -> &'static str {
        match self {
            ContainerTech::Docker => "docker",
            ContainerTech::Singularity => "singularity",
            ContainerTech::Shifter => "shifter",
            ContainerTech::None => "none",
        }
    }
}

/// Host-system profiles used in the evaluation (§7.2, §7.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SystemProfile {
    /// ANL Theta: KNL nodes, slow cores, Lustre contention.
    Theta,
    /// NERSC Cori: KNL partition, Shifter.
    Cori,
    /// AWS EC2 m5.large.
    Ec2,
    /// Generic laptop/local host (fast, no contention).
    Local,
}

impl SystemProfile {
    pub fn name(&self) -> &'static str {
        match self {
            SystemProfile::Theta => "theta",
            SystemProfile::Cori => "cori",
            SystemProfile::Ec2 => "ec2",
            SystemProfile::Local => "local",
        }
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf
/// approximation (max abs error ~1.5e-7 — far inside the 2% tolerance
/// the sample-mean pin demands).
fn normal_cdf(x: f64) -> f64 {
    let z = x / std::f64::consts::SQRT_2;
    let sign = if z < 0.0 { -1.0 } else { 1.0 };
    let z = z.abs();
    let t = 1.0 / (1.0 + 0.3275911 * z);
    // Horner evaluation of the A&S degree-5 polynomial in t.
    let mut poly = 1.061405429;
    for c in [-1.453152027, 1.421413741, -0.284496736, 0.254829592] {
        poly = poly * t + c;
    }
    let erf = 1.0 - poly * t * (-z * z).exp();
    0.5 * (1.0 + sign * erf)
}

/// Mean of `LogNormal(mu, sigma)` conditioned on the draw being ≤ `cap`
/// (the closed-form truncated-log-normal mean).
fn truncated_lognormal_mean(mu: f64, sigma: f64, cap: f64) -> f64 {
    let a = (cap.ln() - mu) / sigma;
    let denom = normal_cdf(a);
    if denom <= 0.0 {
        return cap; // whole mass above the cap; conditional mean → cap
    }
    (mu + sigma * sigma / 2.0).exp() * normal_cdf(a - sigma) / denom
}

/// Cold-start cost model for one (system, tech) pair, parameterised to
/// reproduce Table 3's min/max/mean. We sample a shifted log-normal:
/// `start = min + LogNormal(mu, sigma)` truncated at `max` by
/// resampling, with (mu, sigma) fitted so the *truncated* mean lands on
/// the paper's mean — the naive `mu = ln(excess) - sigma²/2` fit targets
/// the untruncated mean, so any truncation (clamping worst of all, with
/// its point mass at `max`) drags the sample mean below Table 3.
#[derive(Clone, Copy, Debug)]
pub struct StartCostModel {
    pub system: SystemProfile,
    pub tech: ContainerTech,
    pub min_s: f64,
    pub max_s: f64,
    pub mean_s: f64,
    mu: f64,
    sigma: f64,
}

impl StartCostModel {
    pub fn new(
        system: SystemProfile,
        tech: ContainerTech,
        min_s: f64,
        max_s: f64,
        mean_s: f64,
    ) -> Self {
        // Fit: excess = mean - min is the target mean of the log-normal
        // part. Pick sigma from the spread (max - min vs mean - min),
        // then solve mu by bisection so the mean *conditioned on the
        // draw fitting under max - min* equals excess. The conditional
        // mean is continuous and strictly increasing in mu, from 0
        // (mu → -∞) to cap (mu → +∞), and excess < cap, so a root
        // exists and bisection converges.
        let excess = (mean_s - min_s).max(1e-6);
        let cap = (max_s - min_s).max(excess * 1.01);
        let spread = (cap / excess).max(1.5);
        let sigma = (spread.ln() / 2.0).clamp(0.2, 1.2);
        let mut lo = excess.ln() - sigma * sigma / 2.0 - 4.0;
        let mut hi = cap.ln() + 4.0 * sigma;
        for _ in 0..96 {
            let mid = 0.5 * (lo + hi);
            if truncated_lognormal_mean(mid, sigma, cap) < excess {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let mu = 0.5 * (lo + hi);
        StartCostModel { system, tech, min_s, max_s, mean_s, mu, sigma }
    }

    /// Sample one cold-start duration. Draws above `max_s` are
    /// resampled (bounded retries) rather than clamped: clamping puts a
    /// point mass at the max, which together with the untruncated fit
    /// biased the sample mean below the Table-3 mean it claims to
    /// reproduce. The retry bound keeps sampling O(1); with the
    /// bisection fit the per-draw rejection probability is ~1%, so the
    /// clamp fallback is ~1e-32 and statistically invisible.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let cap = self.max_s - self.min_s;
        for _ in 0..16 {
            let v = rng.lognormal(self.mu, self.sigma);
            if v <= cap {
                return self.min_s + v;
            }
        }
        self.max_s
    }

    /// Deterministic expected value (used by analytic estimates).
    pub fn mean(&self) -> f64 {
        self.mean_s
    }
}

/// Table 3 of the paper, verbatim.
pub const TABLE3_ROWS: [(SystemProfile, ContainerTech, f64, f64, f64); 4] = [
    (SystemProfile::Theta, ContainerTech::Singularity, 9.83, 14.06, 10.40),
    (SystemProfile::Cori, ContainerTech::Shifter, 7.25, 31.26, 8.49),
    (SystemProfile::Ec2, ContainerTech::Docker, 1.74, 1.88, 1.79),
    (SystemProfile::Ec2, ContainerTech::Singularity, 1.19, 1.26, 1.22),
];

/// Pre-fit models for every Table-3 row.
pub struct Table3Models;

#[allow(non_upper_case_globals)]
pub static TABLE3_MODELS: Table3Models = Table3Models;

impl Table3Models {
    /// Model for a (system, tech) pair; rows not in Table 3 fall back to
    /// a fast local profile (0.05–0.3 s — warm python env spawn).
    pub fn lookup(&self, system: SystemProfile, tech: ContainerTech) -> StartCostModel {
        for (s, t, min, max, mean) in TABLE3_ROWS {
            if s == system && t == tech {
                return StartCostModel::new(s, t, min, max, mean);
            }
        }
        // Local bare-process model.
        StartCostModel::new(system, tech, 0.05, 0.30, 0.10)
    }

    pub fn all(&self) -> Vec<StartCostModel> {
        TABLE3_ROWS
            .iter()
            .map(|(s, t, min, max, mean)| StartCostModel::new(*s, *t, *min, *max, *mean))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_samples_within_bounds() {
        let mut rng = Rng::new(1);
        for m in TABLE3_MODELS.all() {
            for _ in 0..2000 {
                let s = m.sample(&mut rng);
                assert!(
                    s >= m.min_s && s <= m.max_s,
                    "{:?}/{:?}: sample {s} outside [{}, {}]",
                    m.system,
                    m.tech,
                    m.min_s,
                    m.max_s
                );
            }
        }
    }

    #[test]
    fn table3_sample_means_close_to_paper() {
        let mut rng = Rng::new(7);
        for m in TABLE3_MODELS.all() {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| m.sample(&mut rng)).sum::<f64>() / n as f64;
            let rel = (mean - m.mean_s).abs() / m.mean_s;
            assert!(
                rel < 0.10,
                "{:?}/{:?}: sample mean {mean:.3} vs paper {:.3} (rel {rel:.3})",
                m.system,
                m.tech,
                m.mean_s
            );
        }
    }

    /// The truncation-bias pin: with the resample-above-max sampler and
    /// the bisection fit of `mu` against the *truncated* mean, 10k
    /// samples land within 2% of the paper's mean for every Table-3
    /// row. (The old clamp-at-max sampler put a point mass at `max_s`
    /// while `mu` was fitted to the untruncated mean, dragging e.g. the
    /// Cori/Shifter sample mean several percent below 8.49 s.)
    #[test]
    fn table3_sample_means_within_two_percent() {
        for (seed, m) in TABLE3_MODELS.all().into_iter().enumerate() {
            let mut rng = Rng::new(0xC0FFEE ^ seed as u64);
            let n = 10_000;
            let mean: f64 = (0..n).map(|_| m.sample(&mut rng)).sum::<f64>() / n as f64;
            let rel = (mean - m.mean_s).abs() / m.mean_s;
            let sys = m.system.name();
            let tech = m.tech.name();
            let paper = m.mean_s;
            assert!(rel < 0.02, "{sys}/{tech}: mean {mean:.4} vs {paper:.4} (rel {rel:.4})");
        }
    }

    /// The analytic fit itself: the closed-form truncated mean at the
    /// fitted (mu, sigma) reproduces `mean_s - min_s` almost exactly,
    /// independent of sampling noise.
    #[test]
    fn truncated_fit_matches_target_mean() {
        for m in TABLE3_MODELS.all() {
            let cap = m.max_s - m.min_s;
            let got = truncated_lognormal_mean(m.mu, m.sigma, cap);
            let want = m.mean_s - m.min_s;
            let rel = (got - want).abs() / want;
            let sys = m.system.name();
            let tech = m.tech.name();
            assert!(rel < 1e-6, "{sys}/{tech}: truncated mean {got} vs target {want}");
        }
    }

    #[test]
    fn hpc_much_slower_than_cloud() {
        // The Table-3 headline: HPC cold starts are ~5-10x cloud ones.
        let theta = TABLE3_MODELS.lookup(SystemProfile::Theta, ContainerTech::Singularity);
        let ec2 = TABLE3_MODELS.lookup(SystemProfile::Ec2, ContainerTech::Docker);
        assert!(theta.mean() > 5.0 * ec2.mean());
    }

    #[test]
    fn unknown_pair_falls_back_to_local() {
        let m = TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None);
        assert!(m.mean() < 0.5);
    }

    #[test]
    fn tech_names() {
        assert_eq!(ContainerTech::Docker.name(), "docker");
        assert_eq!(SystemProfile::Theta.name(), "theta");
    }
}
