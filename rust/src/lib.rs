//! # funcx-rs — funcX: Federated Function as a Service for Science
//!
//! A reproduction of the funcX platform (Li, Chard, Babuji, et al.,
//! IEEE TPDS 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the federated FaaS coordinator: the
//!   cloud-hosted service ([`service`]) with per-endpoint forwarders and
//!   Redis-like queues ([`store`]), the endpoint hierarchy
//!   ([`endpoint`]: agent → manager → worker), container management and
//!   warming-aware routing ([`containers`], [`routing`]), elastic
//!   provisioning ([`provider`]), intra/inter-endpoint data management
//!   ([`data`], [`datastore`], [`transfer`]), batching ([`batching`]), the
//!   serialization facade ([`serialize`]), and a Globus-Auth-like IAM
//!   substrate ([`auth`]).
//! * **Layer 2/1 (build-time Python)** — JAX compute graphs over Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`; the [`runtime`]
//!   module loads and executes them via PJRT so real scientific payloads
//!   run on the request path with Python nowhere in sight.
//!
//! Scale experiments (131 072 workers, Fig. 4) run on the discrete-event
//! simulator ([`sim`]) which drives the *same* policy objects as the
//! live engine; see `DESIGN.md` for the substitution table.

pub mod auth;
pub mod batching;
pub mod common;
pub mod containers;
pub mod data;
pub mod datastore;
pub mod endpoint;
pub mod experiments;
pub mod metrics;
pub mod provider;
pub mod registry;
pub mod routing;
pub mod runtime;
pub mod sdk;
pub mod serialize;
pub mod service;
pub mod sim;
pub mod store;
pub mod testing;
pub mod transfer;
pub mod workloads;

pub use common::error::{Error, Result};
pub use common::ids::Uuid;
