//! The REST-equivalent service API (Fig. 2 steps 1–3 and 6).
//!
//! The service plane is sharded N ways behind the consistent-hash
//! [`ShardMap`] (§4.1 "designed to scale horizontally"): each
//! [`ServiceShard`] owns its own KV store, payload store, and result
//! latch, so shards share no locks on the hot path. Tasks hash by task
//! id, endpoints by endpoint id, and forwarded-ref refcounts by ref
//! identity; auth, the registry, and counters are shared (the registry
//! *is* the cross-shard advertisement replication — every shard reads
//! the same store/endpoint advertisements). With the default
//! `service_shards = 1` the service behaves exactly like the unsharded
//! original.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::auth::{AuthService, Scope, Token};
use crate::batching::BatchRequest;
use crate::common::config::ServiceConfig;
use crate::common::error::{Error, Result};
use crate::common::ids::{EndpointId, FunctionId, TaskId, UserId, Uuid};
use crate::common::sync::Notify;
use crate::common::task::{Payload, Task, TaskResult, TaskState};
use crate::common::time::{Clock, Time, WallClock};
use crate::datastore::{DataFabric, DataRef, TieredConfig, TieredStore};
use crate::metrics::{
    Counters, FlightRecorder, LatencyBreakdown, MetricsRegistry, MetricsSnapshot, TaskTrace,
    TraceCtx, TraceId, TraceKind,
};
use crate::registry::{EndpointStatus, Registry};
use crate::serialize::{pack, unpack, Value, Wire};
use crate::service::shard::{shard_owner, ShardMap};
use crate::store::{KvStore, TaskQueue};

/// Receipt for a submitted task.
#[derive(Clone, Copy, Debug)]
pub struct SubmitReceipt {
    pub task: TaskId,
}

/// One slice of the service plane: private KV store, private payload
/// store, private result latch. Everything keyed by a task, endpoint,
/// or ref identity lives on exactly one shard (see [`ShardMap`]).
struct ServiceShard {
    kv: KvStore,
    /// The shard's slice of the data fabric. Its local store advertises
    /// frames under [`shard_owner`]`(i)`; at construction every shard's
    /// fabric is peered with every *other* shard's local store, so a ref
    /// minted on one shard resolves from any shard.
    fabric: Arc<DataFabric>,
    /// Signalled on every result stored on this shard, so
    /// [`FuncXService::wait_result`] waiters only wake for results that
    /// can be theirs.
    result_notify: Arc<Notify>,
    /// Task ids whose inputs were offloaded to this shard's fabric — so
    /// the result hot path only touches the payload store's lock for
    /// tasks that actually dispatched by reference.
    offloaded: Mutex<HashSet<TaskId>>,
    /// Chain tasks (submitted via [`FuncXService::submit_by_ref`]) →
    /// the result ref they consume: when such a task reaches a terminal
    /// state, the consumed `task-result:*` frame is reclaimed eagerly
    /// instead of lingering until TTL (result-frame GC, mirroring how
    /// offloaded *inputs* are reclaimed on terminal results). Keyed by
    /// the chain task's shard.
    consumed: Mutex<HashMap<TaskId, DataRef>>,
    /// How many not-yet-terminal chain tasks still hold each forwarded
    /// result ref (keyed by owner:epoch:key): a frame is only reclaimed
    /// once its last pending consumer completes. Keyed by the *ref's*
    /// identity hash — producer and consumer tasks may live on different
    /// shards, but both reach the same refcount row this way.
    pending_refs: Mutex<HashMap<String, usize>>,
}

/// The cloud-hosted service. Clone-shareable across threads.
#[derive(Clone)]
pub struct FuncXService {
    pub auth: AuthService,
    pub registry: Registry,
    /// Shard 0's slice of the service data fabric, kept as a public
    /// handle: with the default single shard this *is* the service-side
    /// fabric of old (oversized inputs are `put()` here and endpoint
    /// fabrics peer with `fabric.local()`, owner
    /// [`crate::datastore::SERVICE_OWNER`], to resolve them — §5).
    /// Multi-shard wiring peers endpoint stores into every shard's
    /// fabric via [`FuncXService::peer_store`].
    pub fabric: Arc<DataFabric>,
    pub cfg: ServiceConfig,
    pub clock: Arc<dyn Clock>,
    pub latency: Arc<LatencyBreakdown>,
    pub counters: Arc<Counters>,
    /// The unified metrics facade: every pre-existing stats struct
    /// (Counters, LatencyBreakdown, per-shard Tier/FabricStats,
    /// per-endpoint TierStats) is polled into one dimensioned snapshot
    /// tree at [`MetricsRegistry::snapshot`] — zero hot-path cost.
    pub metrics: Arc<MetricsRegistry>,
    /// The task flight recorder (see `docs/observability.md`): every
    /// hop of every task appends a typed event; assemble timelines via
    /// [`FuncXService::trace`]. Ring capacity comes from
    /// [`ServiceConfig::trace_ring_capacity`] (0 disables).
    pub recorder: Arc<FlightRecorder>,
    shard_map: ShardMap,
    shards: Arc<Vec<ServiceShard>>,
}

/// The identity a forwarded ref is refcounted under.
fn ref_ident(r: &DataRef) -> String {
    format!("{}:{}:{}", r.owner, r.epoch, r.key)
}

/// The typed error a terminal non-success result maps to (shared by
/// [`FuncXService::get_result`] and [`FuncXService::wait_result_ref`]
/// so the two APIs always report failures identically).
fn terminal_error(r: &TaskResult) -> Error {
    match r.state {
        TaskState::Failed => {
            let msg = unpack(&r.output)
                .ok()
                .and_then(|v| v.as_str().map(str::to_string))
                .unwrap_or_else(|| "unknown".into());
            Error::TaskFailed(msg)
        }
        _ => Error::TaskFailed("abandoned after agent loss".into()),
    }
}

/// Build the N service shards. Each payload store is TTL-pinned to the
/// service's own clock (owner-stamped expiry): endpoint fabrics
/// resolving against it with skewed clocks cannot mis-expire offloaded
/// frames. The shards' fabrics are cross-peered into a full mesh so a
/// frame owned by any shard resolves from every shard.
fn build_shards(
    cfg: &ServiceConfig,
    clock: &Arc<dyn Clock>,
    counters: &Arc<Counters>,
    recorder: &Arc<FlightRecorder>,
) -> Arc<Vec<ServiceShard>> {
    let n = cfg.service_shards.max(1);
    let shards: Vec<ServiceShard> = (0..n)
        .map(|i| {
            let store = Arc::new(
                TieredStore::new(
                    shard_owner(i),
                    TieredConfig {
                        mem_high_watermark: cfg.store_mem_watermark_bytes,
                        default_ttl_s: cfg.result_ttl_s,
                        spool_dir: None,
                    },
                )
                .expect("create service payload spool")
                .with_owner_clock(clock.clone()),
            );
            store.with_recorder(recorder.clone(), clock.clone());
            let fabric = Arc::new(DataFabric::new(store));
            fabric.with_counters(counters.clone());
            fabric.with_recorder(recorder.clone());
            ServiceShard {
                kv: KvStore::new(),
                fabric,
                result_notify: Arc::new(Notify::new()),
                offloaded: Mutex::new(HashSet::new()),
                consumed: Mutex::new(HashMap::new()),
                pending_refs: Mutex::new(HashMap::new()),
            }
        })
        .collect();
    for (i, a) in shards.iter().enumerate() {
        for (j, b) in shards.iter().enumerate() {
            if i != j {
                a.fabric.connect_peer(shard_owner(j), b.fabric.local().clone());
            }
        }
    }
    Arc::new(shards)
}

impl FuncXService {
    pub fn new(cfg: ServiceConfig) -> Self {
        let clock: Arc<dyn Clock> = Arc::new(WallClock::new());
        let counters = Counters::new();
        let recorder = Arc::new(FlightRecorder::with_capacity(cfg.trace_ring_capacity));
        let shards = build_shards(&cfg, &clock, &counters, &recorder);
        let shard_map = ShardMap::new(cfg.service_shards.max(1));
        let svc = FuncXService {
            auth: AuthService::new(),
            registry: Registry::new(),
            fabric: shards[0].fabric.clone(),
            cfg,
            clock,
            latency: Arc::new(LatencyBreakdown::new()),
            counters,
            metrics: MetricsRegistry::new(),
            recorder,
            shard_map,
            shards,
        };
        svc.register_metric_sources();
        svc
    }

    /// Replace the service clock (construction-time only: the shard
    /// payload stores are rebuilt so their owner-stamped TTLs follow
    /// the new clock, dropping any peers already wired into the old
    /// fabrics).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self.shards = build_shards(&self.cfg, &self.clock, &self.counters, &self.recorder);
        self.fabric = self.shards[0].fabric.clone();
        // The old registry's per-shard sources capture the replaced
        // shard fabrics; start a fresh registry over the new ones.
        self.metrics = MetricsRegistry::new();
        self.register_metric_sources();
        self
    }

    /// Adapt every pre-existing stats surface into the metrics facade:
    /// polled only at [`MetricsRegistry::snapshot`], so the hot paths
    /// keep their relaxed-atomic structs untouched.
    fn register_metric_sources(&self) {
        let counters = self.counters.clone();
        self.metrics.register_source(move |b| counters.fill(b));
        let latency = self.latency.clone();
        self.metrics.register_source(move |b| latency.fill(b));
        let recorder = self.recorder.clone();
        self.metrics.register_source(move |b| {
            b.gauge("funcx_trace_events_resident", &[], recorder.resident() as i64);
            b.counter("funcx_trace_events_dropped_total", &[], recorder.dropped());
        });
        for (i, sh) in self.shards.iter().enumerate() {
            let fabric = sh.fabric.clone();
            let shard = i.to_string();
            self.metrics.register_source(move |b| {
                let dims = [("shard", shard.as_str())];
                fabric.stats.fill(b, &dims);
                fabric.local().stats.fill(b, &dims);
            });
        }
        // Endpoint membership is dynamic: enumerate advertised stores
        // at snapshot time rather than capturing today's set.
        let registry = self.registry.clone();
        self.metrics.register_source(move |b| {
            for (ep, store) in registry.advertised_stores() {
                let id = ep.to_string();
                store.stats.fill(b, &[("endpoint", id.as_str())]);
            }
        });
    }

    /// Record a trace event on a service-shard component. The
    /// `enabled()` gate keeps the disabled path free of the component
    /// string allocation.
    fn record_shard(
        &self,
        shard: usize,
        trace: Option<TraceId>,
        task: TaskId,
        at: Time,
        kind: TraceKind,
    ) {
        if self.recorder.enabled() {
            self.recorder.record(&format!("shard-{shard}"), trace, Some(task), at, kind);
        }
    }

    /// Assemble one task's cross-shard, cross-endpoint flight-recorder
    /// timeline (`None` if no events were recorded for it — recorder
    /// disabled, or the events aged out of every ring).
    pub fn trace(&self, id: TaskId) -> Option<TaskTrace> {
        self.recorder.assemble(id)
    }

    /// One coherent snapshot of every registered metric (see
    /// `docs/observability.md` for the catalog).
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    // ---- shard routing -----------------------------------------------------

    /// The consistent-hash shard map, shared verbatim with clients (the
    /// SDK exposes it as the client shard map) and the simulator.
    pub fn shard_map(&self) -> ShardMap {
        self.shard_map
    }

    /// Number of service-plane shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn task_shard(&self, id: TaskId) -> &ServiceShard {
        &self.shards[self.shard_map.shard_for_task(id)]
    }

    fn endpoint_shard(&self, ep: EndpointId) -> &ServiceShard {
        &self.shards[self.shard_map.shard_for_endpoint(ep)]
    }

    fn ref_shard(&self, r: &DataRef) -> &ServiceShard {
        &self.shards[self.shard_map.shard_for_key(&ref_ident(r))]
    }

    /// Every shard's service payload store, in shard order — the
    /// forwarder advertises each downstream so agents can resolve
    /// `iref`s no matter which shard offloaded them.
    pub(crate) fn shard_stores(&self) -> Vec<Arc<TieredStore>> {
        self.shards.iter().map(|s| s.fabric.local().clone()).collect()
    }

    /// Peer an endpoint's advertised store into EVERY shard's fabric:
    /// result refs resolve on the owning task shard, replica routing
    /// scans from any shard, and decommission drains can land on peers
    /// registered via any shard.
    pub(crate) fn peer_store(&self, owner: EndpointId, store: Arc<TieredStore>) {
        for sh in self.shards.iter() {
            sh.fabric.connect_peer(owner, store.clone());
        }
    }

    // ---- registration (§3) -----------------------------------------------

    /// Register a function (requires the register_function scope).
    pub fn register_function(
        &self,
        token: &Token,
        name: &str,
        payload: Payload,
        container: Option<crate::common::ids::ContainerId>,
    ) -> Result<FunctionId> {
        let user = self.auth.check(token, Scope::RegisterFunction, self.clock.now())?;
        Ok(self.registry.register_function(name, user, payload, container))
    }

    /// Register an endpoint (requires the register_endpoint scope).
    pub fn register_endpoint(
        &self,
        token: &Token,
        name: &str,
        description: &str,
    ) -> Result<EndpointId> {
        let user = self.auth.check(token, Scope::RegisterEndpoint, self.clock.now())?;
        Ok(self.registry.register_endpoint(name, description, user))
    }

    // ---- submission (Fig. 2 steps 1–3) ------------------------------------

    /// Submit one invocation: auth, authz, payload cap, persist, enqueue.
    pub fn submit(
        &self,
        token: &Token,
        function: FunctionId,
        endpoint: EndpointId,
        input: &Value,
    ) -> Result<SubmitReceipt> {
        let now = self.clock.now();
        let user = self.auth.check(token, Scope::RunFunction, now)?;
        let f = self.registry.function(function)?;
        let e = self.registry.endpoint(endpoint)?;
        if !self.auth.may_invoke_function(user, f.owner, function) {
            return Err(Error::Forbidden(format!("{user} may not invoke {function}")));
        }
        if !self.auth.may_use_endpoint(user, e.owner, endpoint) {
            return Err(Error::Forbidden(format!("{user} may not use endpoint {endpoint}")));
        }
        let buf = pack(input, 0)?;
        let task =
            self.make_task(function, endpoint, user, f.container, f.payload.clone(), buf, now)?;
        self.enqueue_task(task, now)
    }

    /// Build the task record for one invocation, enforcing the inline
    /// data cap: inputs above `max_payload_bytes` are offloaded to the
    /// owning task shard's fabric and the task carries a compact
    /// `DataRef` in its trailer meta (§5 pass-by-reference dispatch) —
    /// or, with `ref_dispatch` disabled, are rejected as in the
    /// original 10 MB-capped service.
    #[allow(clippy::too_many_arguments)]
    fn make_task(
        &self,
        function: FunctionId,
        endpoint: EndpointId,
        user: UserId,
        container: Option<crate::common::ids::ContainerId>,
        payload: Payload,
        input: crate::serialize::Buffer,
        now: Time,
    ) -> Result<Task> {
        let id = TaskId::new();
        let trace = self.recorder.enabled().then(|| self.recorder.mint(id));
        if input.len() > self.cfg.max_payload_bytes {
            if !self.cfg.ref_dispatch {
                return Err(Error::PayloadTooLarge {
                    size: input.len(),
                    limit: self.cfg.max_payload_bytes,
                });
            }
            let size = input.len() as u64;
            let shard = self.task_shard(id);
            // Offload under the task's trace context: a shed put
            // (spill backpressure) then lands in this task's timeline.
            let r = {
                let _ctx = TraceCtx::enter(trace, id);
                shard.fabric.put(&format!("task-input:{id}"), input, now)?
            };
            shard.offloaded.lock().expect("offloaded set poisoned").insert(id);
            crate::metrics::Counters::incr(&self.counters.tasks_ref_dispatched);
            crate::metrics::Counters::add(&self.counters.bytes_offloaded, size);
            return Ok(Task {
                id,
                function,
                endpoint,
                user,
                container,
                payload,
                input: crate::serialize::Buffer::empty(),
                input_ref: Some(r),
                trace,
            });
        }
        Ok(Task { id, function, endpoint, user, container, payload, input, input_ref: None, trace })
    }

    /// Submit a user-facing batch (§4.6): one authenticated call, many
    /// invocations, one receipt per invocation.
    pub fn submit_batch(&self, token: &Token, batch: &BatchRequest) -> Result<Vec<SubmitReceipt>> {
        let now = self.clock.now();
        let user = self.auth.check(token, Scope::RunFunction, now)?;
        let f = self.registry.function(batch.function)?;
        let e = self.registry.endpoint(batch.endpoint)?;
        if !self.auth.may_invoke_function(user, f.owner, batch.function) {
            return Err(Error::Forbidden("not authorized for function".into()));
        }
        if !self.auth.may_use_endpoint(user, e.owner, batch.endpoint) {
            return Err(Error::Forbidden("not authorized for endpoint".into()));
        }
        // Admission is atomic: the size check runs before anything is
        // enqueued, so an oversized batch never leaves orphaned members
        // behind. Without ref dispatch the whole batch is inline-capped
        // (the original rule — any over-cap member also trips it); with
        // ref dispatch, oversized members offload individually but the
        // bytes that stay *inline* must still fit the cap.
        let inline_total: usize = batch
            .inputs
            .iter()
            .map(crate::serialize::Buffer::len)
            .filter(|l| !self.cfg.ref_dispatch || *l <= self.cfg.max_payload_bytes)
            .sum();
        if inline_total > self.cfg.max_payload_bytes {
            return Err(Error::PayloadTooLarge {
                size: inline_total,
                limit: self.cfg.max_payload_bytes,
            });
        }
        // Build every task first (offloading oversized inputs), then
        // enqueue: size errors can no longer strike mid-batch.
        let tasks: Vec<Task> = batch
            .inputs
            .iter()
            .map(|input| {
                self.make_task(
                    batch.function,
                    batch.endpoint,
                    user,
                    f.container,
                    f.payload.clone(),
                    input.clone(),
                    now,
                )
            })
            .collect::<Result<_>>()?;
        self.enqueue_batch(batch.endpoint, tasks, now)
    }

    fn enqueue_task(&self, mut task: Task, now: f64) -> Result<SubmitReceipt> {
        let id = task.id;
        // Tasks built outside make_task (submit_by_ref chains) have no
        // trace yet — mint at the enqueue boundary so every submitted
        // task is traceable.
        if task.trace.is_none() && self.recorder.enabled() {
            task.trace = Some(self.recorder.mint(id));
        }
        self.latency.on_submit(id, now);
        self.record_shard(
            self.shard_map.shard_for_task(id),
            task.trace,
            id,
            now,
            TraceKind::Submitted { endpoint: task.endpoint },
        );
        // Persist task state on the owning shard (Redis hashset; §4.1).
        self.task_shard(id).kv.hset("tasks", &id.to_string(), task.to_buffer());
        self.set_state(id, TaskState::Received);
        crate::metrics::Counters::incr(&self.counters.tasks_submitted);
        crate::metrics::Counters::add(
            &self.counters.bytes_through_service,
            task.input.len() as u64,
        );
        // Append to the endpoint's task queue (Redis list; §4.1).
        self.task_queue(task.endpoint).push(&task)?;
        self.set_state(id, TaskState::WaitingForEndpoint);
        let queued_at = self.clock.now();
        self.latency.on_queued(id, queued_at);
        // The dispatch queue lives on the ENDPOINT's shard (which may
        // differ from the task's) — record where the task actually sits.
        let qshard = self.shard_map.shard_for_endpoint(task.endpoint);
        self.record_shard(
            qshard,
            task.trace,
            id,
            queued_at,
            TraceKind::ShardEnqueued { shard: qshard as u32 },
        );
        Ok(SubmitReceipt { task: id })
    }

    /// Enqueue a pre-built batch: per-task records first, then ONE
    /// queue append for the whole batch ([`TaskQueue::push_all`]) so the
    /// forwarder's watch latch fires once per flush, not once per frame
    /// (producer-side watch coalescing).
    fn enqueue_batch(
        &self,
        endpoint: EndpointId,
        mut tasks: Vec<Task>,
        now: f64,
    ) -> Result<Vec<SubmitReceipt>> {
        for task in &mut tasks {
            let id = task.id;
            if task.trace.is_none() && self.recorder.enabled() {
                task.trace = Some(self.recorder.mint(id));
            }
            self.latency.on_submit(id, now);
            self.record_shard(
                self.shard_map.shard_for_task(id),
                task.trace,
                id,
                now,
                TraceKind::Submitted { endpoint },
            );
            self.task_shard(id).kv.hset("tasks", &id.to_string(), task.to_buffer());
            self.set_state(id, TaskState::Received);
            crate::metrics::Counters::incr(&self.counters.tasks_submitted);
            crate::metrics::Counters::add(
                &self.counters.bytes_through_service,
                task.input.len() as u64,
            );
        }
        self.task_queue(endpoint).push_all(&tasks)?;
        let queued_at = self.clock.now();
        let qshard = self.shard_map.shard_for_endpoint(endpoint);
        let mut receipts = Vec::with_capacity(tasks.len());
        for task in &tasks {
            self.set_state(task.id, TaskState::WaitingForEndpoint);
            self.latency.on_queued(task.id, queued_at);
            self.record_shard(
                qshard,
                task.trace,
                task.id,
                queued_at,
                TraceKind::ShardEnqueued { shard: qshard as u32 },
            );
            receipts.push(SubmitReceipt { task: task.id });
        }
        Ok(receipts)
    }

    // ---- status & results (Fig. 2 step 6) ----------------------------------

    pub fn task_state(&self, id: TaskId) -> Result<TaskState> {
        let raw = self
            .task_shard(id)
            .kv
            .hget("task_state", &id.to_string())
            .ok_or_else(|| Error::NotFound(format!("task {id}")))?;
        TaskState::from_name(std::str::from_utf8(&raw).unwrap_or("?"))
    }

    pub(crate) fn set_state(&self, id: TaskId, state: TaskState) {
        self.task_shard(id).kv.hset("task_state", &id.to_string(), state.name().as_bytes());
    }

    /// Retrieve a completed task's output; `None` while still running.
    /// Results are purged after retrieval (§4.1 cost control). A by-ref
    /// result (`"rref"`) resolves through the owning shard fabric's
    /// fetch ladder — local store, cache, peer forward, Globus model —
    /// so the caller sees the bytes whether or not they ever touched the
    /// service queues; a vanished or corrupt frame surfaces the typed
    /// [`Error::NotFound`] / [`Error::Corrupt`].
    ///
    /// Retrieval CONSUMES an offloaded result: the frame is reclaimed
    /// from its owner store eagerly (result-frame GC) unless chain
    /// tasks are still pending on it — so to forward a result into a
    /// chain, take its ref via [`FuncXService::wait_result_ref`] /
    /// [`FuncXService::peek_result`] and `submit_by_ref` *before* (or
    /// instead of) retrieving the bytes.
    pub fn get_result(&self, id: TaskId) -> Result<Option<Value>> {
        let state = self.task_state(id)?;
        if !state.is_terminal() {
            return Ok(None);
        }
        let shard = self.task_shard(id);
        let key = format!("result:{id}");
        let raw = shard
            .kv
            .get_at(&key, self.clock.now())
            .ok_or_else(|| Error::NotFound(format!("result for {id} (purged?)")))?;
        let result = TaskResult::from_buffer(&raw)?;
        match result.state {
            TaskState::Success => {
                // Resolve BEFORE purging: a transiently-unreachable
                // by-ref frame must leave the record in place so a
                // later get_result call can still succeed once the
                // owner endpoint is reachable again. (The error itself
                // still propagates — wait_result surfaces it rather
                // than blocking on a ref that may be gone for good.)
                let frame = match &result.output_ref {
                    Some(r) => {
                        // Resolve under the task's trace context so the
                        // ladder outcome (hit tier, retries, replica
                        // failover) lands in this task's timeline.
                        let _ctx = TraceCtx::enter(self.recorder.trace_id(id), id);
                        shard.fabric.resolve(r, self.clock.now())?
                    }
                    None => result.output.clone(),
                };
                let value = unpack(&frame)?;
                shard.kv.del(&key); // purge once actually retrieved
                // Result-frame GC: the offloaded output has been
                // delivered, so reclaim its frame from the owner store
                // now instead of waiting out the TTL — unless a chain
                // task is still pending on this very ref, in which case
                // the last consumer's completion reclaims it instead.
                // (The pending map — on the REF's shard, reachable from
                // producer and consumers alike — stays locked through
                // the reclaim so a racing submit_by_ref cannot adopt a
                // ref that is being reclaimed.)
                if let Some(r) = &result.output_ref {
                    let pending =
                        self.ref_shard(r).pending_refs.lock().expect("pending refs poisoned");
                    if !pending.contains_key(&ref_ident(r)) && shard.fabric.reclaim(r) {
                        crate::metrics::Counters::incr(&self.counters.result_frames_reclaimed);
                    }
                }
                Ok(Some(value))
            }
            _ => {
                shard.kv.del(&key); // purge once retrieved
                Err(terminal_error(&result))
            }
        }
    }

    /// Read a completed task's stored result record without purging or
    /// resolving it (`None` while still running) — the chain submitter's
    /// peek: take the `DataRef`, leave the bytes where they are.
    pub fn peek_result(&self, id: TaskId) -> Result<Option<TaskResult>> {
        let state = self.task_state(id)?;
        if !state.is_terminal() {
            return Ok(None);
        }
        let raw = self
            .task_shard(id)
            .kv
            .get_at(&format!("result:{id}"), self.clock.now())
            .ok_or_else(|| Error::NotFound(format!("result for {id} (purged?)")))?;
        Ok(Some(TaskResult::from_buffer(&raw)?))
    }

    /// Block until `id` completes and return the [`DataRef`] its
    /// offloaded output travels by — the ref-forwarding fast path: feed
    /// it straight into [`FuncXService::submit_by_ref`] and the result
    /// bytes never transit the service. The stored result is *not*
    /// purged (follow-on resolution still needs the frame). Failed tasks
    /// surface their traceback; an inline result is an
    /// [`Error::InvalidArgument`] (there is nothing to forward — use
    /// [`FuncXService::get_result`]).
    pub fn wait_result_ref(&self, id: TaskId, timeout: std::time::Duration) -> Result<DataRef> {
        let deadline = std::time::Instant::now() + timeout;
        let notify = &self.task_shard(id).result_notify;
        loop {
            let seen = notify.epoch();
            if let Some(r) = self.peek_result(id)? {
                return match r.state {
                    TaskState::Success => r.output_ref.ok_or_else(|| {
                        Error::InvalidArgument(format!(
                            "result for {id} is inline; use get_result"
                        ))
                    }),
                    _ => Err(terminal_error(&r)),
                };
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return Err(Error::Timeout(format!("task {id}")));
            }
            notify.wait_newer(seen, remaining);
        }
    }

    /// Submit an invocation whose input *is* a prior result's ref
    /// (§5 ref forwarding): the task carries the compact `DataRef`
    /// through the queues and the service never touches the payload —
    /// the worker resolves it endpoint-side, a local store hit when
    /// [`crate::routing::LocalityAware`] routed the task to the owner.
    ///
    /// Forwarding a result ref makes this chain task a *consumer* of
    /// the frame: the frame survives at least until the last pending
    /// consumer completes, at which point it is reclaimed (and the
    /// producing task's stored record purged) — the result is consumed
    /// *by the chain*. Forward before retrieving: a ref whose frame was
    /// already reclaimed by `get_result` fails the chain task with a
    /// typed `NotFound`, like any other dead ref.
    pub fn submit_by_ref(
        &self,
        token: &Token,
        function: FunctionId,
        endpoint: EndpointId,
        input: &DataRef,
    ) -> Result<SubmitReceipt> {
        let now = self.clock.now();
        let user = self.auth.check(token, Scope::RunFunction, now)?;
        let f = self.registry.function(function)?;
        let e = self.registry.endpoint(endpoint)?;
        if !self.auth.may_invoke_function(user, f.owner, function) {
            return Err(Error::Forbidden(format!("{user} may not invoke {function}")));
        }
        if !self.auth.may_use_endpoint(user, e.owner, endpoint) {
            return Err(Error::Forbidden(format!("{user} may not use endpoint {endpoint}")));
        }
        let task = Task::new(
            function,
            endpoint,
            user,
            f.container,
            f.payload.clone(),
            crate::serialize::Buffer::empty(),
        )
        .with_input_ref(input.clone());
        // A forwarded *result* ref is consumed by this chain task: once
        // the LAST pending consumer of the ref is terminal the frame is
        // reclaimed eagerly (result-frame GC) — the refcount lets one
        // result fan out to several chain tasks safely. Other refs
        // (re-forwarded inputs, external data) are left to their owners.
        // The consumed record lives on the CHAIN task's shard; the
        // refcount lives on the REF's shard (the producer may hash
        // elsewhere — both sides must see the same row).
        if input.key.starts_with("task-result:") {
            self.task_shard(task.id)
                .consumed
                .lock()
                .expect("consumed map poisoned")
                .insert(task.id, input.clone());
            *self
                .ref_shard(input)
                .pending_refs
                .lock()
                .expect("pending refs poisoned")
                .entry(ref_ident(input))
                .or_insert(0) += 1;
        }
        crate::metrics::Counters::incr(&self.counters.tasks_ref_forwarded);
        self.enqueue_task(task, now)
    }

    /// Block until the task reaches a terminal state (test/SDK helper).
    /// Wakeup-driven: waiters sleep on the owning shard's result latch
    /// and are woken by [`FuncXService::store_result`] — no poll
    /// interval, and no cross-shard wakeup herd.
    pub fn wait_result(&self, id: TaskId, timeout: std::time::Duration) -> Result<Value> {
        let deadline = std::time::Instant::now() + timeout;
        let notify = &self.task_shard(id).result_notify;
        loop {
            // Snapshot the epoch *before* checking so a result stored
            // between the check and the wait still wakes us.
            let seen = notify.epoch();
            if let Some(v) = self.get_result(id)? {
                return Ok(v);
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return Err(Error::Timeout(format!("task {id}")));
            }
            notify.wait_newer(seen, remaining);
        }
    }

    // ---- internals shared with the forwarder -------------------------------

    pub(crate) fn task_queue(&self, ep: EndpointId) -> TaskQueue<Task> {
        TaskQueue::new(self.endpoint_shard(ep).kv.clone(), format!("ep:{ep}:tasks"))
    }

    pub(crate) fn store_result(&self, r: &TaskResult) {
        let now = self.clock.now();
        let shard = self.task_shard(r.task);
        // Everything below — replication's ladder pull, the GC
        // reclaims — runs under this task's trace context.
        let trace = self.recorder.trace_id(r.task);
        let _ctx = TraceCtx::enter(trace, r.task);
        // Replication (§5 survivability): before the record is
        // persisted, copies of a by-ref result frame are pushed to
        // other advertised stores and the replica set is recorded on
        // the stored ref — everything downstream (retrieval, chain
        // forwarding, routing hints) then knows where to fail over if
        // the owner dies. No-op unless `replication_factor` is set.
        let replicated = self.replicate_result(r, now);
        let r = replicated.as_ref().unwrap_or(r);
        shard.kv.set_ex(
            &format!("result:{}", r.task),
            r.to_buffer(),
            self.cfg.result_ttl_s,
            now,
        );
        // Byte accounting for the return path: by-ref results contribute
        // only their empty placeholder here (the §5 symmetric-path pin).
        crate::metrics::Counters::add(
            &self.counters.result_bytes_through_service,
            r.output.len() as u64,
        );
        if r.returns_by_ref() {
            crate::metrics::Counters::incr(&self.counters.results_ref_offloaded);
        }
        // Terminal state: reclaim the offloaded input frame, if any,
        // instead of letting it sit in the payload store until TTL.
        // Gated on the offloaded set so inline results (the common
        // case) never touch the payload store's lock. (Re-dispatch
        // after agent loss never reaches here non-terminal, so
        // in-flight refs stay resolvable.)
        if shard.offloaded.lock().expect("offloaded set poisoned").remove(&r.task) {
            let _ = shard.fabric.local().remove(&format!("task-input:{}", r.task));
        }
        // Result-frame GC, chain flavor: this terminal task consumed a
        // prior result's ref (submit_by_ref). Drop its hold; when the
        // last pending consumer of the ref completes, the
        // `task-result:*` frame has served its purpose and is reclaimed
        // from the owner's store eagerly. Gated on the consumed map, so
        // ordinary results never touch it.
        let consumed = shard.consumed.lock().expect("consumed map poisoned").remove(&r.task);
        if let Some(cref) = consumed {
            let mut pending =
                self.ref_shard(&cref).pending_refs.lock().expect("pending refs poisoned");
            let drained = match pending.get_mut(&ref_ident(&cref)) {
                Some(n) if *n > 1 => {
                    *n -= 1;
                    false
                }
                _ => {
                    pending.remove(&ref_ident(&cref));
                    true
                }
            };
            if drained {
                if shard.fabric.reclaim(&cref) {
                    crate::metrics::Counters::incr(&self.counters.result_frames_reclaimed);
                }
                // Replica copies of the reclaimed frame die with it
                // instead of lingering in peer stores until TTL.
                if !cref.replicas.is_empty() {
                    let rkey = cref.replica_key();
                    for (ep, store) in self.registry.advertised_stores() {
                        if cref.replicas.contains(&ep) {
                            let _ = store.remove(&rkey);
                        }
                    }
                }
                // The producing task's stored record now points at
                // reclaimed bytes; purge it so a later get_result on
                // the producer reports "purged" (consumed by the
                // chain), not an eternal NotFound against a live
                // record. The producer may live on another shard —
                // route by its parsed task id.
                if let Some(tid) = cref.key.strip_prefix("task-result:") {
                    if let Ok(uuid) = tid.parse::<Uuid>() {
                        self.task_shard(TaskId(uuid)).kv.del(&format!("result:{tid}"));
                    }
                }
            }
        }
        self.set_state(r.task, r.state);
        self.latency.on_result_stored(r.task, now);
        let shard_no = self.shard_map.shard_for_task(r.task);
        self.record_shard(
            shard_no,
            trace,
            r.task,
            now,
            TraceKind::ResultStored { shard: shard_no as u32, state: r.state.name() },
        );
        match r.state {
            TaskState::Success => {
                crate::metrics::Counters::incr(&self.counters.tasks_completed);
            }
            _ => {
                let error = match r.state {
                    TaskState::Abandoned => "Abandoned",
                    _ => "TaskFailed",
                };
                self.record_shard(shard_no, trace, r.task, now, TraceKind::TaskFailed { error });
                crate::metrics::Counters::incr(&self.counters.tasks_failed);
            }
        }
        if r.cold_start {
            crate::metrics::Counters::incr(&self.counters.cold_starts);
        } else {
            crate::metrics::Counters::incr(&self.counters.warm_hits);
        }
        shard.result_notify.notify();
    }

    /// Push up to `replication_factor` copies of a successful by-ref
    /// result frame into *other* registry-advertised stores, under the
    /// ref's [`DataRef::replica_key`]. Returns a rewritten result whose
    /// `output_ref` lists the endpoints now holding copies, or `None`
    /// when nothing was replicated (factor 0, inline result,
    /// already-replicated ref, unresolvable frame, or no peer stores).
    /// Replica targets come from the shared registry, so copies may
    /// land on peers whose endpoints registered via any shard.
    fn replicate_result(&self, r: &TaskResult, now: Time) -> Option<TaskResult> {
        if self.cfg.replication_factor == 0 || r.state != TaskState::Success {
            return None;
        }
        let dref = r.output_ref.as_ref()?;
        if !dref.replicas.is_empty() {
            return None;
        }
        // Pull the frame through the fabric ladder (peer-forwarded from
        // the owner's store; a per-frame cost paid once, off the inline
        // result path — the record itself still carries zero bytes).
        let frame = self.task_shard(r.task).fabric.resolve(dref, now).ok()?;
        let rkey = dref.replica_key();
        let mut holders = Vec::new();
        for (ep, store) in self.registry.advertised_stores() {
            if holders.len() >= self.cfg.replication_factor {
                break;
            }
            if ep == dref.owner {
                continue;
            }
            if store.put_with_ttl(&rkey, frame.clone(), Some(self.cfg.result_ttl_s), now).is_ok() {
                crate::metrics::Counters::incr(&self.counters.replicas_created);
                holders.push(ep);
            }
        }
        if holders.is_empty() {
            return None;
        }
        let mut out = r.clone();
        let mut dref = dref.clone();
        dref.replicas = holders;
        out.output_ref = Some(dref);
        Some(out)
    }

    /// Periodic housekeeping across every shard: purge expired results
    /// (§4.1) and sweep expired offloaded inputs out of the payload
    /// stores (frames whose tasks never produced a result would
    /// otherwise only expire lazily on access — i.e. never). The
    /// offloaded-id sets are pruned in the same pass so ids of
    /// never-completing tasks don't accumulate across the service's
    /// lifetime.
    pub fn purge_expired_results(&self) -> usize {
        let now = self.clock.now();
        let mut purged = 0usize;
        for sh in self.shards.iter() {
            sh.fabric.local().evict_expired(now);
            sh.offloaded.lock().expect("offloaded set poisoned").retain(|id| {
                sh.fabric.local().live_tier(&format!("task-input:{id}"), now).is_some()
            });
            // Chain tasks that never produce a result would pin their
            // consumed-ref records (and their ref holds) forever; drop
            // records whose task is already terminal (handled at
            // store_result) or unknown, releasing their refcounts
            // without reclaiming (TTL owns frames nobody completes
            // against). The refcount rows live on the REF's shard, so
            // dead entries are collected under the consumed lock and
            // the cross-shard decrements run after it drops.
            let dead: Vec<DataRef> = {
                let mut consumed = sh.consumed.lock().expect("consumed map poisoned");
                let mut dead = Vec::new();
                consumed.retain(|id, cref| {
                    let live = self.task_state(*id).map(|s| !s.is_terminal()).unwrap_or(false);
                    if !live {
                        dead.push(cref.clone());
                    }
                    live
                });
                dead
            };
            for cref in dead {
                let mut pending =
                    self.ref_shard(&cref).pending_refs.lock().expect("pending refs poisoned");
                match pending.get_mut(&ref_ident(&cref)) {
                    Some(n) if *n > 1 => *n -= 1,
                    _ => {
                        pending.remove(&ref_ident(&cref));
                    }
                }
            }
            purged += sh.kv.purge_expired(now);
        }
        purged
    }

    /// Connect an endpoint's agent link: spawns the forwarder (§4.1
    /// "a unique forwarder process is created for each endpoint") on
    /// the endpoint's owning shard.
    ///
    /// Peer auto-discovery (§5): the agent advertises its tiered store
    /// over the link and the forwarder peers EVERY shard fabric with it
    /// (recorded in the shared registry — the cross-shard advertisement
    /// replication), so `rref` results resolve on whichever shard owns
    /// the producing task; the forwarder advertises each shard's
    /// payload store downstream symmetrically for `iref`s. On
    /// reconnect, a previously advertised store re-peers immediately.
    pub fn connect_endpoint(
        &self,
        endpoint: EndpointId,
        link: crate::endpoint::ForwarderSide,
    ) -> Result<crate::service::ForwarderHandle> {
        self.registry.set_endpoint_status(endpoint, EndpointStatus::Online)?;
        if let Some(store) = self.registry.advertised_store(endpoint) {
            self.peer_store(store.owner(), store);
        }
        Ok(crate::service::forwarder::spawn(self.clone(), endpoint, link))
    }

    /// Decommission an endpoint (§4.1 under churn): the graceful
    /// retirement path [`crate::registry::Registry::withdraw_store`]
    /// was built for. Live frames the endpoint's advertised store owns
    /// are re-homed to other advertised stores under their replica
    /// keys — in-flight refs minted against this owner keep resolving
    /// via the fabric's replica failover — then the advertisement is
    /// withdrawn, every shard fabric drops its peer link, the spool is
    /// GC'd, and the endpoint is marked Offline. Requeue + drain stay
    /// within the owning shard (the forwarder and queue live there),
    /// while drain targets come from the shared registry, so replicas
    /// may land on peers registered via any shard. Returns the number
    /// of frames re-homed.
    pub fn decommission_endpoint(&self, endpoint: EndpointId) -> Result<usize> {
        let now = self.clock.now();
        let store = self.registry.advertised_store(endpoint);
        let mut drained = 0usize;
        if let Some(store) = &store {
            let targets: Vec<_> = self
                .registry
                .advertised_stores()
                .into_iter()
                .filter(|(ep, _)| *ep != endpoint)
                .collect();
            let copies = self.cfg.replication_factor.max(1);
            for key in store.live_keys(now) {
                // Replica copies this store held for *other* owners are
                // not re-homed: their owner (or its remaining replicas)
                // still serves them.
                if key.starts_with("replica:") {
                    continue;
                }
                let Ok(frame) = store.get(&key, now) else { continue };
                let dref = DataRef {
                    owner: store.owner(),
                    epoch: store.epoch(),
                    key: key.clone(),
                    size: frame.len() as u64,
                    checksum: crate::datastore::checksum(frame.as_slice()),
                    replicas: Vec::new(),
                };
                let rkey = dref.replica_key();
                let mut placed = false;
                for (_, target) in targets.iter().take(copies) {
                    placed |= target
                        .put_with_ttl(&rkey, frame.clone(), Some(self.cfg.result_ttl_s), now)
                        .is_ok();
                }
                if placed {
                    drained += 1;
                    crate::metrics::Counters::incr(&self.counters.frames_drained);
                    // Key-only event: the drain has no task identity —
                    // assembly joins it into timelines by ref key.
                    if self.recorder.enabled() {
                        self.recorder.record(
                            &format!("shard-{}", self.shard_map.shard_for_endpoint(endpoint)),
                            None,
                            None,
                            now,
                            TraceKind::FrameDrained { key: key.clone() },
                        );
                    }
                }
            }
        }
        self.registry.withdraw_store(endpoint);
        for sh in self.shards.iter() {
            sh.fabric.disconnect_peer(endpoint);
        }
        if let Some(store) = &store {
            store.purge_all();
        }
        self.registry.set_endpoint_status(endpoint, EndpointStatus::Offline)?;
        Ok(drained)
    }

    /// A ready-to-use admin identity + all-scope token (dev/test setup).
    pub fn bootstrap_user(&self, name: &str) -> (UserId, Token) {
        let u = self.auth.register_identity(name);
        let t = self
            .auth
            .issue_token(u, &[Scope::All], 365.0 * 86400.0, self.clock.now())
            .expect("identity just registered");
        (u, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::SERVICE_OWNER;

    fn svc() -> (FuncXService, Token, FunctionId, EndpointId) {
        let s = FuncXService::new(ServiceConfig::default());
        let (_u, tok) = s.bootstrap_user("alice");
        let f = s.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let e = s.register_endpoint(&tok, "laptop", "test endpoint").unwrap();
        (s, tok, f, e)
    }

    #[test]
    fn submit_enqueues_and_tracks_state() {
        let (s, tok, f, e) = svc();
        let r = s.submit(&tok, f, e, &Value::Null).unwrap();
        assert_eq!(s.task_state(r.task).unwrap(), TaskState::WaitingForEndpoint);
        assert_eq!(s.task_queue(e).len(), 1);
        assert_eq!(s.get_result(r.task).unwrap(), None); // not terminal yet
    }

    #[test]
    fn submit_rejects_bad_auth() {
        let (s, _tok, f, e) = svc();
        let mallory = s.auth.register_identity("mallory");
        let bad = s.auth.issue_token(mallory, &[Scope::RegisterFunction], 100.0, 0.0).unwrap();
        // No run_function scope.
        assert!(matches!(
            s.submit(&bad, f, e, &Value::Null),
            Err(Error::Forbidden(_)) | Err(Error::Unauthenticated(_))
        ));
    }

    #[test]
    fn submit_rejects_unshared_function() {
        let (s, _tok, f, e) = svc();
        let (_bob, bob_tok) = s.bootstrap_user("bob");
        // bob has scopes but no grant on alice's function.
        assert!(matches!(s.submit(&bob_tok, f, e, &Value::Null), Err(Error::Forbidden(_))));
        // After sharing both function and endpoint, submission works.
        let alice = s.registry.function(f).unwrap().owner;
        let bob = s.auth.check(&bob_tok, Scope::RunFunction, 0.0).unwrap();
        assert_ne!(alice, bob);
        s.auth.grant_function(f, bob);
        s.auth.grant_endpoint(e, bob);
        assert!(s.submit(&bob_tok, f, e, &Value::Null).is_ok());
    }

    #[test]
    fn oversized_payload_dispatches_by_ref() {
        let (s, tok, f, e) = svc();
        let big = Value::Bytes(vec![0xAB; 11 * 1024 * 1024]);
        let r = s.submit(&tok, f, e, &big).unwrap();
        assert_eq!(s.task_state(r.task).unwrap(), TaskState::WaitingForEndpoint);
        // The queued task carries a DataRef, not 11 MB of inline bytes.
        let task = s.task_queue(e).pop().unwrap().unwrap();
        let dref = task.input_ref.expect("oversized input must go by reference");
        assert!(dref.size > 10 * 1024 * 1024);
        assert_eq!(dref.owner, SERVICE_OWNER);
        assert!(task.input.len() < 100, "placeholder input only");
        // The frame resolves from the service store bit-for-bit.
        let frame = s.fabric.resolve(&dref, s.clock.now()).unwrap();
        assert_eq!(frame.len() as u64, dref.size);
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.tasks_ref_dispatched),
            1
        );
        assert!(crate::metrics::Counters::get(&s.counters.bytes_offloaded) > 10 * 1024 * 1024);
    }

    #[test]
    fn payload_cap_enforced_without_ref_dispatch() {
        let s = FuncXService::new(ServiceConfig { ref_dispatch: false, ..Default::default() });
        let (_u, tok) = s.bootstrap_user("alice");
        let f = s.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let e = s.register_endpoint(&tok, "laptop", "test endpoint").unwrap();
        let big = Value::Bytes(vec![0; 11 * 1024 * 1024]);
        assert!(matches!(
            s.submit(&tok, f, e, &big),
            Err(Error::PayloadTooLarge { .. })
        ));
    }

    #[test]
    fn unknown_ids_rejected() {
        let (s, tok, f, e) = svc();
        assert!(s.submit(&tok, FunctionId::new(), e, &Value::Null).is_err());
        assert!(s.submit(&tok, f, EndpointId::new(), &Value::Null).is_err());
        assert!(s.task_state(TaskId::new()).is_err());
    }

    #[test]
    fn batch_submit_produces_receipts() {
        let (s, tok, f, e) = svc();
        let mut b = BatchRequest::new(f, e);
        for i in 0..5 {
            b.add(&Value::Int(i)).unwrap();
        }
        let receipts = s.submit_batch(&tok, &b).unwrap();
        assert_eq!(receipts.len(), 5);
        assert_eq!(s.task_queue(e).len(), 5);
    }

    #[test]
    fn batch_admission_is_atomic_and_inline_capped() {
        let (s, tok, f, e) = svc();
        // Members under the per-task cap but summing over it: the batch
        // is rejected up front — nothing enqueued, nothing orphaned.
        let mut b = BatchRequest::new(f, e);
        for _ in 0..3 {
            b.add(&Value::Bytes(vec![0; 4 * 1024 * 1024])).unwrap();
        }
        b.add(&Value::Bytes(vec![0; 9 * 1024 * 1024])).unwrap();
        assert!(matches!(
            s.submit_batch(&tok, &b),
            Err(Error::PayloadTooLarge { .. })
        ));
        assert_eq!(s.task_queue(e).len(), 0, "rejected batch must enqueue nothing");
        // An oversized member offloads by ref while small siblings stay
        // inline; the batch passes because the *inline* bytes fit.
        let mut b = BatchRequest::new(f, e);
        b.add(&Value::Bytes(vec![1; 1024])).unwrap();
        b.add(&Value::Bytes(vec![2; 11 * 1024 * 1024])).unwrap();
        let receipts = s.submit_batch(&tok, &b).unwrap();
        assert_eq!(receipts.len(), 2);
        let t1 = s.task_queue(e).pop().unwrap().unwrap();
        let t2 = s.task_queue(e).pop().unwrap().unwrap();
        assert!(t1.input_ref.is_none());
        assert!(t2.input_ref.is_some());
    }

    #[test]
    fn by_ref_result_resolves_through_the_fabric() {
        let (s, tok, f, e) = svc();
        let r = s.submit(&tok, f, e, &Value::Null).unwrap();
        // The worker-side store holding the offloaded output, peered
        // with the service fabric (as connect_endpoint wiring would).
        let store = Arc::new(
            TieredStore::new(e, TieredConfig::default()).unwrap(),
        );
        s.fabric.connect_peer(e, store.clone());
        let out = Value::Bytes(vec![0x6B; 32 * 1024]);
        let frame = pack(&out, 0).unwrap();
        let dref = store.put(&format!("task-result:{}", r.task), frame, 0.0).unwrap();
        let tr = TaskResult {
            task: r.task,
            state: TaskState::Success,
            output: crate::serialize::Buffer::empty(),
            output_ref: Some(dref.clone()),
            exec_time_s: 0.0,
            cold_start: false,
        };
        s.store_result(&tr);
        // peek leaves the record in place; get_result resolves the ref.
        let peeked = s.peek_result(r.task).unwrap().unwrap();
        assert_eq!(peeked.output_ref, Some(dref.clone()));
        // Ref forwarding FIRST (retrieval reclaims the frame): a
        // follow-on task carries the same ref; the service enqueues it
        // without touching the bytes.
        let r2 = s.submit_by_ref(&tok, f, e, &dref).unwrap();
        let _first = s.task_queue(e).pop().unwrap().unwrap(); // r's task
        let task = s.task_queue(e).pop().unwrap().unwrap();
        assert_eq!(task.id, r2.task);
        assert_eq!(task.input_ref, Some(dref.clone()));
        assert_eq!(task.input.len(), 0);
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.tasks_ref_forwarded),
            1
        );
        assert_eq!(s.get_result(r.task).unwrap(), Some(out));
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.results_ref_offloaded),
            1
        );
        // Only the empty placeholder crossed the service queues.
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.result_bytes_through_service),
            0
        );
        // Result-frame GC, consumer-safe: the chain task r2 still holds
        // the ref, so retrieval must NOT reclaim the frame from under
        // it — the bytes stay resolvable for the pending consumer.
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.result_frames_reclaimed),
            0
        );
        assert!(
            s.fabric.resolve(&dref, s.clock.now()).is_ok(),
            "frame must survive retrieval while a chain consumer is pending"
        );
        // The last pending consumer's terminal result drains the hold
        // and reclaims the frame eagerly.
        let tr2 = TaskResult {
            task: r2.task,
            state: TaskState::Success,
            output: pack(&Value::Int(1), 0).unwrap(),
            output_ref: None,
            exec_time_s: 0.0,
            cold_start: false,
        };
        s.store_result(&tr2);
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.result_frames_reclaimed),
            1,
            "chain completion must reclaim the consumed frame"
        );
        assert!(store.is_empty(), "task-result frame reclaimed once its consumer finished");
    }

    #[test]
    fn by_ref_result_with_vanished_frame_is_typed_not_found() {
        let (s, tok, f, e) = svc();
        let r = s.submit(&tok, f, e, &Value::Null).unwrap();
        let dref = crate::datastore::DataRef {
            owner: EndpointId::new(), // never peered
            epoch: 3,
            key: "task-result:gone".into(),
            size: 64,
            checksum: 0,
            replicas: Vec::new(),
        };
        let tr = TaskResult {
            task: r.task,
            state: TaskState::Success,
            output: crate::serialize::Buffer::empty(),
            output_ref: Some(dref),
            exec_time_s: 0.0,
            cold_start: false,
        };
        s.store_result(&tr);
        assert!(matches!(s.get_result(r.task), Err(Error::NotFound(_))));
    }

    #[test]
    fn result_purged_after_retrieval() {
        let (s, tok, f, e) = svc();
        let r = s.submit(&tok, f, e, &Value::Null).unwrap();
        // Fake a completed result as the forwarder would store it.
        let tr = TaskResult {
            task: r.task,
            state: TaskState::Success,
            output: pack(&Value::Int(7), 0).unwrap(),
            output_ref: None,
            exec_time_s: 0.0,
            cold_start: false,
        };
        s.store_result(&tr);
        assert_eq!(s.get_result(r.task).unwrap(), Some(Value::Int(7)));
        // Second retrieval: purged.
        assert!(s.get_result(r.task).is_err());
    }

    #[test]
    fn failed_result_surfaces_error() {
        let (s, tok, f, e) = svc();
        let r = s.submit(&tok, f, e, &Value::Null).unwrap();
        let tr = TaskResult {
            task: r.task,
            state: TaskState::Failed,
            output: pack(&Value::Str("boom".into()), 0).unwrap(),
            output_ref: None,
            exec_time_s: 0.0,
            cold_start: false,
        };
        s.store_result(&tr);
        match s.get_result(r.task) {
            Err(Error::TaskFailed(m)) => assert_eq!(m, "boom"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn replicated_result_fails_over_after_owner_death() {
        let s = FuncXService::new(ServiceConfig {
            replication_factor: 1,
            ..ServiceConfig::default()
        });
        let (_u, tok) = s.bootstrap_user("alice");
        let f = s.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let e1 = s.register_endpoint(&tok, "owner", "dies before retrieval").unwrap();
        let e2 = s.register_endpoint(&tok, "holder", "keeps the replica").unwrap();
        let store1 = Arc::new(TieredStore::new(e1, TieredConfig::default()).unwrap());
        let store2 = Arc::new(TieredStore::new(e2, TieredConfig::default()).unwrap());
        for (ep, st) in [(e1, &store1), (e2, &store2)] {
            s.registry.advertise_store(ep, st.clone());
            s.fabric.connect_peer(ep, st.clone());
        }
        let r = s.submit(&tok, f, e1, &Value::Null).unwrap();
        let out = Value::Bytes(vec![0x5A; 48 * 1024]);
        let frame = pack(&out, 0).unwrap();
        let dref = store1.put(&format!("task-result:{}", r.task), frame, 0.0).unwrap();
        s.store_result(&TaskResult {
            task: r.task,
            state: TaskState::Success,
            output: crate::serialize::Buffer::empty(),
            output_ref: Some(dref.clone()),
            exec_time_s: 0.0,
            cold_start: false,
        });
        // The stored record's ref lists the replica holder and the copy
        // really landed in e2's store under the replica key.
        let stored = s.peek_result(r.task).unwrap().unwrap().output_ref.unwrap();
        assert_eq!(stored.replicas, vec![e2]);
        assert_eq!(crate::metrics::Counters::get(&s.counters.replicas_created), 1);
        assert!(store2.get(&dref.replica_key(), s.clock.now()).is_ok());
        // Owner dies before retrieval: sever its peer link and drop the
        // fabric's cached copy (reclaim leaves the replica alone).
        s.fabric.disconnect_peer(e1);
        s.fabric.reclaim(&dref);
        drop(store1);
        assert_eq!(s.get_result(r.task).unwrap(), Some(out));
        assert!(
            crate::metrics::Counters::get(&s.counters.failover_resolutions) >= 1,
            "retrieval after owner death must count a failover resolution"
        );
        // Still zero inline result bytes: failover stays by-reference.
        assert_eq!(
            crate::metrics::Counters::get(&s.counters.result_bytes_through_service),
            0
        );
    }

    #[test]
    fn decommission_rehomes_frames_and_clears_advertisement() {
        let (s, tok, _f, e) = svc();
        let e2 = s.register_endpoint(&tok, "survivor", "takes the drain").unwrap();
        let store = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
        let store2 = Arc::new(TieredStore::new(e2, TieredConfig::default()).unwrap());
        for (ep, st) in [(e, &store), (e2, &store2)] {
            s.registry.advertise_store(ep, st.clone());
            s.fabric.connect_peer(ep, st.clone());
        }
        let frame = pack(&Value::Bytes(vec![0x11; 8 * 1024]), 0).unwrap();
        let dref = store.put("task-result:keep", frame.clone(), 0.0).unwrap();
        // A replica copy this store held for some other owner is NOT
        // re-homed — its owner still serves it.
        store.put("replica:someone:1:other", pack(&Value::Int(1), 0).unwrap(), 0.0).unwrap();
        assert_eq!(s.decommission_endpoint(e).unwrap(), 1);
        // Advertisement withdrawn, spool GC'd, endpoint offline.
        assert!(s.registry.advertised_store(e).is_none());
        assert!(store.is_empty(), "purge_all reaps every entry");
        assert_eq!(s.registry.endpoint(e).unwrap().status, EndpointStatus::Offline);
        assert_eq!(crate::metrics::Counters::get(&s.counters.frames_drained), 1);
        // The re-homed frame keeps serving the in-flight ref via the
        // fabric's replica scan.
        let got = s.fabric.resolve(&dref, s.clock.now()).unwrap();
        assert_eq!(got.as_slice(), frame.as_slice());
        assert!(crate::metrics::Counters::get(&s.counters.failover_resolutions) >= 1);
    }

    #[test]
    fn sharded_service_routes_and_cross_resolves() {
        let s = FuncXService::new(ServiceConfig { service_shards: 4, ..Default::default() });
        assert_eq!(s.shard_count(), 4);
        let (_u, tok) = s.bootstrap_user("alice");
        let f = s.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let e = s.register_endpoint(&tok, "laptop", "sharded endpoint").unwrap();
        // Small tasks land spread across shards but queue on the one
        // endpoint queue (owned by the endpoint's shard).
        for _ in 0..16 {
            s.submit(&tok, f, e, &Value::Null).unwrap();
        }
        assert_eq!(s.task_queue(e).len(), 16);
        // An oversized input offloads into its TASK shard's store; any
        // shard's fabric resolves it through the cross-shard peer mesh
        // (here: shard 0's public handle).
        let big = Value::Bytes(vec![0xCD; 11 * 1024 * 1024]);
        let r = s.submit(&tok, f, e, &big).unwrap();
        let q = s.task_queue(e);
        let mut dref = None;
        while let Some(t) = q.pop().unwrap() {
            if t.id == r.task {
                dref = t.input_ref.clone();
            }
        }
        let dref = dref.expect("oversized input must go by reference");
        let own_shard = s.shard_map().shard_for_task(r.task);
        assert_eq!(dref.owner, shard_owner(own_shard));
        let frame = s.fabric.resolve(&dref, s.clock.now()).unwrap();
        assert_eq!(frame.len() as u64, dref.size);
        // The result round-trips through the owning shard.
        s.store_result(&TaskResult {
            task: r.task,
            state: TaskState::Success,
            output: pack(&Value::Int(9), 0).unwrap(),
            output_ref: None,
            exec_time_s: 0.0,
            cold_start: false,
        });
        assert_eq!(s.get_result(r.task).unwrap(), Some(Value::Int(9)));
    }
}
