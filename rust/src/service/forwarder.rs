//! §4.1 — the per-endpoint forwarder.
//!
//! Listens on the endpoint's Redis task queue, dispatches tasks down the
//! agent link, persists returned results, and enforces the reliability
//! contract: tasks are cached in an in-flight set and, when the agent is
//! lost (missed heartbeats / dead link), returned to the *front* of the
//! task queue for re-dispatch on reconnect; tasks exceeding the
//! re-dispatch budget are marked Abandoned.
//!
//! The loop is event-driven: it blocks on a single wakeup latch
//! signalled by (a) pushes to this endpoint's task queue (a KV watch),
//! (b) upstream traffic on the agent link, and (c) shutdown — bounded by
//! the heartbeat period so agent-loss deadlines are still enforced.
//! Under load it never sleeps; idle it never spins.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::common::ids::{EndpointId, TaskId};
use crate::common::task::{Task, TaskState};
use crate::endpoint::{Downstream, ForwarderSide, Upstream};
use crate::metrics::TraceKind;
use crate::registry::EndpointStatus;
use crate::service::api::FuncXService;

/// Externally-readable forwarder statistics.
#[derive(Default)]
pub struct ForwarderStats {
    pub dispatched: AtomicU64,
    /// Subset of `dispatched` that carried a `DataRef` instead of
    /// inline input bytes (§5 pass-by-reference dispatch).
    pub ref_dispatched: AtomicU64,
    pub results: AtomicU64,
    /// Subset of `results` whose output returned as a `DataRef`
    /// (`"rref"`; §5 result offload).
    pub ref_results: AtomicU64,
    pub heartbeats: AtomicU64,
    pub requeued: AtomicU64,
    pub abandoned: AtomicU64,
}

/// Handle to a running forwarder thread.
pub struct ForwarderHandle {
    pub stats: Arc<ForwarderStats>,
    stop: Arc<AtomicBool>,
    decommission: Arc<AtomicBool>,
    wake: Arc<crate::common::sync::Notify>,
    thread: Option<JoinHandle<()>>,
}

impl ForwarderHandle {
    /// Signal shutdown (sends Shutdown to the agent) and join.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.wake.notify(); // pull the loop out of its blocking wait
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    /// Retire the endpoint gracefully (§4.1 churn): ask the agent to
    /// drain and deregister, run the service-side decommission (frame
    /// drain to replicas, store withdrawal, fabric disconnect, spool
    /// GC, Offline) once it signs off, and join. Tasks the agent never
    /// finished are requeued for whichever endpoint reconnects.
    pub fn decommission(mut self) {
        self.decommission.store(true, Ordering::Relaxed);
        self.wake.notify();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    /// `(signals published, waits actually woken)` on this forwarder's
    /// latch — the watch-wakeup baseline the hotpath bench tracks so
    /// coalescing work (ROADMAP "watch granularity") starts from
    /// measurements, not guesses.
    pub fn wake_counters(&self) -> (u64, u64) {
        (self.wake.notify_count(), self.wake.wakeup_count())
    }
}

pub(crate) fn spawn(
    svc: FuncXService,
    endpoint: EndpointId,
    link: ForwarderSide,
) -> ForwarderHandle {
    let stats = Arc::new(ForwarderStats::default());
    let stop = Arc::new(AtomicBool::new(false));
    let decommission = Arc::new(AtomicBool::new(false));
    let wake = link.wake_handle();
    let st = stats.clone();
    let sp = stop.clone();
    let dc = decommission.clone();
    let thread = std::thread::Builder::new()
        .name(format!("funcx-forwarder-{endpoint}"))
        .spawn(move || forwarder_loop(svc, endpoint, link, st, sp, dc))
        .expect("spawn forwarder");
    ForwarderHandle { stats, stop, decommission, wake, thread: Some(thread) }
}

fn forwarder_loop(
    svc: FuncXService,
    endpoint: EndpointId,
    link: ForwarderSide,
    stats: Arc<ForwarderStats>,
    stop: Arc<AtomicBool>,
    decommission: Arc<AtomicBool>,
) {
    let queue = svc.task_queue(endpoint);
    // This forwarder's flight-recorder component: it runs on the
    // endpoint's owning shard.
    let component = format!("shard-{}", svc.shard_map().shard_for_endpoint(endpoint));
    // One latch, three wake sources: upstream link traffic (wired in by
    // `link()`), pushes to this endpoint's task queue, and shutdown.
    let wake = link.wake_handle();
    queue.watch(wake.clone());
    // Advertise EVERY shard's payload store down the link so the
    // agent's fabric auto-peers for `iref` resolution no matter which
    // shard offloaded the input (§5 peer auto-discovery; the agent
    // advertises its own store upstream symmetrically). Each store
    // carries its own shard-owner id, so the agent-side handler needs
    // no shard awareness — one Advertise per store, keyed by owner.
    for store in svc.shard_stores() {
        let _ = link.send(Downstream::Advertise(store));
    }
    // Tasks sent to the agent but not yet completed (§4.1 ack cache).
    // Shared handles: caching a task and framing it onto the link are
    // refcount bumps on one allocation, not clones of the record (whose
    // input is itself a view into the queue frame it was popped from).
    let mut in_flight: HashMap<TaskId, Arc<Task>> = HashMap::new();
    // Per-task re-dispatch counts.
    let mut redispatches: HashMap<TaskId, u32> = HashMap::new();
    let mut last_heartbeat = svc.clock.now();
    // Decommission request relayed downstream (sent once); dispatch is
    // fenced while we wait for the agent's Deregister sign-off.
    let mut decommission_sent = false;

    loop {
        // Epoch snapshot before EVERY check below — including stop: a
        // shutdown() (store + notify) racing past the stop check bumps
        // the epoch after this read and voids the idle wait.
        let seen = wake.epoch();
        if stop.load(Ordering::Relaxed) {
            let _ = link.send(Downstream::Shutdown);
            break;
        }
        if decommission.load(Ordering::Relaxed) && !decommission_sent {
            decommission_sent = true;
            let _ = link.send(Downstream::Decommission);
        }
        let mut progressed = false;
        let now = svc.clock.now();

        // Agent-loss detection (§4.1): missed heartbeats or dead link.
        let deadline = svc.cfg.heartbeat_period_s * (svc.cfg.heartbeat_misses_allowed as f64 + 1.0);
        let lost = !link.is_alive() || (now - last_heartbeat) > deadline;
        if lost {
            let _ = svc.registry.set_endpoint_status(endpoint, EndpointStatus::Lost);
            // Return all dispatched-but-unfinished tasks to the front of
            // the queue so they are re-forwarded on reconnect (§4.1).
            for (id, task) in in_flight.drain() {
                let n = redispatches.entry(id).or_insert(0);
                *n += 1;
                if *n > svc.cfg.max_redispatch {
                    svc.set_state(id, TaskState::Abandoned);
                    let r = crate::common::task::TaskResult {
                        task: id,
                        state: TaskState::Abandoned,
                        output: crate::serialize::Buffer::empty(),
                        output_ref: None,
                        exec_time_s: 0.0,
                        cold_start: false,
                    };
                    svc.store_result(&r);
                    stats.abandoned.fetch_add(1, Ordering::Relaxed);
                } else {
                    let _ = queue.push_front(task.as_ref());
                    svc.set_state(id, TaskState::WaitingForEndpoint);
                    stats.requeued.fetch_add(1, Ordering::Relaxed);
                    crate::metrics::Counters::incr(&svc.counters.tasks_redispatched);
                    if svc.recorder.enabled() {
                        svc.recorder.record(
                            &component,
                            task.trace,
                            Some(id),
                            now,
                            TraceKind::Redispatched { attempt: *n },
                        );
                    }
                }
            }
            break; // this forwarder's link is done; reconnect spawns a new one
        }

        // Dispatch a batch of queued tasks to the agent. (The seed's
        // always-true `batch_is_empty_hint` made the loop sleep 500 µs
        // even after dispatching a *full* batch; now a non-empty batch
        // counts as progress and the loop re-runs immediately.)
        let batch: Vec<Arc<Task>> = if decommission_sent {
            Vec::new() // retiring: queued tasks wait for a successor endpoint
        } else {
            queue.pop_n(64).unwrap_or_default().into_iter().map(Arc::new).collect()
        };
        if !batch.is_empty() {
            progressed = true;
            let now = svc.clock.now();
            for t in &batch {
                in_flight.insert(t.id, t.clone());
                svc.set_state(t.id, TaskState::WaitingForNodes);
                svc.latency.on_forwarded(t.id, now);
                if svc.recorder.enabled() {
                    svc.recorder.record(
                        &component,
                        t.trace,
                        Some(t.id),
                        now,
                        TraceKind::Forwarded { endpoint },
                    );
                }
            }
            stats.dispatched.fetch_add(batch.len() as u64, Ordering::Relaxed);
            let refs = batch.iter().filter(|t| t.dispatches_by_ref()).count() as u64;
            if refs > 0 {
                stats.ref_dispatched.fetch_add(refs, Ordering::Relaxed);
            }
            if !link.send(Downstream::Tasks(batch)) {
                continue; // next iteration handles the lost link
            }
        }

        // Drain upstream messages.
        while let Some(msg) = link.try_recv() {
            progressed = true;
            match msg {
                Upstream::Results(rs) => {
                    for r in rs {
                        in_flight.remove(&r.task);
                        redispatches.remove(&r.task);
                        // Count before storing: store_result wakes
                        // result waiters, who may read the stats.
                        stats.results.fetch_add(1, Ordering::Relaxed);
                        if r.returns_by_ref() {
                            stats.ref_results.fetch_add(1, Ordering::Relaxed);
                        }
                        svc.store_result(&r);
                    }
                }
                Upstream::Advertise(store) => {
                    // The endpoint's tiered store: record it in the
                    // shared registry (visible to every shard — the
                    // cross-shard advertisement replication) and peer
                    // EVERY shard's fabric so `rref` results resolve on
                    // whichever shard owns the producing task.
                    svc.registry.advertise_store(endpoint, store.clone());
                    svc.peer_store(store.owner(), store);
                }
                Upstream::Heartbeat { .. } => {
                    last_heartbeat = svc.clock.now();
                    stats.heartbeats.fetch_add(1, Ordering::Relaxed);
                    crate::metrics::Counters::incr(&svc.counters.heartbeats);
                }
                Upstream::Deregister => {
                    // Orderly retirement: everything the agent will ever
                    // send has arrived (results precede Deregister in
                    // FIFO order). Requeue what it never finished, then
                    // run the service-side decommission — frame drain to
                    // replicas, advertisement withdrawal, fabric
                    // disconnect, spool GC, Offline.
                    for (id, task) in in_flight.drain() {
                        redispatches.remove(&id);
                        let _ = queue.push_front(task.as_ref());
                        svc.set_state(id, TaskState::WaitingForEndpoint);
                        stats.requeued.fetch_add(1, Ordering::Relaxed);
                        if svc.recorder.enabled() {
                            svc.recorder.record(
                                &component,
                                task.trace,
                                Some(id),
                                svc.clock.now(),
                                TraceKind::DecommissionRequeued { endpoint },
                            );
                        }
                    }
                    let _ = svc.decommission_endpoint(endpoint);
                    return;
                }
            }
        }

        if !progressed {
            // Nothing to do: block until a push/result/shutdown arrives,
            // bounded by the heartbeat period so the agent-loss deadline
            // above is still checked on time.
            let bound = Duration::from_secs_f64(svc.cfg.heartbeat_period_s.max(1e-3));
            wake.wait_newer(seen, bound);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{EndpointConfig, ServiceConfig};
    use crate::common::task::Payload;
    use crate::endpoint::{link, EndpointBuilder};
    use crate::serialize::Value;

    /// Full live round trip: SDK-style submit → queue → forwarder →
    /// agent → manager → worker → result → retrieval.
    #[test]
    fn live_round_trip() {
        let svc = FuncXService::new(ServiceConfig::default());
        let (_u, tok) = svc.bootstrap_user("alice");
        let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
        let e = svc.register_endpoint(&tok, "laptop", "").unwrap();

        let (fwd_side, agent_side) = link();
        let handle = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
            .heartbeat_period(0.05)
            .start(agent_side);
        let fh = svc.connect_endpoint(e, fwd_side).unwrap();
        assert_eq!(svc.registry.endpoint(e).unwrap().status, EndpointStatus::Online);

        let input = Value::map([("x", Value::Int(42))]);
        let r = svc.submit(&tok, f, e, &input).unwrap();
        let out = svc.wait_result(r.task, Duration::from_secs(10)).unwrap();
        assert_eq!(out, input);
        assert_eq!(svc.task_state(r.task).unwrap(), TaskState::Success);

        fh.shutdown();
        handle.join();
    }

    /// §4.1 fault tolerance: tasks in flight when the agent dies are
    /// returned to the queue front and the endpoint is marked Lost.
    #[test]
    fn agent_loss_requeues_in_flight() {
        let mut cfg = ServiceConfig::default();
        cfg.heartbeat_period_s = 0.05;
        cfg.heartbeat_misses_allowed = 1;
        let svc = FuncXService::new(cfg);
        let (_u, tok) = svc.bootstrap_user("alice");
        let f = svc.register_function(&tok, "slow", Payload::Sleep(30.0), None).unwrap();
        let e = svc.register_endpoint(&tok, "flaky", "").unwrap();

        let (fwd_side, agent_side) = link();
        // Sever immediately: agent never picks tasks up, never heartbeats.
        agent_side.sever();
        drop(agent_side);

        let fh = svc.connect_endpoint(e, fwd_side).unwrap();
        let r = svc.submit(&tok, f, e, &Value::Null).unwrap();

        // Give the forwarder time to dispatch and detect the dead link.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while svc.registry.endpoint(e).unwrap().status != EndpointStatus::Lost
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(svc.registry.endpoint(e).unwrap().status, EndpointStatus::Lost);
        // The task is back in the queue (or was never dispatched).
        assert_eq!(svc.task_queue(e).len(), 1);
        assert_eq!(svc.task_state(r.task).unwrap(), TaskState::WaitingForEndpoint);
        fh.shutdown();

        // Reconnect with a healthy agent: the task completes.
        let (fwd2, agent2) = link();
        let handle = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
            .heartbeat_period(0.02)
            .start(agent2);
        // Re-register the fast function body under the same task? No — the
        // task still carries Sleep(30). Replace: drain and resubmit a fast
        // one to prove the path works end-to-end post-reconnect.
        let _ = svc.task_queue(e).pop().unwrap();
        let f2 = svc.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let fh2 = svc.connect_endpoint(e, fwd2).unwrap();
        let r2 = svc.submit(&tok, f2, e, &Value::Null).unwrap();
        svc.wait_result(r2.task, Duration::from_secs(10)).unwrap();
        fh2.shutdown();
        handle.join();
    }

    /// The seed's `batch_is_empty_hint` was always-true, so the
    /// forwarder slept 500 µs per iteration even right after dispatching
    /// a full batch — and submissions landing while it slept waited out
    /// the nap. Now dispatch is wakeup-driven: a task submitted to an
    /// *idle* stack (forwarder blocked in its wait) must be picked up by
    /// the queue-watch notification, not a poll tick, and a saturating
    /// burst must drain without idle naps in between.
    #[test]
    fn wakeup_driven_dispatch_not_throttled() {
        let svc = FuncXService::new(ServiceConfig::default());
        let (_u, tok) = svc.bootstrap_user("alice");
        let f = svc.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let e = svc.register_endpoint(&tok, "node", "").unwrap();
        let (fwd_side, agent_side) = link();
        let handle = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 4, ..Default::default() })
            .heartbeat_period(0.05)
            .start(agent_side);
        let fh = svc.connect_endpoint(e, fwd_side).unwrap();

        // Let the stack go fully idle (forwarder blocked on its latch —
        // with the default 30 s heartbeat period a poll-based loop would
        // otherwise be napping).
        std::thread::sleep(Duration::from_millis(100));

        // An idle-path submit completes promptly (push → watch → dispatch).
        let r = svc.submit(&tok, f, e, &Value::Null).unwrap();
        svc.wait_result(r.task, Duration::from_secs(5)).unwrap();

        // A burst larger than several dispatch batches drains fully.
        let receipts: Vec<_> =
            (0..300).map(|_| svc.submit(&tok, f, e, &Value::Null).unwrap()).collect();
        for r in &receipts {
            svc.wait_result(r.task, Duration::from_secs(30)).unwrap();
        }
        assert_eq!(fh.stats.dispatched.load(Ordering::Relaxed), 301);
        assert_eq!(fh.stats.results.load(Ordering::Relaxed), 301);
        fh.shutdown();
        handle.join();
    }

    /// Graceful retirement end to end: the agent drains and signs off
    /// with Deregister; the forwarder runs the service-side
    /// decommission — advertisement withdrawn, spool GC'd, endpoint
    /// Offline — and both threads exit.
    #[test]
    fn decommission_retires_endpoint_cleanly() {
        use crate::datastore::{DataFabric, TieredConfig, TieredStore};
        let svc = FuncXService::new(ServiceConfig::default());
        let (_u, tok) = svc.bootstrap_user("alice");
        let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
        let e = svc.register_endpoint(&tok, "retiring", "").unwrap();

        let store = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
        let fabric = Arc::new(DataFabric::new(store.clone()));
        let (fwd_side, agent_side) = link();
        let handle = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
            .fabric(fabric)
            .heartbeat_period(0.05)
            .start(agent_side);
        let fh = svc.connect_endpoint(e, fwd_side).unwrap();

        let input = Value::map([("x", Value::Int(7))]);
        let r = svc.submit(&tok, f, e, &input).unwrap();
        assert_eq!(svc.wait_result(r.task, Duration::from_secs(10)).unwrap(), input);
        // The agent advertised its store on connect.
        assert!(svc.registry.advertised_store(e).is_some());
        // Park a frame in the endpoint store so decommission has
        // something to GC (no peers are advertised, so it cannot be
        // re-homed — the spool must still come out clean).
        store
            .put("task-result:leftover", crate::serialize::Buffer::from_vec(vec![9; 2048]), 0.0)
            .unwrap();

        fh.decommission();
        handle.join();
        assert_eq!(svc.registry.endpoint(e).unwrap().status, EndpointStatus::Offline);
        assert!(svc.registry.advertised_store(e).is_none(), "advertisement withdrawn");
        assert!(store.is_empty(), "decommission GCs the retired store");
    }

    /// 200-task smoke through the full stack with 4 workers.
    #[test]
    fn sustained_load_conserves_tasks() {
        let svc = FuncXService::new(ServiceConfig::default());
        let (_u, tok) = svc.bootstrap_user("alice");
        let f = svc.register_function(&tok, "noop", Payload::Noop, None).unwrap();
        let e = svc.register_endpoint(&tok, "node", "").unwrap();
        let (fwd_side, agent_side) = link();
        let handle = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 2, workers_per_node: 2, ..Default::default() })
            .heartbeat_period(0.05)
            .start(agent_side);
        let fh = svc.connect_endpoint(e, fwd_side).unwrap();

        let receipts: Vec<_> =
            (0..200).map(|_| svc.submit(&tok, f, e, &Value::Null).unwrap()).collect();
        for r in &receipts {
            svc.wait_result(r.task, Duration::from_secs(30)).unwrap();
        }
        assert_eq!(
            crate::metrics::Counters::get(&svc.counters.tasks_completed),
            200
        );
        fh.shutdown();
        handle.join();
    }
}
