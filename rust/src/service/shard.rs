//! Consistent-hash shard map for the service plane.
//!
//! The paper scales funcX by replicating the cloud service horizontally;
//! here the service plane is split N ways and every piece of per-task /
//! per-endpoint state lives on exactly one shard. Placement must be
//! *consistent* — the same id always lands on the same shard, and growing
//! the plane relocates as little state as possible — so the map is built
//! on Lamping & Veach's jump consistent hash: deterministic, within a
//! couple of percent of perfectly balanced, and growing from N to N+1
//! shards moves only the ~1/(N+1) of keys that belong on the new shard
//! (every other key stays put).
//!
//! The same [`ShardMap`] value is shared verbatim with clients (the SDK's
//! shard map) and the simulator, so client-side routing, the live
//! service, and simulated placement can never disagree.

use crate::common::ids::{EndpointId, TaskId, Uuid};

/// Jump consistent hash (Lamping & Veach, 2014): maps `key` onto
/// `0..buckets` with no lookup table and no ring state.
pub fn jump_hash(mut key: u64, buckets: usize) -> usize {
    debug_assert!(buckets > 0);
    let mut b: i64 = -1;
    let mut j: i64 = 0;
    while j < buckets as i64 {
        b = j;
        key = key.wrapping_mul(2862933555777941757).wrapping_add(1);
        j = (((b + 1) as f64) * ((1u64 << 31) as f64 / (((key >> 33) + 1) as f64))) as i64;
    }
    b as usize
}

/// splitmix64 finalizer: ids are structured (v4 version/variant bits,
/// registry-assigned low words), so bits are scrambled before jumping.
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Fold a 128-bit id into the 64-bit jump key.
fn fold_id(u: Uuid) -> u64 {
    (u.0 as u64) ^ ((u.0 >> 64) as u64)
}

/// FNV-1a over a string key (ref identities).
fn fnv64(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// The consistent-hash ring: a pure value (just the shard count) shared
/// by the service, the SDK, and the simulator. Tasks hash by task id,
/// endpoints by endpoint id, forwarded refs by their ref identity —
/// three independent key spaces over the same ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMap {
    n: usize,
}

impl ShardMap {
    pub fn new(n: usize) -> Self {
        ShardMap { n: n.max(1) }
    }

    /// Number of shards in the ring.
    pub fn shards(&self) -> usize {
        self.n
    }

    /// The shard owning a task's state (record, state hash, result row,
    /// offloaded input, result latch).
    pub fn shard_for_task(&self, id: TaskId) -> usize {
        jump_hash(mix64(fold_id(id.0)), self.n)
    }

    /// The shard owning an endpoint's queue and forwarder.
    pub fn shard_for_endpoint(&self, id: EndpointId) -> usize {
        jump_hash(mix64(fold_id(id.0)), self.n)
    }

    /// The shard owning a string-keyed row (forwarded-ref refcounts):
    /// producer and consumers may live on different task shards, so the
    /// refcount hashes by the *ref's* identity, reachable from both.
    pub fn shard_for_key(&self, key: &str) -> usize {
        jump_hash(mix64(fnv64(key)), self.n)
    }
}

/// The owner id shard `i`'s service payload store advertises frames
/// under. Shard 0 keeps the historical
/// [`crate::datastore::SERVICE_OWNER`] (the nil id) so single-shard
/// deployments are bit-compatible with the unsharded service; higher
/// shards use the low ids 1..N, which cannot collide with real endpoint
/// ids (those carry random v4 bits).
pub fn shard_owner(i: usize) -> EndpointId {
    EndpointId(Uuid(i as u128))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    fn task(g: &mut Gen) -> TaskId {
        TaskId(Uuid(((g.u64() as u128) << 64) | g.u64() as u128))
    }

    #[test]
    fn shard_owner_zero_is_service_owner() {
        assert_eq!(shard_owner(0), crate::datastore::SERVICE_OWNER);
        assert_ne!(shard_owner(1), shard_owner(2));
    }

    #[test]
    fn single_shard_maps_everything_to_zero() {
        check("single-shard", 50, |g| {
            let m = ShardMap::new(1);
            assert_eq!(m.shard_for_task(task(g)), 0);
            assert_eq!(m.shard_for_key(&g.string(24)), 0);
        });
    }

    /// Assignment is a pure function of (id, N) — two maps with the same
    /// shard count agree on every key, across key spaces.
    #[test]
    fn prop_assignment_deterministic() {
        check("shard-map determinism", 50, |g| {
            let n = *g.choose(&[2usize, 4, 8]);
            let (a, b) = (ShardMap::new(n), ShardMap::new(n));
            let t = task(g);
            let e = EndpointId(Uuid(((g.u64() as u128) << 64) | g.u64() as u128));
            let k = g.string(32);
            assert_eq!(a.shard_for_task(t), b.shard_for_task(t));
            assert_eq!(a.shard_for_endpoint(e), b.shard_for_endpoint(e));
            assert_eq!(a.shard_for_key(&k), b.shard_for_key(&k));
            assert!(a.shard_for_task(t) < n);
        });
    }

    /// No shard holds more than 2× its ideal share at N ∈ {2, 4, 8}.
    /// With 16 384 keys the worst-case ideal share is 2048 (σ ≈ 42), so
    /// the 2× bound sits dozens of standard deviations out — this pins
    /// hash quality, not luck.
    #[test]
    fn prop_balance_within_2x_of_ideal() {
        check("shard-map balance", 8, |g| {
            for n in [2usize, 4, 8] {
                let m = ShardMap::new(n);
                const KEYS: usize = 16_384;
                let mut counts = vec![0usize; n];
                for _ in 0..KEYS {
                    counts[m.shard_for_task(task(g))] += 1;
                }
                let ideal = KEYS / n;
                for (shard, c) in counts.iter().enumerate() {
                    assert!(
                        *c <= 2 * ideal,
                        "shard {shard}/{n} holds {c} of {KEYS} keys (ideal {ideal})"
                    );
                    assert!(*c > 0, "shard {shard}/{n} got no keys at all");
                }
            }
        });
    }

    /// Growing the ring from N to N+1 moves < 1/N of keys, and — the
    /// structural jump-hash guarantee — every moved key lands on the NEW
    /// shard: no key ever shuffles between existing shards.
    #[test]
    fn prop_growth_moves_less_than_one_nth_only_to_new_shard() {
        check("shard-map growth stability", 8, |g| {
            for n in [2usize, 4, 8] {
                let (old, new) = (ShardMap::new(n), ShardMap::new(n + 1));
                const KEYS: usize = 16_384;
                let mut moved = 0usize;
                for _ in 0..KEYS {
                    let t = task(g);
                    let (a, b) = (old.shard_for_task(t), new.shard_for_task(t));
                    if a != b {
                        moved += 1;
                        assert_eq!(b, n, "a moved key may only land on the new shard");
                    }
                }
                assert!(
                    moved < KEYS / n,
                    "growing {n}→{} moved {moved}/{KEYS} keys (bound {})",
                    n + 1,
                    KEYS / n
                );
            }
        });
    }
}
