//! §4.1 — the cloud-hosted funcX service.
//!
//! The service exposes the REST-equivalent API (register/submit/monitor/
//! retrieve), stores tasks in the Redis-subset store, maintains one task
//! queue + result store per endpoint, and runs a *forwarder* per
//! connected endpoint that dispatches tasks over the agent link and
//! persists returned results (Fig. 2's lifecycle).

mod api;
mod forwarder;

pub use api::{FuncXService, SubmitReceipt};
pub use forwarder::ForwarderHandle;
