//! §4.1 — the cloud-hosted funcX service.
//!
//! The service exposes the REST-equivalent API (register/submit/monitor/
//! retrieve), stores tasks in the Redis-subset store, maintains one task
//! queue + result store per endpoint, and runs a *forwarder* per
//! connected endpoint that dispatches tasks over the agent link and
//! persists returned results (Fig. 2's lifecycle).
//!
//! The plane is sharded N ways behind the consistent-hash
//! [`ShardMap`] (see `docs/architecture.md`): each shard owns a private
//! KV store, payload store, and result latch; forwarders run on the
//! shard owning their endpoint.

mod api;
mod forwarder;
pub mod shard;

pub use api::{FuncXService, SubmitReceipt};
pub use forwarder::ForwarderHandle;
pub use shard::{shard_owner, ShardMap};
