//! The tiered payload store: memory tier + disk tier behind one index.
//!
//! Frames land in the memory tier; once the tier's resident bytes exceed
//! the configured high-watermark, a background spiller moves
//! least-recently-used frames to the disk tier as raw wire bytes. A
//! disk-tier hit promotes the frame back to memory when it fits without
//! displacing residents (promotion never cascades into spills, so a
//! frame larger than the remaining headroom simply keeps serving from
//! disk). Every entry carries an optional TTL; expired entries resolve
//! to [`Error::NotFound`] and are removed lazily on access or eagerly
//! via [`TieredStore::evict_expired`].
//!
//! The store never decodes a frame: spill writes the frame's bytes,
//! reload wraps the read bytes in a fresh shared allocation, and a
//! memory-tier hit returns another handle on the *original* allocation
//! (pointer-pinned in `tests/data_fabric.rs`).
//!
//! # Concurrency: the per-key state machine
//!
//! Each entry moves through [`EntryState`]:
//!
//! ```text
//!            put                    spill commit
//!   (new) ────────► Resident ─────────────────────► OnDisk
//!                      ▲   └─► Spilling ──┘           │ promote mark
//!                      │         (bg spiller,         ▼
//!                      └────── Promoting ◄── disk hit w/ headroom
//!                    promote commit
//!
//!   any state ──(TTL lapse / remove)──► Expired (entry reaped)
//! ```
//!
//! The index mutex guards **metadata only** — state tags, sizes, LRU
//! seqs, and the O(1) frame *handles* of memory-resident entries. No
//! backend I/O ever runs under it:
//!
//! * **Spill** (background thread): pop the LRU victim, mark it
//!   `Spilling` (the entry keeps its live `Buffer` handle), drop the
//!   lock, write the spool file, re-acquire to commit `OnDisk`.
//!   Concurrent `get`s of a `Spilling` key are served from the
//!   still-live handle with zero blocking — a stalled spool write
//!   cannot delay a memory-tier hit (pinned below with a blocking fake
//!   spool).
//! * **Promote** (symmetric): a disk hit with headroom marks
//!   `Promoting` (reserving the bytes), drops the lock, reads the spool
//!   file, re-acquires to commit `Resident`. Concurrent `get`s of a
//!   `Promoting` key read the spool file themselves (it stays in place
//!   until the commit) or retry into the committed handle.
//! * **`put` never pays disk latency**: it installs the frame handle,
//!   bumps the generation, and nudges the spiller when the watermark is
//!   crossed.
//!
//! Every `put` of a key bumps its **generation** (and every spill
//! re-stamps it); transition commits re-check the generation, so an
//! overwrite or removal that lands mid-transition makes the in-flight
//! worker abandon its artifact instead of clobbering newer data. Spool
//! files are keyed `key#generation` and each name is written exactly
//! once, so no two generations ever share a file and a reader can
//! never observe a partially-written one.
//!
//! # Clock contract
//!
//! Like [`crate::store::KvStore`]'s TTL ops, every method takes the
//! caller's clock reading so the simulator can drive expiry under
//! virtual time. By default all parties touching one store — the owner
//! writing frames and any fabric resolving against it — MUST share a
//! clock (e.g. pass the service's clock to `EndpointBuilder::clock`).
//! For cross-endpoint deployments where that cannot hold, pin the store
//! with [`TieredStore::with_owner_clock`]: expiry stamps *and* expiry
//! decisions then both read the owner's clock and readers' skewed `now`
//! arguments are ignored for TTL purposes, so a resolver whose clock
//! runs fast cannot expire a live entry and one running slow cannot
//! resurrect a dead one (owner-stamped expiry; pinned in
//! `tests/fabric_faults.rs`).
//!
//! # Crash recovery
//!
//! The disk tier's epoch-stamped, append-only manifest log (see
//! [`crate::datastore::DiskBackend`]) makes spilled frames survive a
//! crash: [`TieredStore::recover`] replays the log and readopts every
//! entry whose file re-verifies — same epoch, same keys, byte-identical
//! frames, so refs minted before the crash still resolve — and reclaims
//! interrupted spills; [`TieredStore::new`] over the same directory
//! instead starts clean, reclaiming the lot (spool GC).

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::common::error::{Error, Result};
use crate::common::ids::EndpointId;
use crate::common::sync::Notify;
use crate::common::time::{Clock, Time};
use crate::datastore::backend::{DiskBackend, SpoolStore, StoreBackend};
use crate::datastore::dataref::{checksum, DataRef};
use crate::metrics::{FlightRecorder, SnapshotBuilder, TraceKind};
use crate::serialize::Buffer;

/// Which tier currently holds a frame (the coarse, two-valued view of
/// [`EntryState`] that routing and planning consume).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Memory,
    Disk,
}

/// The per-key lifecycle (module docs). `Expired` is terminal: the
/// entry is reaped and the key resolves [`Error::NotFound`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryState {
    /// Frame handle live in the memory tier.
    Resident,
    /// Background spill in flight: the handle is still live (gets are
    /// memory hits); the spiller is writing the spool file off-lock.
    Spilling,
    /// Frame lives only in the spool file.
    OnDisk,
    /// Promotion in flight: bytes reserved, the promoter is reading the
    /// spool file off-lock; gets read the file too until the commit.
    Promoting,
    /// TTL lapsed but the entry has not been reaped yet (reported by
    /// [`TieredStore::state_of`]; any access reaps it).
    Expired,
}

/// Tiered-store tuning knobs.
#[derive(Clone, Debug)]
pub struct TieredConfig {
    /// Bytes the memory tier may hold before LRU frames spill to disk.
    pub mem_high_watermark: usize,
    /// Default TTL applied by [`TieredStore::put`]; `<= 0` disables
    /// expiry.
    pub default_ttl_s: f64,
    /// Spool directory for the disk tier (`None` = unique temp dir,
    /// removed when the store drops).
    pub spool_dir: Option<PathBuf>,
}

impl Default for TieredConfig {
    fn default() -> Self {
        TieredConfig {
            mem_high_watermark: 64 * 1024 * 1024,
            default_ttl_s: 3600.0,
            spool_dir: None,
        }
    }
}

/// Monotone counters exposed for tests/benches/telemetry.
#[derive(Default)]
pub struct TierStats {
    pub puts: AtomicU64,
    pub mem_hits: AtomicU64,
    pub disk_hits: AtomicU64,
    pub spills: AtomicU64,
    pub spilled_bytes: AtomicU64,
    /// Spills abandoned because the key was overwritten/removed while
    /// the spool write was in flight (the artifact is reclaimed).
    pub spill_aborts: AtomicU64,
    /// Spool writes that FAILED (disk full, spool dir gone): the victim
    /// stays resident and the spiller backs off, so a climbing count
    /// here means the watermark is not being enforced — alert on it.
    pub spill_errors: AtomicU64,
    /// Puts refused under spill backpressure: the spool is persistently
    /// failing and the memory tier is already past its shed limit
    /// ([`TieredStore::with_shed_factor`] × watermark), so the write
    /// surfaced [`Error::Overloaded`] instead of growing the tier.
    pub shed_puts: AtomicU64,
    pub promotes: AtomicU64,
    pub expirations: AtomicU64,
}

impl TierStats {
    /// Export every tier counter into a metrics snapshot under the
    /// given dimensions (the registry-source adapter).
    pub fn fill(&self, b: &mut SnapshotBuilder, dims: &[(&str, &str)]) {
        b.counter("funcx_store_puts_total", dims, self.puts.load(Ordering::Relaxed));
        b.counter("funcx_store_mem_hits_total", dims, self.mem_hits.load(Ordering::Relaxed));
        b.counter("funcx_store_disk_hits_total", dims, self.disk_hits.load(Ordering::Relaxed));
        b.counter("funcx_store_spills_total", dims, self.spills.load(Ordering::Relaxed));
        b.counter(
            "funcx_store_spilled_bytes_total",
            dims,
            self.spilled_bytes.load(Ordering::Relaxed),
        );
        b.counter(
            "funcx_store_spill_aborts_total",
            dims,
            self.spill_aborts.load(Ordering::Relaxed),
        );
        b.counter(
            "funcx_store_spill_errors_total",
            dims,
            self.spill_errors.load(Ordering::Relaxed),
        );
        b.counter("funcx_store_shed_puts_total", dims, self.shed_puts.load(Ordering::Relaxed));
        b.counter("funcx_store_promotes_total", dims, self.promotes.load(Ordering::Relaxed));
        b.counter(
            "funcx_store_expirations_total",
            dims,
            self.expirations.load(Ordering::Relaxed),
        );
    }
}

/// Spiller threads per store: victims shard across a small pool so one
/// slow spool write does not serialize the whole drain.
const SPILLER_POOL: usize = 2;

/// Victims claimed per spiller lock pass: under a put storm each pool
/// member drains a small batch per index round-trip (write-coalescing),
/// so the index lock is taken twice per `SPILL_BATCH` spool writes
/// instead of twice per write.
const SPILL_BATCH: usize = 4;

/// Consecutive spool-write failures before the store treats the spool
/// as down and starts shedding over-limit puts.
const SPOOL_FAIL_SHED_STREAK: u64 = 1;

/// Default memory-tier shed limit, as a multiple of the high watermark.
const DEFAULT_SHED_FACTOR: usize = 4;

struct Entry {
    /// The key's shared handle (also the LRU queue's value — one
    /// allocation per key, not per touch).
    key: Arc<str>,
    size: usize,
    checksum: u64,
    state: EntryState,
    /// Bumped on every `put` of this key; in-flight transitions re-check
    /// it at commit so they abandon instead of clobbering a newer
    /// generation.
    gen: u64,
    /// Live frame handle while memory-resident (`Resident`/`Spilling`).
    frame: Option<Buffer>,
    /// Monotone access sequence number (LRU order).
    last_access: u64,
    /// Where this entry's victim-queue node currently sits (`Some` iff
    /// `Resident`): lets overwrite/remove/expiry delete the node
    /// instead of leaking it until the spiller happens to pop it.
    lru_pos: Option<u64>,
    expires_at: Option<Time>,
}

struct Index {
    entries: HashMap<Arc<str>, Entry>,
    /// Lazy LRU victim queue over `Resident` entries: keyed by the seq
    /// at insert time; a popped node whose entry has been touched since
    /// is re-queued at its current seq instead of spilled (so `get`
    /// stays O(1) with zero allocations — no queue reshuffle per hit).
    lru: BTreeMap<u64, (Arc<str>, u64)>,
    seq: u64,
    /// Bytes held by the memory tier: `Resident` + `Spilling` frames
    /// plus `Promoting` reservations.
    mem_bytes: usize,
    /// Bytes currently mid-spill (`Spilling` frames): victim selection
    /// subtracts them so concurrent spillers in the pool never claim
    /// more victims than the watermark overshoot warrants.
    spilling_bytes: usize,
    /// Entries currently in `Spilling`/`Promoting` ([`TieredStore::settle`]).
    in_flight: usize,
}

impl Index {
    fn bump(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Release the accounting a dying entry held, per its state —
    /// memory bytes, its victim-queue node. Disk artifacts of `OnDisk`
    /// entries must be reclaimed by the caller *off-lock* (returned as
    /// the spool key); in-flight transitions clean up their own
    /// artifact when their commit sees the generation gone.
    fn release(&mut self, e: &Entry) -> Option<String> {
        if let Some(pos) = e.lru_pos {
            self.lru.remove(&pos);
        }
        match e.state {
            EntryState::Resident | EntryState::Spilling | EntryState::Promoting => {
                self.mem_bytes -= e.size;
                None
            }
            EntryState::OnDisk => Some(spool_key(&e.key, e.gen)),
            EntryState::Expired => None,
        }
    }
}

fn spool_key(key: &str, gen: u64) -> String {
    format!("{key}#{gen}")
}

/// Process-wide epoch source: every store gets a distinct generation so
/// refs cannot resolve against the wrong store instance.
static EPOCHS: AtomicU64 = AtomicU64::new(1);

struct Inner {
    owner: EndpointId,
    epoch: u64,
    cfg: TieredConfig,
    spool: Arc<dyn SpoolStore>,
    index: Mutex<Index>,
    /// When set, TTL stamps and expiry decisions read this clock and
    /// ignore callers' `now` arguments (owner-stamped expiry — see the
    /// module's clock contract).
    owner_clock: OnceLock<Arc<dyn Clock>>,
    /// Flight recorder, the clock stamping its events, and this store's
    /// prebuilt component name (`store-<owner>`): spill/shed decisions
    /// become trace events — key-only from the background spiller,
    /// joined into task timelines by ref key at assembly.
    recorder: OnceLock<(Arc<FlightRecorder>, Arc<dyn Clock>, String)>,
    stats: Arc<TierStats>,
    /// Nudged when the watermark is crossed (and on shutdown).
    spill_wake: Notify,
    /// Signalled after every committed/aborted transition so
    /// [`TieredStore::settle`] can wait without polling.
    settled: Notify,
    /// Consecutive spool-write failures (reset by any success). At
    /// [`SPOOL_FAIL_SHED_STREAK`] the store starts shedding puts that
    /// would push the memory tier past `shed_factor × watermark`
    /// (spill backpressure — the spiller cannot drain, so growth must
    /// be bounded at the admission side).
    spool_fail_streak: AtomicU64,
    /// Memory-tier shed limit as a watermark multiple (see above).
    shed_factor: AtomicU64,
    shutdown: AtomicBool,
}

/// The tiered store. Thread-safe; share via `Arc`.
pub struct TieredStore {
    inner: Arc<Inner>,
    spillers: Vec<JoinHandle<()>>,
    pub stats: Arc<TierStats>,
}

impl TieredStore {
    pub fn new(owner: EndpointId, cfg: TieredConfig) -> Result<Self> {
        let disk = match &cfg.spool_dir {
            Some(d) => DiskBackend::new(d.clone())?,
            None => DiskBackend::temp()?,
        };
        let epoch = EPOCHS.fetch_add(1, Ordering::Relaxed);
        disk.set_epoch(epoch)?;
        Ok(Self::assemble(owner, epoch, cfg, Arc::new(disk), HashMap::new(), 0))
    }

    /// Build a store over an injected spool backend (fault/locking
    /// tests: a blocking fake pins that spool I/O never runs under the
    /// index lock). Not part of the supported API surface.
    #[doc(hidden)]
    pub fn with_spool_for_tests(
        owner: EndpointId,
        cfg: TieredConfig,
        spool: Arc<dyn SpoolStore>,
    ) -> Self {
        let epoch = EPOCHS.fetch_add(1, Ordering::Relaxed);
        Self::assemble(owner, epoch, cfg, spool, HashMap::new(), 0)
    }

    /// Reopen a crashed store's spool (requires an explicit
    /// `cfg.spool_dir`): disk-tier frames whose manifest record
    /// re-verifies are readopted under the manifest's epoch — so
    /// [`DataRef`]s minted before the crash still resolve, byte-identical
    /// — and interrupted spills are reclaimed. Memory-tier contents died
    /// with the process and are gone.
    pub fn recover(owner: EndpointId, cfg: TieredConfig) -> Result<Self> {
        let dir = cfg.spool_dir.clone().ok_or_else(|| {
            Error::InvalidArgument("recover requires an explicit spool_dir".into())
        })?;
        let (disk, adopted) = DiskBackend::recover(dir)?;
        let mut epoch = disk.epoch();
        if epoch == 0 {
            // Nothing to readopt from (no stamped manifest): behave like
            // a fresh store.
            epoch = EPOCHS.fetch_add(1, Ordering::Relaxed);
            disk.set_epoch(epoch)?;
        } else {
            // Keep future fresh epochs distinct from the readopted one.
            EPOCHS.fetch_max(epoch + 1, Ordering::Relaxed);
        }
        // Spool keys are `key#gen`; a crash between a newer generation's
        // spill and the older one's reclaim can leave both on disk —
        // keep the newest, reclaim the rest.
        let mut newest: HashMap<String, (u64, crate::datastore::SpoolEntry)> = HashMap::new();
        let mut losers: Vec<String> = Vec::new();
        for (skey, e) in adopted {
            let (key, gen) = match skey.rsplit_once('#') {
                Some((k, g)) => match g.parse::<u64>() {
                    Ok(gen) => (k.to_string(), gen),
                    Err(_) => (skey.clone(), 0),
                },
                None => (skey.clone(), 0),
            };
            match newest.get(&key).map(|(have, _)| *have) {
                Some(have) if have >= gen => losers.push(spool_key(&key, gen)),
                Some(have) => {
                    losers.push(spool_key(&key, have));
                    newest.insert(key, (gen, e));
                }
                None => {
                    newest.insert(key, (gen, e));
                }
            }
        }
        for skey in losers {
            let _ = disk.remove(&skey);
        }
        let mut entries = HashMap::new();
        let mut seq = 0u64;
        let mut max_gen = 0u64;
        for (key, (gen, e)) in newest {
            seq += 1;
            max_gen = max_gen.max(gen);
            let karc: Arc<str> = Arc::from(key.as_str());
            entries.insert(
                karc.clone(),
                Entry {
                    key: karc,
                    size: e.size as usize,
                    checksum: e.checksum,
                    state: EntryState::OnDisk,
                    gen,
                    frame: None,
                    last_access: seq,
                    lru_pos: None,
                    expires_at: e.expires_at,
                },
            );
        }
        let seq = seq.max(max_gen);
        Ok(Self::assemble(owner, epoch, cfg, Arc::new(disk), entries, seq))
    }

    fn assemble(
        owner: EndpointId,
        epoch: u64,
        cfg: TieredConfig,
        spool: Arc<dyn SpoolStore>,
        entries: HashMap<Arc<str>, Entry>,
        seq: u64,
    ) -> Self {
        let stats = Arc::new(TierStats::default());
        let inner = Arc::new(Inner {
            owner,
            epoch,
            cfg,
            spool,
            index: Mutex::new(Index {
                entries,
                lru: BTreeMap::new(),
                seq,
                mem_bytes: 0,
                spilling_bytes: 0,
                in_flight: 0,
            }),
            owner_clock: OnceLock::new(),
            recorder: OnceLock::new(),
            stats: stats.clone(),
            spill_wake: Notify::new(),
            settled: Notify::new(),
            spool_fail_streak: AtomicU64::new(0),
            shed_factor: AtomicU64::new(DEFAULT_SHED_FACTOR as u64),
            shutdown: AtomicBool::new(false),
        });
        let spillers = (0..SPILLER_POOL)
            .map(|i| {
                let worker = inner.clone();
                std::thread::Builder::new()
                    .name(format!("funcx-tier-spiller-{i}"))
                    .spawn(move || spiller_loop(worker))
                    .expect("spawn tier spiller")
            })
            .collect();
        TieredStore { inner, spillers, stats }
    }

    /// Override the spill-backpressure shed limit: puts are shed (with
    /// [`Error::Overloaded`]) once the spool is failing *and* the
    /// memory tier would exceed `factor × mem_high_watermark`. Default
    /// [`DEFAULT_SHED_FACTOR`]. `0` sheds every put while the spool is
    /// down.
    pub fn with_shed_factor(self, factor: usize) -> Self {
        self.inner.shed_factor.store(factor as u64, Ordering::Relaxed);
        self
    }

    /// Pin TTL stamps and expiry decisions to this store's own clock
    /// (owner-stamped expiry): callers' `now` arguments are then ignored
    /// for TTL purposes, so cross-endpoint resolvers with skewed clocks
    /// cannot mis-expire entries. Call before sharing the store.
    pub fn with_owner_clock(self, clock: Arc<dyn Clock>) -> Self {
        let _ = self.inner.owner_clock.set(clock);
        self
    }

    /// Attach the task flight recorder: spill commits and shed puts are
    /// recorded on component `store-<owner>`, stamped by `clock` (pass
    /// the deployment's shared clock so store events order correctly
    /// against service/endpoint hops). First call wins.
    pub fn with_recorder(&self, rec: Arc<FlightRecorder>, clock: Arc<dyn Clock>) {
        let component = format!("store-{}", self.inner.owner);
        let _ = self.inner.recorder.set((rec, clock, component));
    }

    /// The clock reading expiry logic should use: the owner clock when
    /// pinned, the caller's `now` otherwise.
    fn ttl_now(&self, caller_now: Time) -> Time {
        match self.inner.owner_clock.get() {
            Some(c) => c.now(),
            None => caller_now,
        }
    }

    pub fn owner(&self) -> EndpointId {
        self.inner.owner
    }

    pub fn epoch(&self) -> u64 {
        self.inner.epoch
    }

    fn mk_ref(&self, key: &str, size: usize, sum: u64) -> DataRef {
        DataRef {
            owner: self.inner.owner,
            epoch: self.inner.epoch,
            key: key.to_string(),
            size: size as u64,
            checksum: sum,
            replicas: Vec::new(),
        }
    }

    /// Store a frame under `key` with the configured default TTL.
    /// Returns the [`DataRef`] that resolves back to it.
    pub fn put(&self, key: &str, frame: Buffer, now: Time) -> Result<DataRef> {
        self.put_with_ttl(key, frame, None, now)
    }

    /// Store a frame with an explicit TTL (`Some(t)`; `t <= 0` disables
    /// expiry for this key) or the configured default (`None`). Pays no
    /// disk latency: the frame lands as a memory handle and the
    /// background spiller restores the watermark asynchronously.
    pub fn put_with_ttl(
        &self,
        key: &str,
        frame: Buffer,
        ttl_s: Option<f64>,
        now: Time,
    ) -> Result<DataRef> {
        let size = frame.len();
        let sum = checksum(frame.as_slice());
        let ttl = ttl_s.unwrap_or(self.inner.cfg.default_ttl_s);
        let expires_at = (ttl > 0.0).then_some(self.ttl_now(now) + ttl);
        let mut reclaim: Option<String> = None;
        let over = {
            let mut guard = self.inner.index.lock().expect("tiered index poisoned");
            // Reborrow as a plain `&mut Index`: field accesses below are
            // then disjoint borrows, not repeated reborrows of the guard.
            let idx = &mut *guard;
            // Spill backpressure: with the spool persistently failing
            // the spiller cannot drain, so past the shed limit this put
            // is refused (typed, retryable) instead of growing the
            // memory tier without bound. Overwrites of resident keys
            // are exempt when they don't grow occupancy — shedding
            // them would lose data for zero memory saved.
            if self.inner.spool_fail_streak.load(Ordering::Relaxed) >= SPOOL_FAIL_SHED_STREAK {
                let limit = (self.inner.shed_factor.load(Ordering::Relaxed) as usize)
                    .saturating_mul(self.inner.cfg.mem_high_watermark);
                let retained = match idx.entries.get(key) {
                    Some(e)
                        if matches!(
                            e.state,
                            EntryState::Resident | EntryState::Spilling | EntryState::Promoting
                        ) =>
                    {
                        e.size
                    }
                    _ => 0,
                };
                if idx.mem_bytes - retained + size > limit {
                    drop(guard);
                    self.stats.shed_puts.fetch_add(1, Ordering::Relaxed);
                    if let Some((rec, clock, component)) = self.inner.recorder.get() {
                        rec.record_ambient(
                            component,
                            clock.now(),
                            TraceKind::ShedPut { key: key.to_string() },
                        );
                    }
                    return Err(Error::Overloaded(format!(
                        "put {key} ({size} bytes) shed: spool is failing and the memory \
                         tier is at its shed limit ({limit} bytes)"
                    )));
                }
            }
            let seq = idx.bump();
            let node = match idx.entries.get_mut(key) {
                Some(e) => {
                    // Overwrite: release the previous generation's
                    // accounting (bytes + victim-queue node). An
                    // in-flight transition on it sees the bumped gen at
                    // commit and abandons its own artifact; a committed
                    // `OnDisk` file is ours to reclaim (off-lock,
                    // below).
                    let old_mem = matches!(
                        e.state,
                        EntryState::Resident | EntryState::Spilling | EntryState::Promoting
                    );
                    let old_size = e.size;
                    let old_pos = e.lru_pos;
                    if !old_mem {
                        reclaim = Some(spool_key(&e.key, e.gen));
                    }
                    install(e, seq, size, sum, frame, expires_at);
                    let node = (e.key.clone(), seq);
                    if old_mem {
                        idx.mem_bytes -= old_size;
                    }
                    if let Some(pos) = old_pos {
                        idx.lru.remove(&pos);
                    }
                    node
                }
                None => {
                    let karc: Arc<str> = Arc::from(key);
                    idx.entries.insert(
                        karc.clone(),
                        Entry {
                            key: karc.clone(),
                            size,
                            checksum: sum,
                            state: EntryState::Resident,
                            gen: seq,
                            frame: Some(frame),
                            last_access: seq,
                            lru_pos: Some(seq),
                            expires_at,
                        },
                    );
                    (karc, seq)
                }
            };
            idx.mem_bytes += size;
            idx.lru.insert(seq, node);
            idx.mem_bytes > self.inner.cfg.mem_high_watermark
        };
        if let Some(skey) = reclaim {
            let _ = self.inner.spool.remove(&skey);
        }
        if over {
            self.inner.spill_wake.notify();
        }
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        Ok(self.mk_ref(key, size, sum))
    }

    /// Fetch the frame under `key`. `Err(NotFound)` for missing or
    /// expired keys. Memory-resident states (`Resident`, `Spilling`)
    /// are served from the live handle under the metadata lock alone —
    /// zero backend calls, zero allocations, zero blocking on tier I/O.
    /// Disk states read the spool file off-lock; a disk hit promotes
    /// the frame back to memory when it fits the remaining headroom.
    pub fn get(&self, key: &str, now: Time) -> Result<Buffer> {
        let now = self.ttl_now(now);
        // Disk reads race transitions (promote commit, overwrite,
        // remove); each retry re-observes the state machine. A repeated
        // verification miss at the SAME generation means no writer
        // moved the key — the spool file itself is damaged — and fails
        // typed instead of re-reading; the iteration cap is a backstop
        // against pathological interleavings only.
        let mut missed_gen: Option<u64> = None;
        for _ in 0..16 {
            enum Action {
                Serve(Buffer),
                Read { gen: u64, size: usize, sum: u64, promoting: bool },
                /// TTL lapsed: the entry was reaped; reclaim the spool
                /// key (if any) off-lock.
                Expired(Option<String>),
            }
            let action = {
                let mut guard = self.inner.index.lock().expect("tiered index poisoned");
                let idx = &mut *guard;
                let Some(e) = idx.entries.get_mut(key) else {
                    return Err(Error::NotFound(format!("data key {key}")));
                };
                if e.expires_at.is_some_and(|t| now >= t) {
                    let e = idx.entries.remove(key).expect("just seen");
                    Action::Expired(idx.release(&e))
                } else {
                    let seq = idx.bump();
                    let e = idx.entries.get_mut(key).expect("just seen");
                    e.last_access = seq;
                    match e.state {
                        EntryState::Resident | EntryState::Spilling => Action::Serve(
                            e.frame.clone().expect("memory-resident entry has a frame"),
                        ),
                        EntryState::OnDisk => {
                            let (gen, size, sum) = (e.gen, e.size, e.checksum);
                            // Promote only into free headroom: promotion
                            // must never spill residents (that would
                            // ping-pong hot sets around the watermark).
                            let promoting =
                                idx.mem_bytes + size <= self.inner.cfg.mem_high_watermark;
                            if promoting {
                                let e = idx.entries.get_mut(key).expect("just seen");
                                e.state = EntryState::Promoting;
                                idx.mem_bytes += size;
                                idx.in_flight += 1;
                            }
                            Action::Read { gen, size, sum, promoting }
                        }
                        EntryState::Promoting => Action::Read {
                            gen: e.gen,
                            size: e.size,
                            sum: e.checksum,
                            promoting: false,
                        },
                        EntryState::Expired => unreachable!("expired entries are reaped above"),
                    }
                }
            };
            match action {
                Action::Serve(frame) => {
                    self.stats.mem_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(frame);
                }
                Action::Expired(reclaim) => {
                    if let Some(skey) = reclaim {
                        let _ = self.inner.spool.remove(&skey);
                    }
                    self.stats.expirations.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::NotFound(format!("data key {key} (expired)")));
                }
                Action::Read { gen, size, sum, promoting } => {
                    let skey = spool_key(key, gen);
                    let read = self.inner.spool.get(&skey);
                    let frame = match read {
                        Ok(Some(f)) if f.len() == size && checksum(f.as_slice()) == sum => {
                            Some(f)
                        }
                        Ok(_) => None,
                        Err(err) => {
                            if promoting {
                                self.abort_promote(key, gen, size);
                            }
                            return Err(err);
                        }
                    };
                    let Some(frame) = frame else {
                        // The file moved under us (promote commit,
                        // overwrite reclaim, removal): undo any
                        // reservation and re-observe. A second miss at
                        // the same generation is not a race — the entry
                        // never left the disk states — so the file is
                        // gone or corrupt for good.
                        if promoting {
                            self.abort_promote(key, gen, size);
                        }
                        if missed_gen == Some(gen) {
                            return Err(Error::Corrupt(format!(
                                "spool frame for {key} is missing or fails verification"
                            )));
                        }
                        missed_gen = Some(gen);
                        continue;
                    };
                    self.stats.disk_hits.fetch_add(1, Ordering::Relaxed);
                    if promoting {
                        self.commit_promote(key, gen, &frame, &skey);
                    }
                    return Ok(frame);
                }
            }
        }
        Err(Error::Data(format!("tier index livelocked for {key}")))
    }

    /// Commit a promotion: install the handle if the generation still
    /// stands, then reclaim the spool file (ours either way — no other
    /// transition touches this generation while it is `Promoting`).
    fn commit_promote(&self, key: &str, gen: u64, frame: &Buffer, skey: &str) {
        let committed = {
            let mut guard = self.inner.index.lock().expect("tiered index poisoned");
            let idx = &mut *guard;
            match idx.entries.get_mut(key) {
                Some(e) if e.gen == gen && e.state == EntryState::Promoting => {
                    e.state = EntryState::Resident;
                    e.frame = Some(frame.clone());
                    let node = (e.key.clone(), e.gen);
                    let at = e.last_access;
                    e.lru_pos = Some(at);
                    idx.lru.insert(at, node);
                    idx.in_flight -= 1;
                    true
                }
                _ => {
                    // Overwritten/removed mid-promotion: whoever did it
                    // released the reservation; only the artifact and
                    // the in-flight count are still ours.
                    idx.in_flight -= 1;
                    false
                }
            }
        };
        let _ = self.inner.spool.remove(skey);
        if committed {
            self.stats.promotes.fetch_add(1, Ordering::Relaxed);
        }
        self.inner.settled.notify();
    }

    /// Abort a promotion we marked: revert the reservation if the entry
    /// still stands (otherwise its replacer already released it).
    fn abort_promote(&self, key: &str, gen: u64, size: usize) {
        let mut guard = self.inner.index.lock().expect("tiered index poisoned");
        let idx = &mut *guard;
        if let Some(e) = idx.entries.get_mut(key) {
            if e.gen == gen && e.state == EntryState::Promoting {
                e.state = EntryState::OnDisk;
                idx.mem_bytes -= size;
            }
        }
        idx.in_flight -= 1;
        drop(guard);
        self.inner.settled.notify();
    }

    /// Resolve a [`DataRef`] against this store: owner + epoch must
    /// match, the key must be live, and the frame must verify against
    /// the ref's size/checksum.
    pub fn resolve(&self, r: &DataRef, now: Time) -> Result<Buffer> {
        if r.owner != self.inner.owner || r.epoch != self.inner.epoch {
            return Err(Error::NotFound(format!(
                "ref {}: owner/epoch does not match this store",
                r.key
            )));
        }
        let frame = self.get(&r.key, now)?;
        r.verify(frame.as_slice())?;
        Ok(frame)
    }

    /// Remove a key from whichever tier holds it. The index entry is
    /// authoritative: once it is gone the key is removed, and the spool
    /// reclaim is best-effort like every other reclaim site (a leaked
    /// file is reclaimed by the next recovery's orphan pass).
    pub fn remove(&self, key: &str) -> Result<bool> {
        let reclaim = {
            let mut idx = self.inner.index.lock().expect("tiered index poisoned");
            match idx.entries.remove(key) {
                Some(e) => idx.release(&e),
                None => return Ok(false),
            }
        };
        if let Some(skey) = reclaim {
            let _ = self.inner.spool.remove(&skey);
        }
        self.inner.settled.notify();
        Ok(true)
    }

    /// Eagerly drop every expired entry; returns how many were evicted.
    pub fn evict_expired(&self, now: Time) -> usize {
        let now = self.ttl_now(now);
        let (evicted, reclaims) = {
            let mut idx = self.inner.index.lock().expect("tiered index poisoned");
            let expired: Vec<Arc<str>> = idx
                .entries
                .values()
                .filter(|e| e.expires_at.is_some_and(|t| now >= t))
                .map(|e| e.key.clone())
                .collect();
            let mut reclaims = Vec::new();
            for k in &expired {
                if let Some(e) = idx.entries.remove(&**k) {
                    if let Some(skey) = idx.release(&e) {
                        reclaims.push(skey);
                    }
                    self.stats.expirations.fetch_add(1, Ordering::Relaxed);
                }
            }
            (expired.len(), reclaims)
        };
        for skey in reclaims {
            let _ = self.inner.spool.remove(&skey);
        }
        evicted
    }

    /// Which tier holds `key` right now (None = absent). Ignores TTL —
    /// use [`TieredStore::live_tier`] for a resolvability answer.
    pub fn tier_of(&self, key: &str) -> Option<Tier> {
        self.inner
            .index
            .lock()
            .expect("tiered index poisoned")
            .entries
            .get(key)
            .map(|e| tier_of_state(e.state))
    }

    /// The key's position in the entry state machine at `now`
    /// (TTL-aware: a lapsed-but-unreaped entry reports
    /// [`EntryState::Expired`]).
    pub fn state_of(&self, key: &str, now: Time) -> Option<EntryState> {
        let now = self.ttl_now(now);
        let idx = self.inner.index.lock().expect("tiered index poisoned");
        let e = idx.entries.get(key)?;
        if e.expires_at.is_some_and(|t| now >= t) {
            return Some(EntryState::Expired);
        }
        Some(e.state)
    }

    /// Which tier holds a frame that is still live (not expired) at
    /// `now` — the non-destructive check behind
    /// [`crate::datastore::DataFabric::plan`]: a `Some` answer means
    /// [`TieredStore::get`] at the same `now` would succeed.
    pub fn live_tier(&self, key: &str, now: Time) -> Option<Tier> {
        let now = self.ttl_now(now);
        let idx = self.inner.index.lock().expect("tiered index poisoned");
        let e = idx.entries.get(key)?;
        if e.expires_at.is_some_and(|t| now >= t) {
            return None;
        }
        Some(tier_of_state(e.state))
    }

    /// Number of live keys across both tiers.
    pub fn len(&self) -> usize {
        self.inner.index.lock().expect("tiered index poisoned").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes resident in the memory tier (live handles + promotion
    /// reservations).
    pub fn mem_bytes(&self) -> usize {
        self.inner.index.lock().expect("tiered index poisoned").mem_bytes
    }

    /// Victim-queue size (tests: pins that the queue is bounded by the
    /// resident set, not by lifetime put count).
    #[cfg(test)]
    fn lru_len(&self) -> usize {
        self.inner.index.lock().expect("tiered index poisoned").lru.len()
    }

    /// Block until the store is quiescent: no spill/promote in flight
    /// and the memory tier back under the watermark (or nothing left to
    /// spill). Tests and benches use this to observe the post-spill
    /// steady state the old synchronous `put` produced inline.
    pub fn settle(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let seen = self.inner.settled.epoch();
            let done = {
                let idx = self.inner.index.lock().expect("tiered index poisoned");
                idx.in_flight == 0
                    && (idx.mem_bytes <= self.inner.cfg.mem_high_watermark
                        || !idx.entries.values().any(|e| e.state == EntryState::Resident))
            };
            if done {
                return true;
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return false;
            }
            // Make sure the spiller is awake, then wait for progress.
            self.inner.spill_wake.notify();
            self.inner.settled.wait_newer(seen, remaining.min(Duration::from_millis(20)));
        }
    }

    /// Snapshot of every live (unexpired) key — the decommission
    /// drain's work list. Frames are then read off-lock one at a time;
    /// keys that expire or vanish between the snapshot and the read are
    /// simply skipped.
    pub fn live_keys(&self, now: Time) -> Vec<String> {
        let now = self.ttl_now(now);
        let idx = self.inner.index.lock().expect("tiered index poisoned");
        idx.entries
            .values()
            .filter(|e| !e.expires_at.is_some_and(|t| now >= t))
            .map(|e| e.key.to_string())
            .collect()
    }

    /// Drop every entry and reclaim every committed spool artifact
    /// (decommission spool GC). In-flight spills abandon at commit and
    /// reclaim their own artifact. Returns the number of entries
    /// purged.
    pub fn purge_all(&self) -> usize {
        let (purged, reclaims) = {
            let mut guard = self.inner.index.lock().expect("tiered index poisoned");
            let idx = &mut *guard;
            let keys: Vec<Arc<str>> = idx.entries.keys().cloned().collect();
            let mut reclaims = Vec::new();
            for k in &keys {
                if let Some(e) = idx.entries.remove(&**k) {
                    if let Some(skey) = idx.release(&e) {
                        reclaims.push(skey);
                    }
                }
            }
            (keys.len(), reclaims)
        };
        for skey in reclaims {
            let _ = self.inner.spool.remove(&skey);
        }
        self.inner.settled.notify();
        purged
    }
}

fn install(
    e: &mut Entry,
    seq: u64,
    size: usize,
    sum: u64,
    frame: Buffer,
    expires_at: Option<Time>,
) {
    e.size = size;
    e.checksum = sum;
    e.state = EntryState::Resident;
    e.gen = seq;
    e.frame = Some(frame);
    e.last_access = seq;
    e.lru_pos = Some(seq);
    e.expires_at = expires_at;
}

fn tier_of_state(s: EntryState) -> Tier {
    match s {
        EntryState::Resident | EntryState::Spilling | EntryState::Expired => Tier::Memory,
        EntryState::OnDisk | EntryState::Promoting => Tier::Disk,
    }
}

/// The background spillers: a small pool (of [`SPILLER_POOL`]) drains
/// the LRU victim queue whenever the memory tier crosses the high
/// watermark. Each thread claims up to [`SPILL_BATCH`] victims per
/// index pass: mark them `Spilling` under the lock, write all their
/// spool files with the lock dropped (write-coalescing), re-acquire
/// once to commit the batch `OnDisk` (abandoning any key that moved
/// on). `put` never pays disk latency; memory hits never wait on a
/// spill — the index lock only ever covers map operations. Victim
/// selection discounts bytes already mid-spill (`spilling_bytes`) so
/// concurrent pool members never over-spill past the watermark
/// overshoot.
fn spiller_loop(inner: Arc<Inner>) {
    loop {
        let seen = inner.spill_wake.epoch();
        if inner.shutdown.load(Ordering::Relaxed) {
            return;
        }
        // Victim selection: pop LRU nodes until the watermark is met or
        // the batch is full, claiming each fresh Resident entry (stale
        // nodes — touched since queueing, state changes, dead
        // generations — are re-queued or dropped).
        let victims = {
            let mut guard = inner.index.lock().expect("tiered index poisoned");
            let idx = &mut *guard;
            let mut found = Vec::new();
            // `saturating_sub`: removing a Spilling key releases its
            // mem_bytes share before the spiller returns the
            // spilling_bytes reserve, so the difference can transiently
            // go negative.
            while found.len() < SPILL_BATCH
                && idx.mem_bytes.saturating_sub(idx.spilling_bytes)
                    > inner.cfg.mem_high_watermark
            {
                let Some((pos, (key, node_gen))) = idx.lru.pop_first() else {
                    break;
                };
                let Some(e) = idx.entries.get_mut(&*key) else {
                    continue; // key removed; drop the node
                };
                if e.gen != node_gen
                    || e.state != EntryState::Resident
                    || e.lru_pos != Some(pos)
                {
                    continue; // superseded generation or already moving
                }
                if e.last_access != pos {
                    // Touched since queueing: not LRU anymore — requeue
                    // at its current position and keep looking.
                    let requeue = (e.key.clone(), e.gen);
                    let at = e.last_access;
                    e.lru_pos = Some(at);
                    idx.lru.insert(at, requeue);
                    continue;
                }
                e.state = EntryState::Spilling;
                e.lru_pos = None;
                // Re-stamp the generation at spill time: every spool
                // file name is then written exactly once, so no reader
                // can ever observe a partially-written file (the name
                // only becomes observable at the OnDisk commit).
                idx.seq += 1;
                e.gen = idx.seq;
                idx.in_flight += 1;
                idx.spilling_bytes += e.size;
                found.push((
                    e.key.clone(),
                    e.gen,
                    e.frame.clone().expect("resident entry has a frame"),
                    e.expires_at,
                    e.size,
                ));
            }
            found
        };
        if victims.is_empty() {
            inner.settled.notify();
            inner.spill_wake.wait_newer(seen, Duration::from_millis(100));
            continue;
        }

        // Tier I/O, no lock held: a slow disk stalls only this thread,
        // and the whole batch is written before the index is touched
        // again. A *panicking* spool (satellite fault case: the backing
        // device dies mid-storm) is contained here and treated as a
        // failed write — the store degrades to memory-only with
        // backpressure instead of silently losing its spiller thread.
        let mut any_err = false;
        let written: Vec<_> = victims
            .into_iter()
            .map(|(key, gen, frame, expires_at, size)| {
                let skey = spool_key(&key, gen);
                let wrote = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    inner.spool.put_entry(&skey, &frame, expires_at)
                }))
                .unwrap_or_else(|_| {
                    Err(Error::Data(format!("spool write for {skey} panicked")))
                });
                match &wrote {
                    Ok(()) => inner.spool_fail_streak.store(0, Ordering::Relaxed),
                    Err(_) => {
                        any_err = true;
                        inner.spool_fail_streak.fetch_add(1, Ordering::Relaxed);
                    }
                }
                (key, gen, skey, size, wrote)
            })
            .collect();

        // One re-lock pass commits the whole batch.
        let mut abandoned = Vec::new();
        let mut spilled: Vec<String> = Vec::new();
        {
            let mut guard = inner.index.lock().expect("tiered index poisoned");
            let idx = &mut *guard;
            for (key, gen, skey, size, wrote) in written {
                idx.in_flight -= 1;
                // We marked this victim Spilling, so the mid-spill
                // reserve is ours to return regardless of how the
                // commit resolves.
                idx.spilling_bytes -= size;
                let abandon = match idx.entries.get_mut(&*key) {
                    Some(e) if e.gen == gen && e.state == EntryState::Spilling => {
                        match &wrote {
                            Ok(()) => {
                                e.state = EntryState::OnDisk;
                                e.frame = None;
                                idx.mem_bytes -= size;
                                inner.stats.spills.fetch_add(1, Ordering::Relaxed);
                                inner
                                    .stats
                                    .spilled_bytes
                                    .fetch_add(size as u64, Ordering::Relaxed);
                                spilled.push(key.to_string());
                                false
                            }
                            Err(_) => {
                                // Spool write failed: the frame stays
                                // resident and spillable; back off
                                // below. Counted so a persistently
                                // failing disk (watermark no longer
                                // enforced) is observable.
                                inner.stats.spill_errors.fetch_add(1, Ordering::Relaxed);
                                e.state = EntryState::Resident;
                                let node = (e.key.clone(), e.gen);
                                let at = e.last_access;
                                e.lru_pos = Some(at);
                                idx.lru.insert(at, node);
                                false
                            }
                        }
                    }
                    _ => wrote.is_ok(), // key moved on mid-spill: reclaim our artifact
                };
                if abandon {
                    abandoned.push(skey);
                }
            }
        }
        for skey in abandoned {
            let _ = inner.spool.remove(&skey);
            inner.stats.spill_aborts.fetch_add(1, Ordering::Relaxed);
        }
        // Trace the committed spills off-lock: the spiller has no task
        // context, so these are key-only events joined into timelines
        // by ref key at assembly.
        if let Some((rec, clock, component)) = inner.recorder.get() {
            let at = clock.now();
            for k in spilled {
                rec.record(component, None, None, at, TraceKind::Spilled { key: k });
            }
        }
        inner.settled.notify();
        if any_err {
            // Persistent disk trouble must not spin the loop.
            inner.spill_wake.wait_newer(seen, Duration::from_millis(50));
        }
    }
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Relaxed);
        self.inner.spill_wake.notify();
        for t in self.spillers.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;
    use std::sync::Condvar;

    const SETTLE: Duration = Duration::from_secs(10);

    fn frame(byte: u8, len: usize) -> Buffer {
        Buffer::from_vec(vec![byte; len])
    }

    fn store(watermark: usize) -> TieredStore {
        TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: watermark, default_ttl_s: 0.0, spool_dir: None },
        )
        .unwrap()
    }

    #[test]
    fn put_get_roundtrip_memory() {
        let s = store(1 << 20);
        let f = frame(0xA5, 4096);
        let r = s.put("k", f.clone(), 0.0).unwrap();
        assert_eq!(r.size, 4096);
        assert_eq!(s.tier_of("k"), Some(Tier::Memory));
        assert_eq!(s.state_of("k", 0.0), Some(EntryState::Resident));
        let got = s.get("k", 0.0).unwrap();
        assert!(got.same_allocation(&f), "memory tier must hand back the same allocation");
        assert_eq!(s.stats.mem_hits.load(Relaxed), 1);
    }

    #[test]
    fn watermark_spills_lru_to_disk() {
        let s = store(10_000);
        s.put("a", frame(1, 4 << 10), 0.0).unwrap();
        s.put("b", frame(2, 4 << 10), 0.0).unwrap();
        // Touch a so b becomes LRU.
        s.get("a", 0.0).unwrap();
        s.put("c", frame(3, 4 << 10), 0.0).unwrap();
        assert!(s.settle(SETTLE), "spiller must restore the watermark");
        assert_eq!(s.tier_of("b"), Some(Tier::Disk), "LRU key spills");
        assert_eq!(s.tier_of("a"), Some(Tier::Memory));
        assert_eq!(s.tier_of("c"), Some(Tier::Memory));
        assert!(s.mem_bytes() <= 10_000);
        assert_eq!(s.stats.spills.load(Relaxed), 1);
        // Disk hit returns the exact bytes.
        let got = s.get("b", 0.0).unwrap();
        assert_eq!(got.as_slice(), frame(2, 4 << 10).as_slice());
        assert_eq!(s.stats.disk_hits.load(Relaxed), 1);
    }

    #[test]
    fn disk_hit_promotes_into_headroom() {
        let s = store(10_000);
        s.put("a", frame(1, 4 << 10), 0.0).unwrap();
        s.put("b", frame(2, 4 << 10), 0.0).unwrap();
        s.put("c", frame(3, 4 << 10), 0.0).unwrap(); // spills "a"
        assert!(s.settle(SETTLE));
        assert_eq!(s.tier_of("a"), Some(Tier::Disk));
        s.remove("b").unwrap(); // free headroom
        s.get("a", 0.0).unwrap();
        assert!(s.settle(SETTLE));
        assert_eq!(s.tier_of("a"), Some(Tier::Memory), "promoted into freed headroom");
        assert_eq!(s.stats.promotes.load(Relaxed), 1);
        // Without headroom the frame keeps serving from disk.
        s.put("d", frame(4, 4 << 10), 0.0).unwrap();
        assert!(s.settle(SETTLE));
        let spilled = ["a", "c", "d"]
            .iter()
            .find(|k| s.tier_of(k) == Some(Tier::Disk))
            .expect("one key is on disk")
            .to_string();
        s.get(&spilled, 0.0).unwrap();
        assert_eq!(s.tier_of(&spilled), Some(Tier::Disk), "no promotion without headroom");
    }

    #[test]
    fn ttl_expiry_is_not_found() {
        let s = TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 10.0, spool_dir: None },
        )
        .unwrap();
        let r = s.put("k", frame(1, 64), 0.0).unwrap();
        assert!(s.get("k", 5.0).is_ok());
        assert_eq!(s.state_of("k", 11.0), Some(EntryState::Expired));
        match s.get("k", 11.0) {
            Err(Error::NotFound(m)) => assert!(m.contains("expired"), "{m}"),
            other => panic!("expected NotFound, got {other:?}"),
        }
        // Gone for good — and resolving the ref reports NotFound too.
        assert!(matches!(s.get("k", 12.0), Err(Error::NotFound(_))));
        assert!(matches!(s.resolve(&r, 12.0), Err(Error::NotFound(_))));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn eager_eviction_and_ttl_override() {
        let s = store(1 << 20);
        s.put_with_ttl("short", frame(1, 64), Some(1.0), 0.0).unwrap();
        s.put_with_ttl("keep", frame(2, 64), Some(0.0), 0.0).unwrap(); // no expiry
        assert_eq!(s.evict_expired(0.5), 0);
        assert_eq!(s.evict_expired(2.0), 1);
        assert!(s.get("keep", 1e9).is_ok());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn stale_epoch_and_wrong_owner_rejected() {
        let a = store(1 << 20);
        let b = store(1 << 20);
        let r = a.put("k", frame(1, 64), 0.0).unwrap();
        assert!(matches!(b.resolve(&r, 0.0), Err(Error::NotFound(_))));
        assert!(a.resolve(&r, 0.0).is_ok());
        assert_ne!(a.epoch(), b.epoch());
    }

    #[test]
    fn overwrite_replaces_and_reaccounts() {
        let s = store(10_000);
        s.put("k", frame(1, 8 << 10), 0.0).unwrap();
        assert_eq!(s.mem_bytes(), 8 << 10);
        let r = s.put("k", frame(2, 1 << 10), 0.0).unwrap();
        assert_eq!(s.mem_bytes(), 1 << 10);
        assert_eq!(s.len(), 1);
        let got = s.resolve(&r, 0.0).unwrap();
        assert_eq!(got.as_slice(), frame(2, 1 << 10).as_slice());
    }

    #[test]
    fn overwrite_of_spilled_key_reclaims_the_old_spool_file() {
        let s = store(1 << 10);
        let stale = s.put("k", frame(1, 8 << 10), 0.0).unwrap();
        assert!(s.settle(SETTLE));
        assert_eq!(s.tier_of("k"), Some(Tier::Disk));
        let fresh = s.put("k", frame(2, 128), 0.0).unwrap();
        assert_eq!(s.get("k", 0.0).unwrap().as_slice(), frame(2, 128).as_slice());
        // The stale ref cannot resolve the old generation's bytes.
        assert!(s.resolve(&stale, 0.0).is_err());
        assert!(s.resolve(&fresh, 0.0).is_ok());
    }

    /// The victim queue holds at most one node per resident entry:
    /// overwrites, removals, and expiry delete their node instead of
    /// leaking it until the spiller happens to pop it — an
    /// under-watermark store (where the spiller never drains) must not
    /// grow the queue with lifetime put count.
    #[test]
    fn victim_queue_is_bounded_by_resident_set() {
        let s = store(1 << 20);
        for _ in 0..500 {
            s.put("hot", frame(1, 64), 0.0).unwrap();
        }
        assert_eq!(s.lru_len(), 1, "overwrites must replace the node, not stack new ones");
        for i in 0..10 {
            s.put(&format!("k{i}"), frame(2, 64), 0.0).unwrap();
        }
        assert_eq!(s.lru_len(), 11);
        for i in 0..10 {
            assert!(s.remove(&format!("k{i}")).unwrap());
        }
        assert_eq!(s.lru_len(), 1, "removal must delete the node");
        s.put_with_ttl("short", frame(3, 64), Some(1.0), 0.0).unwrap();
        assert_eq!(s.evict_expired(2.0), 1);
        assert_eq!(s.lru_len(), 1, "expiry must delete the node");
    }

    /// A spool file damaged at rest (truncated/deleted outside the
    /// store) fails `get` typed and fast — Corrupt after one
    /// re-observation, not 16 blind re-reads ending in a bogus
    /// "livelocked" error.
    #[test]
    fn damaged_spool_file_fails_corrupt_not_livelocked() {
        let spool = BlockingSpool::new();
        let s = TieredStore::with_spool_for_tests(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 0, default_ttl_s: 0.0, spool_dir: None },
            spool.clone(),
        );
        spool.release(); // writes flow freely in this test
        s.put("k", frame(7, 4 << 10), 0.0).unwrap();
        assert!(s.settle(SETTLE));
        assert_eq!(s.tier_of("k"), Some(Tier::Disk));
        // Damage: delete the spool file behind the store's back.
        spool.inner_damage_remove_all();
        match s.get("k", 0.0) {
            Err(Error::Corrupt(m)) => {
                assert!(m.contains("verification"), "{m}");
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn owner_clock_overrides_reader_skew() {
        let vc = crate::common::time::VirtualClock::new();
        let s = TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 10.0, spool_dir: None },
        )
        .unwrap()
        .with_owner_clock(Arc::new(vc.clone()));
        let r = s.put("k", frame(1, 64), 777.0).unwrap(); // caller's now is ignored
        // A reader whose clock runs far ahead cannot expire the entry…
        assert!(s.get("k", 1e6).is_ok());
        assert!(s.resolve(&r, 1e6).is_ok());
        assert_eq!(s.live_tier("k", 1e6), Some(Tier::Memory));
        // …and one running far behind cannot resurrect it once the
        // owner's clock passes the stamp.
        vc.advance_to(11.0);
        assert_eq!(s.live_tier("k", -1e6), None);
        assert!(matches!(s.get("k", -1e6), Err(Error::NotFound(_))));
    }

    #[test]
    fn recover_readopts_spilled_frames_under_the_old_epoch() {
        let dir =
            std::env::temp_dir().join(format!("funcx-tiered-recover-{}", crate::Uuid::new()));
        let owner = EndpointId::new();
        let cfg = TieredConfig {
            mem_high_watermark: 0, // everything spills immediately
            default_ttl_s: 0.0,
            spool_dir: Some(dir.clone()),
        };
        let (r1, epoch1, bytes) = {
            let s = TieredStore::new(owner, cfg.clone()).unwrap();
            let f = frame(0x3C, 8 << 10);
            let r = s.put("k1", f.clone(), 0.0).unwrap();
            s.put("k2", frame(0x4D, 4 << 10), 0.0).unwrap();
            assert!(s.settle(SETTLE));
            assert_eq!(s.tier_of("k1"), Some(Tier::Disk));
            let (epoch, bytes) = (s.epoch(), f.to_vec());
            std::mem::forget(s); // crash: no Drop, no cleanup
            (r, epoch, bytes)
        };
        let s2 = TieredStore::recover(owner, cfg.clone()).unwrap();
        assert_eq!(s2.epoch(), epoch1, "recovery adopts the crashed store's epoch");
        assert_eq!(s2.len(), 2);
        let got = s2.resolve(&r1, 0.0).unwrap();
        assert_eq!(got.as_slice(), &bytes[..], "readopted frame resolves byte-identical");
        // A *fresh* store over the same dir instead reclaims everything.
        drop(s2);
        let s3 = TieredStore::new(owner, cfg).unwrap();
        assert_eq!(s3.len(), 0);
        assert!(matches!(s3.resolve(&r1, 0.0), Err(Error::NotFound(_))));
        assert_ne!(s3.epoch(), epoch1);
        drop(s3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn oversized_single_frame_spills_itself() {
        let s = store(1 << 10);
        s.put("big", frame(9, 64 << 10), 0.0).unwrap();
        assert!(s.settle(SETTLE));
        assert_eq!(s.tier_of("big"), Some(Tier::Disk));
        assert_eq!(s.mem_bytes(), 0);
        // Serves from disk, never promotes (larger than the watermark).
        let got = s.get("big", 0.0).unwrap();
        assert_eq!(got.len(), 64 << 10);
        assert_eq!(s.tier_of("big"), Some(Tier::Disk));
    }

    /// A spool whose writes block until released: the harness for the
    /// locking-discipline pin below.
    struct BlockingSpool {
        inner: DiskBackend,
        gate: Mutex<bool>,
        cv: Condvar,
        writes_started: AtomicU64,
    }

    impl BlockingSpool {
        fn new() -> Arc<Self> {
            Arc::new(BlockingSpool {
                inner: DiskBackend::temp().unwrap(),
                gate: Mutex::new(false),
                cv: Condvar::new(),
                writes_started: AtomicU64::new(0),
            })
        }

        fn release(&self) {
            *self.gate.lock().unwrap() = true;
            self.cv.notify_all();
        }

        /// Damage-at-rest: delete every frame file behind the store's
        /// back, leaving the manifest in place.
        fn inner_damage_remove_all(&self) {
            for entry in std::fs::read_dir(self.inner.root()).unwrap() {
                let p = entry.unwrap().path();
                if p.file_name().is_some_and(|n| n != "spool.manifest") {
                    let _ = std::fs::remove_file(&p);
                }
            }
        }

        /// Bounded so a failing test (which drops the store and joins
        /// the spiller before ever calling `release`) cannot hang the
        /// suite.
        fn block_here(&self) {
            let deadline = std::time::Instant::now() + Duration::from_secs(30);
            let mut open = self.gate.lock().unwrap();
            while !*open && std::time::Instant::now() < deadline {
                let (g, _) = self.cv.wait_timeout(open, Duration::from_millis(100)).unwrap();
                open = g;
            }
        }
    }

    impl crate::datastore::backend::StoreBackend for BlockingSpool {
        fn name(&self) -> &'static str {
            "blocking-fake"
        }
        fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
            self.inner.put(key, frame)
        }
        fn get(&self, key: &str) -> Result<Option<Buffer>> {
            self.inner.get(key)
        }
        fn remove(&self, key: &str) -> Result<bool> {
            crate::datastore::backend::StoreBackend::remove(&self.inner, key)
        }
    }

    impl SpoolStore for BlockingSpool {
        fn put_entry(&self, key: &str, frame: &Buffer, expires_at: Option<Time>) -> Result<()> {
            self.writes_started.fetch_add(1, Ordering::SeqCst);
            self.block_here();
            self.inner.put_entry(key, frame, expires_at)
        }
    }

    /// THE locking-discipline pin: with a spool whose write stalls
    /// indefinitely, a spill in flight must not delay memory-tier gets —
    /// neither of an untouched resident key nor of the `Spilling` victim
    /// itself (both are served from live handles under the metadata
    /// lock alone).
    #[test]
    fn stalled_spill_does_not_block_memory_hits() {
        let spool = BlockingSpool::new();
        let s = TieredStore::with_spool_for_tests(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 8 << 10, default_ttl_s: 0.0, spool_dir: None },
            spool.clone(),
        );
        let old = frame(1, 6 << 10);
        let hot = frame(2, 6 << 10);
        s.put("victim", old.clone(), 0.0).unwrap(); // LRU → the spill victim
        s.put("hot", hot.clone(), 0.0).unwrap(); // crosses the watermark
        // Wait until the spiller is stuck inside the spool write.
        let t0 = std::time::Instant::now();
        while spool.writes_started.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "spill never started");
            std::thread::yield_now();
        }
        assert_eq!(s.state_of("victim", 0.0), Some(EntryState::Spilling));

        // Memory-tier gets while the disk write is stalled: all fast,
        // all the original allocations.
        let t0 = std::time::Instant::now();
        for _ in 0..100 {
            assert!(s.get("hot", 0.0).unwrap().same_allocation(&hot));
            assert!(
                s.get("victim", 0.0).unwrap().same_allocation(&old),
                "a Spilling key is served from its still-live handle"
            );
        }
        let stalled_hits = t0.elapsed();
        assert!(
            stalled_hits < Duration::from_millis(500),
            "memory hits waited on a stalled spill: {stalled_hits:?}"
        );
        assert!(s.stats.mem_hits.load(Relaxed) >= 200);
        assert_eq!(s.stats.spills.load(Relaxed), 0, "the spill has not committed yet");

        // Release the disk; the spill commits and the bytes survive.
        spool.release();
        assert!(s.settle(SETTLE));
        assert_eq!(s.tier_of("victim"), Some(Tier::Disk));
        assert_eq!(s.get("victim", 0.0).unwrap().as_slice(), old.as_slice());
    }

    /// A spool whose writes fail on demand — the spill-backpressure
    /// harness (reads and reclaims keep working; only new spills fail).
    struct FlakySpool {
        inner: DiskBackend,
        fail: AtomicBool,
    }

    impl FlakySpool {
        fn new(fail: bool) -> Arc<Self> {
            Arc::new(FlakySpool {
                inner: DiskBackend::temp().unwrap(),
                fail: AtomicBool::new(fail),
            })
        }

        fn set_fail(&self, fail: bool) {
            self.fail.store(fail, Ordering::SeqCst);
        }
    }

    impl crate::datastore::backend::StoreBackend for FlakySpool {
        fn name(&self) -> &'static str {
            "flaky-fake"
        }
        fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
            self.inner.put(key, frame)
        }
        fn get(&self, key: &str) -> Result<Option<Buffer>> {
            self.inner.get(key)
        }
        fn remove(&self, key: &str) -> Result<bool> {
            crate::datastore::backend::StoreBackend::remove(&self.inner, key)
        }
    }

    impl SpoolStore for FlakySpool {
        fn put_entry(&self, key: &str, frame: &Buffer, expires_at: Option<Time>) -> Result<()> {
            if self.fail.load(Ordering::SeqCst) {
                return Err(Error::Data("injected spool failure".into()));
            }
            self.inner.put_entry(key, frame, expires_at)
        }
    }

    /// THE backpressure pin: a permanently failing spool bounds the
    /// memory tier at shed_factor × watermark. Over-limit puts shed
    /// with `Error::Overloaded` (typed, no hang, no panic), accepted
    /// keys stay readable (degraded memory-only store), and once the
    /// spool heals the store drains and accepts puts again.
    #[test]
    fn failing_spool_bounds_memory_tier_with_typed_sheds() {
        const WM: usize = 4 << 10;
        let spool = FlakySpool::new(true);
        let s = TieredStore::with_spool_for_tests(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: WM, default_ttl_s: 0.0, spool_dir: None },
            spool.clone(),
        )
        .with_shed_factor(4);
        let limit = 4 * WM;

        // Fill past the watermark so the spiller attempts (and fails).
        let mut accepted = 0usize;
        for i in 0..8 {
            s.put(&format!("k{i}"), frame(i as u8, 1 << 10), 0.0).unwrap();
            accepted += 1;
        }
        let t0 = std::time::Instant::now();
        while s.stats.spill_errors.load(Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "spiller never hit the bad spool");
            std::thread::yield_now();
        }

        // Keep putting: occupancy must stay bounded at the shed limit,
        // with over-limit puts refused typed.
        let mut shed = 0usize;
        for i in 8..64 {
            match s.put(&format!("k{i}"), frame(i as u8, 1 << 10), 0.0) {
                Ok(_) => accepted += 1,
                Err(Error::Overloaded(m)) => {
                    assert!(m.contains("shed"), "{m}");
                    shed += 1;
                }
                Err(other) => panic!("expected Overloaded, got {other:?}"),
            }
            assert!(s.mem_bytes() <= limit, "memory tier exceeded the shed limit");
        }
        assert!(shed > 0, "a permanently failing spool must shed eventually");
        assert_eq!(s.stats.shed_puts.load(Relaxed), shed as u64);
        assert_eq!(s.len(), accepted, "every accepted key is retained");
        // Degraded mode: every accepted key is still readable.
        for i in 0..accepted {
            let got = s.get(&format!("k{i}"), 0.0).unwrap();
            assert_eq!(got.as_slice(), frame(i as u8, 1 << 10).as_slice());
        }
        // Overwriting a resident key doesn't grow occupancy, so it is
        // exempt from shedding even at the limit.
        s.put("k0", frame(0xEE, 1 << 10), 0.0).unwrap();

        // Heal the spool: the spiller drains back under the watermark
        // and new puts are accepted again.
        spool.set_fail(false);
        s.inner.spill_wake.notify();
        assert!(s.settle(SETTLE), "healed spool must drain the backlog");
        assert!(s.mem_bytes() <= WM);
        s.put("after-heal", frame(0xAA, 1 << 10), 0.0).unwrap();
        assert_eq!(s.get("k0", 0.0).unwrap().as_slice(), frame(0xEE, 1 << 10).as_slice());
    }

    /// Decommission support: `purge_all` reaps every entry and every
    /// committed spool artifact; `live_keys` snapshots the drain list.
    #[test]
    fn purge_all_reaps_entries_and_spool_files() {
        let s = store(1 << 10);
        s.put("mem", frame(1, 128), 0.0).unwrap();
        s.put("disk", frame(2, 8 << 10), 0.0).unwrap(); // over watermark → spills
        assert!(s.settle(SETTLE));
        let mut keys = s.live_keys(0.0);
        keys.sort();
        assert_eq!(keys, vec!["disk".to_string(), "mem".to_string()]);
        assert_eq!(s.purge_all(), 2);
        assert_eq!(s.len(), 0);
        assert_eq!(s.mem_bytes(), 0);
        assert!(matches!(s.get("mem", 0.0), Err(Error::NotFound(_))));
        assert!(matches!(s.get("disk", 0.0), Err(Error::NotFound(_))));
    }

    /// Overwriting a key while its spill is stalled mid-write: the
    /// spiller's commit sees the bumped generation, abandons its
    /// artifact, and the new bytes win.
    #[test]
    fn overwrite_mid_spill_abandons_the_stale_artifact() {
        let spool = BlockingSpool::new();
        let s = TieredStore::with_spool_for_tests(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 4 << 10, default_ttl_s: 0.0, spool_dir: None },
            spool.clone(),
        );
        s.put("k", frame(1, 6 << 10), 0.0).unwrap(); // over watermark → spill
        let t0 = std::time::Instant::now();
        while spool.writes_started.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "spill never started");
            std::thread::yield_now();
        }
        // Overwrite while the spool write is stalled.
        let fresh = s.put("k", frame(2, 128), 0.0).unwrap();
        spool.release();
        assert!(s.settle(SETTLE));
        assert_eq!(s.stats.spill_aborts.load(Relaxed), 1, "stale spill must abandon");
        let got = s.resolve(&fresh, 0.0).unwrap();
        assert_eq!(got.as_slice(), frame(2, 128).as_slice());
    }
}
