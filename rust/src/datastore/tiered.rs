//! The tiered payload store: memory tier + disk tier behind one index.
//!
//! Frames land in the memory tier; once the tier's resident bytes exceed
//! the configured high-watermark, least-recently-used frames spill to
//! the disk tier as raw wire bytes. A disk-tier hit promotes the frame
//! back to memory when it fits without displacing residents (promotion
//! never cascades into spills, so a frame larger than the remaining
//! headroom simply keeps serving from disk). Every entry carries an
//! optional TTL; expired entries resolve to [`Error::NotFound`] and are
//! removed lazily on access or eagerly via
//! [`TieredStore::evict_expired`].
//!
//! The store never decodes a frame: spill writes the frame's bytes,
//! reload wraps the read bytes in a fresh shared allocation, and a
//! memory-tier hit returns another handle on the *original* allocation
//! (pointer-pinned in `tests/data_fabric.rs`).
//!
//! # Clock contract
//!
//! Like [`crate::store::KvStore`]'s TTL ops, every method takes the
//! caller's clock reading so the simulator can drive expiry under
//! virtual time. By default all parties touching one store — the owner
//! writing frames and any fabric resolving against it — MUST share a
//! clock (e.g. pass the service's clock to `EndpointBuilder::clock`).
//! For cross-endpoint deployments where that cannot hold, pin the store
//! with [`TieredStore::with_owner_clock`]: expiry stamps *and* expiry
//! decisions then both read the owner's clock and readers' skewed `now`
//! arguments are ignored for TTL purposes, so a resolver whose clock
//! runs fast cannot expire a live entry and one running slow cannot
//! resurrect a dead one (owner-stamped expiry; pinned in
//! `tests/fabric_faults.rs`).
//!
//! # Crash recovery
//!
//! The disk tier's epoch-stamped manifest (see
//! [`crate::datastore::DiskBackend`]) makes spilled frames survive a
//! crash: [`TieredStore::recover`] readopts every manifest entry whose
//! file re-verifies — same epoch, same keys, byte-identical frames, so
//! refs minted before the crash still resolve — and reclaims interrupted
//! spills; [`TieredStore::new`] over the same directory instead starts
//! clean, reclaiming the lot (spool GC).
//!
//! # Locking
//!
//! One index mutex guards both tiers, so disk-tier reads/spills
//! serialize concurrent store ops. That is deliberate for now —
//! correctness first; the memory tier dominates the hot path — and
//! lifting I/O out of the lock is a ROADMAP item.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::common::error::{Error, Result};
use crate::common::ids::EndpointId;
use crate::common::time::{Clock, Time};
use crate::datastore::backend::{DiskBackend, MemoryBackend, StoreBackend};
use crate::datastore::dataref::{checksum, DataRef};
use crate::serialize::Buffer;

/// Which tier currently holds a frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Memory,
    Disk,
}

/// Tiered-store tuning knobs.
#[derive(Clone, Debug)]
pub struct TieredConfig {
    /// Bytes the memory tier may hold before LRU frames spill to disk.
    pub mem_high_watermark: usize,
    /// Default TTL applied by [`TieredStore::put`]; `<= 0` disables
    /// expiry.
    pub default_ttl_s: f64,
    /// Spool directory for the disk tier (`None` = unique temp dir,
    /// removed when the store drops).
    pub spool_dir: Option<PathBuf>,
}

impl Default for TieredConfig {
    fn default() -> Self {
        TieredConfig {
            mem_high_watermark: 64 * 1024 * 1024,
            default_ttl_s: 3600.0,
            spool_dir: None,
        }
    }
}

/// Monotone counters exposed for tests/benches/telemetry.
#[derive(Default)]
pub struct TierStats {
    pub puts: AtomicU64,
    pub mem_hits: AtomicU64,
    pub disk_hits: AtomicU64,
    pub spills: AtomicU64,
    pub spilled_bytes: AtomicU64,
    pub promotes: AtomicU64,
    pub expirations: AtomicU64,
}

struct Entry {
    size: usize,
    checksum: u64,
    tier: Tier,
    /// Monotone access sequence number (LRU order).
    last_access: u64,
    expires_at: Option<Time>,
}

struct Index {
    entries: HashMap<String, Entry>,
    seq: u64,
    /// Bytes currently resident in the memory tier.
    mem_bytes: usize,
}

/// Process-wide epoch source: every store gets a distinct generation so
/// refs cannot resolve against the wrong store instance.
static EPOCHS: AtomicU64 = AtomicU64::new(1);

/// The tiered store. Thread-safe; share via `Arc`.
pub struct TieredStore {
    owner: EndpointId,
    epoch: u64,
    cfg: TieredConfig,
    mem: MemoryBackend,
    disk: DiskBackend,
    index: Mutex<Index>,
    /// When set, TTL stamps and expiry decisions read this clock and
    /// ignore callers' `now` arguments (owner-stamped expiry — see the
    /// module's clock contract).
    owner_clock: Option<Arc<dyn Clock>>,
    pub stats: TierStats,
}

impl TieredStore {
    pub fn new(owner: EndpointId, cfg: TieredConfig) -> Result<Self> {
        let disk = match &cfg.spool_dir {
            Some(d) => DiskBackend::new(d.clone())?,
            None => DiskBackend::temp()?,
        };
        let epoch = EPOCHS.fetch_add(1, Ordering::Relaxed);
        disk.set_epoch(epoch)?;
        Ok(TieredStore {
            owner,
            epoch,
            cfg,
            mem: MemoryBackend::new(),
            disk,
            index: Mutex::new(Index { entries: HashMap::new(), seq: 0, mem_bytes: 0 }),
            owner_clock: None,
            stats: TierStats::default(),
        })
    }

    /// Reopen a crashed store's spool (requires an explicit
    /// `cfg.spool_dir`): disk-tier frames whose manifest record
    /// re-verifies are readopted under the manifest's epoch — so
    /// [`DataRef`]s minted before the crash still resolve, byte-identical
    /// — and interrupted spills are reclaimed. Memory-tier contents died
    /// with the process and are gone.
    pub fn recover(owner: EndpointId, cfg: TieredConfig) -> Result<Self> {
        let dir = cfg.spool_dir.clone().ok_or_else(|| {
            Error::InvalidArgument("recover requires an explicit spool_dir".into())
        })?;
        let (disk, adopted) = DiskBackend::recover(dir)?;
        let mut epoch = disk.epoch();
        if epoch == 0 {
            // Nothing to readopt from (no stamped manifest): behave like
            // a fresh store.
            epoch = EPOCHS.fetch_add(1, Ordering::Relaxed);
            disk.set_epoch(epoch)?;
        } else {
            // Keep future fresh epochs distinct from the readopted one.
            EPOCHS.fetch_max(epoch + 1, Ordering::Relaxed);
        }
        let mut entries = HashMap::new();
        let mut seq = 0u64;
        for (key, e) in adopted {
            seq += 1;
            entries.insert(
                key,
                Entry {
                    size: e.size as usize,
                    checksum: e.checksum,
                    tier: Tier::Disk,
                    last_access: seq,
                    expires_at: e.expires_at,
                },
            );
        }
        Ok(TieredStore {
            owner,
            epoch,
            cfg,
            mem: MemoryBackend::new(),
            disk,
            index: Mutex::new(Index { entries, seq, mem_bytes: 0 }),
            owner_clock: None,
            stats: TierStats::default(),
        })
    }

    /// Pin TTL stamps and expiry decisions to this store's own clock
    /// (owner-stamped expiry): callers' `now` arguments are then ignored
    /// for TTL purposes, so cross-endpoint resolvers with skewed clocks
    /// cannot mis-expire entries. Call before sharing the store.
    pub fn with_owner_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.owner_clock = Some(clock);
        self
    }

    /// The clock reading expiry logic should use: the owner clock when
    /// pinned, the caller's `now` otherwise.
    fn ttl_now(&self, caller_now: Time) -> Time {
        match &self.owner_clock {
            Some(c) => c.now(),
            None => caller_now,
        }
    }

    pub fn owner(&self) -> EndpointId {
        self.owner
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Store a frame under `key` with the configured default TTL.
    /// Returns the [`DataRef`] that resolves back to it.
    pub fn put(&self, key: &str, frame: Buffer, now: Time) -> Result<DataRef> {
        self.put_with_ttl(key, frame, None, now)
    }

    /// Store a frame with an explicit TTL (`Some(t)`; `t <= 0` disables
    /// expiry for this key) or the configured default (`None`).
    pub fn put_with_ttl(
        &self,
        key: &str,
        frame: Buffer,
        ttl_s: Option<f64>,
        now: Time,
    ) -> Result<DataRef> {
        let size = frame.len();
        let sum = checksum(frame.as_slice());
        let ttl = ttl_s.unwrap_or(self.cfg.default_ttl_s);
        let expires_at = (ttl > 0.0).then_some(self.ttl_now(now) + ttl);
        let mut idx = self.index.lock().expect("tiered index poisoned");
        // Overwrite: drop the previous generation of the key first.
        if let Some(old) = idx.entries.remove(key) {
            match old.tier {
                Tier::Memory => {
                    idx.mem_bytes -= old.size;
                    self.mem.remove(key)?;
                }
                Tier::Disk => {
                    self.disk.remove(key)?;
                }
            }
        }
        self.mem.put(key, &frame)?;
        idx.seq += 1;
        let last_access = idx.seq;
        idx.mem_bytes += size;
        idx.entries.insert(
            key.to_string(),
            Entry { size, checksum: sum, tier: Tier::Memory, last_access, expires_at },
        );
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.spill_over_watermark(&mut idx)?;
        Ok(DataRef {
            owner: self.owner,
            epoch: self.epoch,
            key: key.to_string(),
            size: size as u64,
            checksum: sum,
        })
    }

    /// Spill LRU memory-tier frames to disk until resident bytes drop to
    /// the watermark. Frames move as raw wire bytes. One O(n log n)
    /// LRU-ordered pass, not an O(n) scan per victim.
    fn spill_over_watermark(&self, idx: &mut Index) -> Result<()> {
        if idx.mem_bytes <= self.cfg.mem_high_watermark {
            return Ok(());
        }
        let mut victims: Vec<(u64, String)> = idx
            .entries
            .iter()
            .filter(|(_, e)| e.tier == Tier::Memory)
            .map(|(k, e)| (e.last_access, k.clone()))
            .collect();
        victims.sort_unstable_by_key(|(seq, _)| *seq);
        for (_, k) in victims {
            if idx.mem_bytes <= self.cfg.mem_high_watermark {
                break;
            }
            let frame = self
                .mem
                .get(&k)?
                .ok_or_else(|| Error::Data(format!("tier index out of sync for {k}")))?;
            // Spill with the entry's expiry stamp so the spool manifest
            // can readopt it (with its TTL) after a crash.
            let expires_at = idx.entries.get(&k).and_then(|e| e.expires_at);
            self.disk.put_entry(&k, &frame, expires_at)?;
            self.mem.remove(&k)?;
            let e = idx.entries.get_mut(&k).expect("victim is indexed");
            e.tier = Tier::Disk;
            let size = e.size;
            idx.mem_bytes -= size;
            self.stats.spills.fetch_add(1, Ordering::Relaxed);
            self.stats.spilled_bytes.fetch_add(size as u64, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Fetch the frame under `key`. `Err(NotFound)` for missing or
    /// expired keys; a disk hit promotes the frame back to memory when
    /// it fits the remaining headroom.
    pub fn get(&self, key: &str, now: Time) -> Result<Buffer> {
        let now = self.ttl_now(now);
        let mut idx = self.index.lock().expect("tiered index poisoned");
        let Some(e) = idx.entries.get(key) else {
            return Err(Error::NotFound(format!("data key {key}")));
        };
        if let Some(exp) = e.expires_at {
            if now >= exp {
                let tier = e.tier;
                let size = e.size;
                idx.entries.remove(key);
                match tier {
                    Tier::Memory => {
                        idx.mem_bytes -= size;
                        self.mem.remove(key)?;
                    }
                    Tier::Disk => {
                        self.disk.remove(key)?;
                    }
                }
                self.stats.expirations.fetch_add(1, Ordering::Relaxed);
                return Err(Error::NotFound(format!("data key {key} (expired)")));
            }
        }
        idx.seq += 1;
        let seq = idx.seq;
        let (tier, size) = {
            let e = idx.entries.get_mut(key).expect("checked above");
            e.last_access = seq;
            (e.tier, e.size)
        };
        match tier {
            Tier::Memory => {
                self.stats.mem_hits.fetch_add(1, Ordering::Relaxed);
                self.mem
                    .get(key)?
                    .ok_or_else(|| Error::Data(format!("tier index out of sync for {key}")))
            }
            Tier::Disk => {
                let frame = self
                    .disk
                    .get(key)?
                    .ok_or_else(|| Error::Data(format!("tier index out of sync for {key}")))?;
                self.stats.disk_hits.fetch_add(1, Ordering::Relaxed);
                // Promote only into free headroom: promotion must never
                // spill residents (that would ping-pong hot sets around
                // the watermark).
                if idx.mem_bytes + size <= self.cfg.mem_high_watermark {
                    self.mem.put(key, &frame)?;
                    self.disk.remove(key)?;
                    if let Some(e) = idx.entries.get_mut(key) {
                        e.tier = Tier::Memory;
                    }
                    idx.mem_bytes += size;
                    self.stats.promotes.fetch_add(1, Ordering::Relaxed);
                }
                Ok(frame)
            }
        }
    }

    /// Resolve a [`DataRef`] against this store: owner + epoch must
    /// match, the key must be live, and the frame must verify against
    /// the ref's size/checksum.
    pub fn resolve(&self, r: &DataRef, now: Time) -> Result<Buffer> {
        if r.owner != self.owner || r.epoch != self.epoch {
            return Err(Error::NotFound(format!(
                "ref {}: owner/epoch does not match this store",
                r.key
            )));
        }
        let frame = self.get(&r.key, now)?;
        r.verify(frame.as_slice())?;
        Ok(frame)
    }

    /// Remove a key from whichever tier holds it.
    pub fn remove(&self, key: &str) -> Result<bool> {
        let mut idx = self.index.lock().expect("tiered index poisoned");
        match idx.entries.remove(key) {
            Some(e) => {
                match e.tier {
                    Tier::Memory => {
                        idx.mem_bytes -= e.size;
                        self.mem.remove(key)?;
                    }
                    Tier::Disk => {
                        self.disk.remove(key)?;
                    }
                }
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Eagerly drop every expired entry; returns how many were evicted.
    pub fn evict_expired(&self, now: Time) -> usize {
        let now = self.ttl_now(now);
        let mut idx = self.index.lock().expect("tiered index poisoned");
        let expired: Vec<String> = idx
            .entries
            .iter()
            .filter(|(_, e)| e.expires_at.is_some_and(|t| now >= t))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &expired {
            if let Some(e) = idx.entries.remove(k) {
                match e.tier {
                    Tier::Memory => {
                        idx.mem_bytes -= e.size;
                        let _ = self.mem.remove(k);
                    }
                    Tier::Disk => {
                        let _ = self.disk.remove(k);
                    }
                }
                self.stats.expirations.fetch_add(1, Ordering::Relaxed);
            }
        }
        expired.len()
    }

    /// Which tier holds `key` right now (None = absent). Ignores TTL —
    /// use [`TieredStore::live_tier`] for a resolvability answer.
    pub fn tier_of(&self, key: &str) -> Option<Tier> {
        self.index
            .lock()
            .expect("tiered index poisoned")
            .entries
            .get(key)
            .map(|e| e.tier)
    }

    /// Which tier holds a frame that is still live (not expired) at
    /// `now` — the non-destructive check behind
    /// [`crate::datastore::DataFabric::plan`]: a `Some` answer means
    /// [`TieredStore::get`] at the same `now` would succeed.
    pub fn live_tier(&self, key: &str, now: Time) -> Option<Tier> {
        let now = self.ttl_now(now);
        let idx = self.index.lock().expect("tiered index poisoned");
        let e = idx.entries.get(key)?;
        if e.expires_at.is_some_and(|t| now >= t) {
            return None;
        }
        Some(e.tier)
    }

    /// Number of live keys across both tiers.
    pub fn len(&self) -> usize {
        self.index.lock().expect("tiered index poisoned").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes resident in the memory tier.
    pub fn mem_bytes(&self) -> usize {
        self.index.lock().expect("tiered index poisoned").mem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    fn frame(byte: u8, len: usize) -> Buffer {
        Buffer::from_vec(vec![byte; len])
    }

    fn store(watermark: usize) -> TieredStore {
        TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: watermark, default_ttl_s: 0.0, spool_dir: None },
        )
        .unwrap()
    }

    #[test]
    fn put_get_roundtrip_memory() {
        let s = store(1 << 20);
        let f = frame(0xA5, 4096);
        let r = s.put("k", f.clone(), 0.0).unwrap();
        assert_eq!(r.size, 4096);
        assert_eq!(s.tier_of("k"), Some(Tier::Memory));
        let got = s.get("k", 0.0).unwrap();
        assert!(got.same_allocation(&f), "memory tier must hand back the same allocation");
        assert_eq!(s.stats.mem_hits.load(Relaxed), 1);
    }

    #[test]
    fn watermark_spills_lru_to_disk() {
        let s = store(10_000);
        s.put("a", frame(1, 4 << 10), 0.0).unwrap();
        s.put("b", frame(2, 4 << 10), 0.0).unwrap();
        // Touch a so b becomes LRU.
        s.get("a", 0.0).unwrap();
        s.put("c", frame(3, 4 << 10), 0.0).unwrap();
        assert_eq!(s.tier_of("b"), Some(Tier::Disk), "LRU key spills");
        assert_eq!(s.tier_of("a"), Some(Tier::Memory));
        assert_eq!(s.tier_of("c"), Some(Tier::Memory));
        assert!(s.mem_bytes() <= 10_000);
        assert_eq!(s.stats.spills.load(Relaxed), 1);
        // Disk hit returns the exact bytes.
        let got = s.get("b", 0.0).unwrap();
        assert_eq!(got.as_slice(), frame(2, 4 << 10).as_slice());
        assert_eq!(s.stats.disk_hits.load(Relaxed), 1);
    }

    #[test]
    fn disk_hit_promotes_into_headroom() {
        let s = store(10_000);
        s.put("a", frame(1, 4 << 10), 0.0).unwrap();
        s.put("b", frame(2, 4 << 10), 0.0).unwrap();
        s.put("c", frame(3, 4 << 10), 0.0).unwrap(); // spills "a"
        assert_eq!(s.tier_of("a"), Some(Tier::Disk));
        s.remove("b").unwrap(); // free headroom
        s.get("a", 0.0).unwrap();
        assert_eq!(s.tier_of("a"), Some(Tier::Memory), "promoted into freed headroom");
        assert_eq!(s.stats.promotes.load(Relaxed), 1);
        // Without headroom the frame keeps serving from disk.
        s.put("d", frame(4, 4 << 10), 0.0).unwrap();
        let spilled = s
            .index
            .lock()
            .unwrap()
            .entries
            .iter()
            .find(|(_, e)| e.tier == Tier::Disk)
            .map(|(k, _)| k.clone())
            .unwrap();
        s.get(&spilled, 0.0).unwrap();
        assert_eq!(s.tier_of(&spilled), Some(Tier::Disk), "no promotion without headroom");
    }

    #[test]
    fn ttl_expiry_is_not_found() {
        let s = TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 10.0, spool_dir: None },
        )
        .unwrap();
        let r = s.put("k", frame(1, 64), 0.0).unwrap();
        assert!(s.get("k", 5.0).is_ok());
        match s.get("k", 11.0) {
            Err(Error::NotFound(m)) => assert!(m.contains("expired"), "{m}"),
            other => panic!("expected NotFound, got {other:?}"),
        }
        // Gone for good — and resolving the ref reports NotFound too.
        assert!(matches!(s.get("k", 12.0), Err(Error::NotFound(_))));
        assert!(matches!(s.resolve(&r, 12.0), Err(Error::NotFound(_))));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn eager_eviction_and_ttl_override() {
        let s = store(1 << 20);
        s.put_with_ttl("short", frame(1, 64), Some(1.0), 0.0).unwrap();
        s.put_with_ttl("keep", frame(2, 64), Some(0.0), 0.0).unwrap(); // no expiry
        assert_eq!(s.evict_expired(0.5), 0);
        assert_eq!(s.evict_expired(2.0), 1);
        assert!(s.get("keep", 1e9).is_ok());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn stale_epoch_and_wrong_owner_rejected() {
        let a = store(1 << 20);
        let b = store(1 << 20);
        let r = a.put("k", frame(1, 64), 0.0).unwrap();
        assert!(matches!(b.resolve(&r, 0.0), Err(Error::NotFound(_))));
        assert!(a.resolve(&r, 0.0).is_ok());
        assert_ne!(a.epoch(), b.epoch());
    }

    #[test]
    fn overwrite_replaces_and_reaccounts() {
        let s = store(10_000);
        s.put("k", frame(1, 8 << 10), 0.0).unwrap();
        assert_eq!(s.mem_bytes(), 8 << 10);
        let r = s.put("k", frame(2, 1 << 10), 0.0).unwrap();
        assert_eq!(s.mem_bytes(), 1 << 10);
        assert_eq!(s.len(), 1);
        let got = s.resolve(&r, 0.0).unwrap();
        assert_eq!(got.as_slice(), frame(2, 1 << 10).as_slice());
    }

    #[test]
    fn owner_clock_overrides_reader_skew() {
        let vc = crate::common::time::VirtualClock::new();
        let s = TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 10.0, spool_dir: None },
        )
        .unwrap()
        .with_owner_clock(Arc::new(vc.clone()));
        let r = s.put("k", frame(1, 64), 777.0).unwrap(); // caller's now is ignored
        // A reader whose clock runs far ahead cannot expire the entry…
        assert!(s.get("k", 1e6).is_ok());
        assert!(s.resolve(&r, 1e6).is_ok());
        assert_eq!(s.live_tier("k", 1e6), Some(Tier::Memory));
        // …and one running far behind cannot resurrect it once the
        // owner's clock passes the stamp.
        vc.advance_to(11.0);
        assert_eq!(s.live_tier("k", -1e6), None);
        assert!(matches!(s.get("k", -1e6), Err(Error::NotFound(_))));
    }

    #[test]
    fn recover_readopts_spilled_frames_under_the_old_epoch() {
        let dir =
            std::env::temp_dir().join(format!("funcx-tiered-recover-{}", crate::Uuid::new()));
        let owner = EndpointId::new();
        let cfg = TieredConfig {
            mem_high_watermark: 0, // everything spills immediately
            default_ttl_s: 0.0,
            spool_dir: Some(dir.clone()),
        };
        let (r1, epoch1, bytes) = {
            let s = TieredStore::new(owner, cfg.clone()).unwrap();
            let f = frame(0x3C, 8 << 10);
            let r = s.put("k1", f.clone(), 0.0).unwrap();
            s.put("k2", frame(0x4D, 4 << 10), 0.0).unwrap();
            assert_eq!(s.tier_of("k1"), Some(Tier::Disk));
            let (epoch, bytes) = (s.epoch(), f.to_vec());
            std::mem::forget(s); // crash: no Drop, no cleanup
            (r, epoch, bytes)
        };
        let s2 = TieredStore::recover(owner, cfg.clone()).unwrap();
        assert_eq!(s2.epoch(), epoch1, "recovery adopts the crashed store's epoch");
        assert_eq!(s2.len(), 2);
        let got = s2.resolve(&r1, 0.0).unwrap();
        assert_eq!(got.as_slice(), &bytes[..], "readopted frame resolves byte-identical");
        // A *fresh* store over the same dir instead reclaims everything.
        drop(s2);
        let s3 = TieredStore::new(owner, cfg).unwrap();
        assert_eq!(s3.len(), 0);
        assert!(matches!(s3.resolve(&r1, 0.0), Err(Error::NotFound(_))));
        assert_ne!(s3.epoch(), epoch1);
        drop(s3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn oversized_single_frame_spills_itself() {
        let s = store(1 << 10);
        s.put("big", frame(9, 64 << 10), 0.0).unwrap();
        assert_eq!(s.tier_of("big"), Some(Tier::Disk));
        assert_eq!(s.mem_bytes(), 0);
        // Serves from disk, never promotes (larger than the watermark).
        let got = s.get("big", 0.0).unwrap();
        assert_eq!(got.len(), 64 << 10);
        assert_eq!(s.tier_of("big"), Some(Tier::Disk));
    }
}
