//! The per-endpoint data-fabric handle: where [`DataRef`]s get resolved.
//!
//! Resolution walks a fetch fallback ladder (cheapest first):
//!
//! 1. **Local store** — the ref is owned by this endpoint's
//!    [`TieredStore`] (memory or disk tier).
//! 2. **Resolve cache** — a hit-counting cache of frames previously
//!    fetched from other endpoints.
//! 3. **Peer forward** — the owning endpoint's store is reachable
//!    directly; the frame moves endpoint-to-endpoint as raw wire bytes
//!    (in-process: another handle on the same allocation — no decode,
//!    no re-encode).
//! 4. **Globus model** — refs at or above the wide-area threshold are
//!    routed through the [`TransferService`] cost model (§5.1): a
//!    third-party transfer is submitted between the endpoints' storage
//!    endpoints and its modeled duration is observable via
//!    [`DataFabric::plan`] / the transfer service itself.
//!
//! An unreachable owner, a stale epoch, or an evicted/expired key
//! surfaces [`Error::NotFound`] — never a panic — so a re-dispatched
//! task whose input aged out fails cleanly at the worker.
//!
//! Under the sharded service plane (see `docs/architecture.md`), each
//! forwarder shard carries its own fabric: the shard stores are
//! full-mesh peered with each other at service build, and every
//! endpoint store advertised up any link is peered into *every* shard's
//! fabric — so the ladder above resolves refs across shard boundaries
//! without the bytes ever transiting the service inline.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::common::error::{Error, Result};
use crate::common::ids::{EndpointId, Uuid};
use crate::common::rng::Rng;
use crate::common::time::Time;
use crate::datastore::dataref::DataRef;
use crate::datastore::tiered::{Tier, TieredStore};
use crate::metrics::{Counters, FlightRecorder, ResolveSource, SnapshotBuilder, TraceKind};
use crate::serialize::Buffer;
use crate::transfer::{GlobusFile, TransferService};

/// Monotone fabric counters (tests/telemetry).
#[derive(Default)]
pub struct FabricStats {
    pub local_hits: AtomicU64,
    pub cache_hits: AtomicU64,
    /// Frames fetched endpoint-to-endpoint as raw wire bytes.
    pub frames_forwarded: AtomicU64,
    pub bytes_forwarded: AtomicU64,
    /// Fetches routed through the Globus transfer model.
    pub globus_transfers: AtomicU64,
    pub misses: AtomicU64,
    /// Frames eagerly reclaimed from their owning store via
    /// [`DataFabric::reclaim`] (result-frame GC).
    pub frames_reclaimed: AtomicU64,
    /// Resolutions that completed via a replica after the owner's copy
    /// was unreachable or gone (the failover half of replication).
    pub failovers: AtomicU64,
    /// Transient peer-fetch failures that were retried (bounded,
    /// jittered backoff) instead of surfacing — a flapping link is not
    /// a missing frame.
    pub peer_retries: AtomicU64,
    /// Peers connected lazily on first fabric miss via the registry
    /// peer source (see [`DataFabric::with_registry`]) instead of
    /// hand-wired `connect_peer` calls.
    pub lazy_peers: AtomicU64,
}

impl FabricStats {
    /// Export every fabric counter into a metrics snapshot under the
    /// given dimensions (the registry-source adapter).
    pub fn fill(&self, b: &mut SnapshotBuilder, dims: &[(&str, &str)]) {
        b.counter("funcx_fabric_local_hits_total", dims, self.local_hits.load(Ordering::Relaxed));
        b.counter("funcx_fabric_cache_hits_total", dims, self.cache_hits.load(Ordering::Relaxed));
        b.counter(
            "funcx_fabric_frames_forwarded_total",
            dims,
            self.frames_forwarded.load(Ordering::Relaxed),
        );
        b.counter(
            "funcx_fabric_bytes_forwarded_total",
            dims,
            self.bytes_forwarded.load(Ordering::Relaxed),
        );
        b.counter(
            "funcx_fabric_globus_transfers_total",
            dims,
            self.globus_transfers.load(Ordering::Relaxed),
        );
        b.counter("funcx_fabric_misses_total", dims, self.misses.load(Ordering::Relaxed));
        b.counter(
            "funcx_fabric_frames_reclaimed_total",
            dims,
            self.frames_reclaimed.load(Ordering::Relaxed),
        );
        b.counter("funcx_fabric_failovers_total", dims, self.failovers.load(Ordering::Relaxed));
        b.counter(
            "funcx_fabric_peer_retries_total",
            dims,
            self.peer_retries.load(Ordering::Relaxed),
        );
        b.counter(
            "funcx_fabric_lazy_peers_total",
            dims,
            self.lazy_peers.load(Ordering::Relaxed),
        );
    }
}

/// Peer-fetch attempts before a transient failure surfaces: the first
/// try plus two retries under jittered exponential backoff.
const PEER_FETCH_ATTEMPTS: u32 = 3;

/// Base backoff before the first retry, milliseconds (doubled per
/// attempt, jittered ×[0.5, 1.5)).
const RETRY_BASE_MS: f64 = 2.0;

/// Transient fetch failures worth retrying: I/O trouble, index
/// livelock, timeouts — the flapping-link shapes. `NotFound` and
/// `Corrupt` are authoritative answers about the frame itself and
/// retrying them cannot help.
fn is_transient(e: &Error) -> bool {
    matches!(e, Error::Io(_) | Error::Data(_) | Error::Timeout(_))
}

/// How a given ref would be (or was) fetched — the ladder decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FetchPlan {
    LocalMemory,
    LocalDisk,
    Cache,
    /// Direct endpoint-to-endpoint frame forward.
    PeerForward,
    /// Wide-area movement through the Globus model, with its estimated
    /// duration in seconds.
    Globus { est_s: f64 },
    Unavailable,
}

struct CacheEntry {
    frame: Buffer,
    checksum: u64,
    hits: u64,
    /// Monotone access stamp (LRU eviction order) — newest insert/hit
    /// wins, so fresh entries are never the immediate victims and cold
    /// old frames cannot pin their allocations forever.
    last_used: u64,
}

struct WideArea {
    transfer: TransferService,
    /// funcX endpoint → Globus storage endpoint fronting its spool.
    storage_of: HashMap<EndpointId, Uuid>,
    /// Refs at or above this size go through the Globus model.
    threshold_bytes: u64,
}

/// Byte budget for the resolve cache. Bounded by *bytes*, not entries:
/// frames are shared handles, and owners reclaim their copies on task
/// completion, so a cached frame may be the last live reference to a
/// large allocation — an entry-count cap could pin gigabytes.
const CACHE_MAX_BYTES: usize = 64 * 1024 * 1024;

struct CacheMap {
    entries: HashMap<String, CacheEntry>,
    /// Total frame bytes currently cached.
    bytes: usize,
}

/// Lazily supplies a peer endpoint's store on first fabric miss — the
/// registry-backed alternative to hand-wiring every peer up front with
/// [`DataFabric::connect_peer`]. Returns `None` for owners with no
/// advertised store (dead, decommissioned, never registered).
pub type PeerSource = Box<dyn Fn(EndpointId) -> Option<Arc<TieredStore>> + Send + Sync>;

/// The per-endpoint resolver handle. Share via `Arc`; workers resolve
/// through it, the service submits through it.
pub struct DataFabric {
    local: Arc<TieredStore>,
    cache: Mutex<CacheMap>,
    /// Monotone stamp source for the cache's LRU order.
    cache_seq: AtomicU64,
    peers: Mutex<HashMap<EndpointId, Arc<TieredStore>>>,
    /// Lazy peering fallback consulted when `peers` misses an owner;
    /// a hit is connected into `peers` (and counted in `lazy_peers`)
    /// so subsequent resolves take the fast path.
    peer_source: OnceLock<PeerSource>,
    wide_area: Mutex<Option<WideArea>>,
    /// Deployment-wide metrics sink (failover resolutions, shed puts):
    /// endpoint-side fabric events land in the same `Counters` the
    /// service asserts on.
    counters: OnceLock<Arc<Counters>>,
    /// Flight recorder plus this fabric's prebuilt component name
    /// (`fabric-<owner>`): resolve-ladder outcomes become trace events,
    /// attributed to the ambient [`crate::metrics::TraceCtx`] when the
    /// resolve runs under a task.
    recorder: OnceLock<(Arc<FlightRecorder>, String)>,
    pub stats: FabricStats,
}

fn cache_key(r: &DataRef) -> String {
    format!("{}:{}:{}", r.owner, r.epoch, r.key)
}

impl DataFabric {
    pub fn new(local: Arc<TieredStore>) -> Self {
        DataFabric {
            local,
            cache: Mutex::new(CacheMap { entries: HashMap::new(), bytes: 0 }),
            cache_seq: AtomicU64::new(0),
            peers: Mutex::new(HashMap::new()),
            peer_source: OnceLock::new(),
            wide_area: Mutex::new(None),
            counters: OnceLock::new(),
            recorder: OnceLock::new(),
            stats: FabricStats::default(),
        }
    }

    /// Sink endpoint-side fabric events (failover resolutions, shed
    /// puts) into a deployment-wide [`Counters`]. First call wins.
    pub fn with_counters(&self, counters: Arc<Counters>) {
        let _ = self.counters.set(counters);
    }

    /// Attach the task flight recorder: every resolve-ladder outcome
    /// (hit and where, bounded retry, replica failover, exhausted miss,
    /// shed put) is recorded on component `fabric-<owner>`. First call
    /// wins.
    pub fn with_recorder(&self, rec: Arc<FlightRecorder>) {
        let _ = self.recorder.set((rec, format!("fabric-{}", self.local.owner())));
    }

    fn trace_event(&self, at: Time, kind: TraceKind) {
        if let Some((rec, component)) = self.recorder.get() {
            rec.record_ambient(component, at, kind);
        }
    }

    /// This endpoint's own tiered store.
    pub fn local(&self) -> &Arc<TieredStore> {
        &self.local
    }

    /// Make a peer endpoint's store directly reachable (the
    /// endpoint-to-endpoint forwarding path).
    pub fn connect_peer(&self, owner: EndpointId, store: Arc<TieredStore>) {
        self.peers.lock().expect("fabric peers poisoned").insert(owner, store);
    }

    /// Install a lazy peer source: on the first fabric miss for a
    /// foreign owner, the source is asked for that owner's store and a
    /// hit is connected as a peer — no hand-wired `connect_peer` mesh
    /// required. First call wins.
    pub fn with_peer_source(&self, source: PeerSource) {
        let _ = self.peer_source.set(source);
    }

    /// Lazy peering backed by the service registry: foreign owners
    /// resolve through their last advertised store
    /// ([`crate::registry::Registry::advertise_store`]), discovered on
    /// first miss. A decommissioned endpoint withdraws its
    /// advertisement before its peers disconnect, so the source never
    /// revives a retired store. First call wins.
    pub fn with_registry(&self, registry: crate::registry::Registry) {
        self.with_peer_source(Box::new(move |owner| registry.advertised_store(owner)));
    }

    /// The owner's peer store: connected peers first, then the lazy
    /// peer source (a hit is connected for next time and counted).
    fn peer_of(&self, owner: EndpointId) -> Option<Arc<TieredStore>> {
        if let Some(p) = self.peers.lock().expect("fabric peers poisoned").get(&owner) {
            return Some(p.clone());
        }
        let store = self.peer_source.get().and_then(|source| source(owner))?;
        self.stats.lazy_peers.fetch_add(1, Ordering::Relaxed);
        self.peers.lock().expect("fabric peers poisoned").insert(owner, store.clone());
        Some(store)
    }

    /// Sever a peer (endpoint lost/disconnected): refs owned there
    /// resolve to [`Error::NotFound`] from now on — except frames
    /// already in the resolve cache, which keep serving (they were
    /// fetched and verified while the peer was up).
    pub fn disconnect_peer(&self, owner: EndpointId) -> bool {
        self.peers.lock().expect("fabric peers poisoned").remove(&owner).is_some()
    }

    /// Enable the wide-area (Globus) fallback for refs at or above
    /// `threshold_bytes`.
    pub fn with_wide_area(&self, transfer: TransferService, threshold_bytes: u64) {
        *self.wide_area.lock().expect("fabric wide-area poisoned") =
            Some(WideArea { transfer, storage_of: HashMap::new(), threshold_bytes });
    }

    /// Map a funcX endpoint to the Globus storage endpoint fronting its
    /// spool (required for the wide-area fallback on that endpoint).
    pub fn map_storage(&self, endpoint: EndpointId, storage: Uuid) {
        if let Some(wa) = self.wide_area.lock().expect("fabric wide-area poisoned").as_mut() {
            wa.storage_of.insert(endpoint, storage);
        }
    }

    /// Store a frame in the local store; returns the ref to dispatch.
    /// A shed write (spill backpressure, [`Error::Overloaded`]) is
    /// counted into the deployment-wide sink before it surfaces.
    pub fn put(&self, key: &str, frame: Buffer, now: Time) -> Result<DataRef> {
        let out = self.local.put(key, frame, now);
        if matches!(&out, Err(Error::Overloaded(_))) {
            if let Some(c) = self.counters.get() {
                Counters::incr(&c.shed_puts);
            }
        }
        out
    }

    /// Resolve a ref down the fetch ladder (see module docs), failing
    /// over to replicas when the owner's copy is gone or unreachable:
    /// local/cached copy → owner (peer forward with bounded retry /
    /// Globus) → listed replicas → replica scan over connected peers.
    pub fn resolve(&self, r: &DataRef, now: Time) -> Result<Buffer> {
        // 1. Local store.
        if r.owner == self.local.owner() && r.epoch == self.local.epoch() {
            match self.local.resolve(r, now) {
                Ok(f) => {
                    self.stats.local_hits.fetch_add(1, Ordering::Relaxed);
                    self.trace_event(
                        now,
                        TraceKind::RefResolved {
                            key: r.key.clone(),
                            source: ResolveSource::Local,
                        },
                    );
                    return Ok(f);
                }
                Err(e) => {
                    // The owner's own copy is gone (evicted, expired,
                    // damaged): replicas are the last word before the
                    // typed error surfaces.
                    if let Some(f) = self.resolve_replicas(r, now) {
                        return Ok(f);
                    }
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    self.trace_event(
                        now,
                        TraceKind::ResolveFailed { key: r.key.clone(), error: e.kind() },
                    );
                    return Err(e);
                }
            }
        }
        // 2. Hit-counting resolve cache.
        if let Some(frame) = self.cache_lookup(r) {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            self.trace_event(
                now,
                TraceKind::RefResolved { key: r.key.clone(), source: ResolveSource::Cache },
            );
            return Ok(frame);
        }
        // 3. Peer forward (raw frame handle) / 4. Globus model. A
        // first miss on a foreign owner may connect the peer lazily
        // from the registry's advertised store (see `with_registry`).
        let peer = self.peer_of(r.owner);
        if let Some(peer) = peer {
            let frame = match self.peer_fetch_with_retry(&peer, r, now) {
                Ok(f) => f,
                Err(e) => {
                    if let Some(f) = self.resolve_replicas(r, now) {
                        return Ok(f);
                    }
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    self.trace_event(
                        now,
                        TraceKind::ResolveFailed { key: r.key.clone(), error: e.kind() },
                    );
                    return Err(e);
                }
            };
            if self.submit_globus(r, now).is_some() {
                self.stats.globus_transfers.fetch_add(1, Ordering::Relaxed);
                self.trace_event(
                    now,
                    TraceKind::RefResolved { key: r.key.clone(), source: ResolveSource::Globus },
                );
            } else {
                self.stats.frames_forwarded.fetch_add(1, Ordering::Relaxed);
                self.stats.bytes_forwarded.fetch_add(r.size, Ordering::Relaxed);
                self.trace_event(
                    now,
                    TraceKind::RefResolved { key: r.key.clone(), source: ResolveSource::Peer },
                );
            }
            self.cache_insert(r, frame.clone());
            return Ok(frame);
        }
        // Owner not connected at all (dead or decommissioned): replicas
        // are the only path left.
        if let Some(f) = self.resolve_replicas(r, now) {
            return Ok(f);
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        self.trace_event(now, TraceKind::ResolveFailed { key: r.key.clone(), error: "NotFound" });
        Err(Error::NotFound(format!(
            "ref {}: owner {} unreachable from this endpoint",
            r.key, r.owner
        )))
    }

    /// Fetch from the owning peer with bounded, jittered retry:
    /// transient failures (I/O, index livelock, timeout — the
    /// flapping-link shapes) are retried up to [`PEER_FETCH_ATTEMPTS`]
    /// before the error surfaces; authoritative answers (`NotFound`,
    /// `Corrupt`) return immediately.
    fn peer_fetch_with_retry(
        &self,
        peer: &Arc<TieredStore>,
        r: &DataRef,
        now: Time,
    ) -> Result<Buffer> {
        let mut rng = Rng::from_entropy();
        let mut last: Option<Error> = None;
        for attempt in 0..PEER_FETCH_ATTEMPTS {
            if attempt > 0 {
                self.stats.peer_retries.fetch_add(1, Ordering::Relaxed);
                self.trace_event(now, TraceKind::PeerRetry { key: r.key.clone(), attempt });
                let backoff_ms =
                    RETRY_BASE_MS * f64::from(1 << (attempt - 1)) * rng.range_f64(0.5, 1.5);
                std::thread::sleep(Duration::from_micros((backoff_ms * 1000.0) as u64));
            }
            match peer.resolve(r, now) {
                Ok(f) => return Ok(f),
                Err(e) if is_transient(&e) => last = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(last.expect("at least one attempt ran"))
    }

    /// The failover half of replication: try every endpoint holding a
    /// replica of `r` — the ref's listed replica set first (preference
    /// order), then a scan over this endpoint's own store and every
    /// connected peer, because a decommission drain may have re-homed
    /// the frame to an endpoint the ref was minted before knowing
    /// about. Replica frames live under [`DataRef::replica_key`] in the
    /// *holder's* store (the holder's own owner/epoch), so fetches go
    /// through `get` plus the ref's size/checksum verify rather than
    /// the owner/epoch-gated `resolve`.
    fn resolve_replicas(&self, r: &DataRef, now: Time) -> Option<Buffer> {
        let rkey = r.replica_key();
        let fetch = |store: &TieredStore| -> Option<Buffer> {
            let f = store.get(&rkey, now).ok()?;
            r.verify(f.as_slice()).ok()?;
            Some(f)
        };
        // `None` source = served from this endpoint's own store.
        let mut hit: Option<(Option<EndpointId>, Buffer)> = None;
        for rep in &r.replicas {
            if *rep == self.local.owner() {
                if let Some(f) = fetch(&self.local) {
                    hit = Some((None, f));
                    break;
                }
            } else {
                let peer = self.peer_of(*rep);
                if let Some(p) = peer {
                    if let Some(f) = fetch(&p) {
                        hit = Some((Some(*rep), f));
                        break;
                    }
                }
            }
        }
        if hit.is_none() && !r.replicas.contains(&self.local.owner()) {
            if let Some(f) = fetch(&self.local) {
                hit = Some((None, f));
            }
        }
        if hit.is_none() {
            let peers: Vec<(EndpointId, Arc<TieredStore>)> = self
                .peers
                .lock()
                .expect("fabric peers poisoned")
                .iter()
                .map(|(id, p)| (*id, p.clone()))
                .collect();
            for (id, p) in peers {
                if r.replicas.contains(&id) {
                    continue; // already tried above
                }
                if let Some(f) = fetch(&p) {
                    hit = Some((Some(id), f));
                    break;
                }
            }
        }
        let (src, frame) = hit?;
        self.stats.failovers.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.counters.get() {
            Counters::incr(&c.failover_resolutions);
        }
        if src.is_some() {
            self.stats.frames_forwarded.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes_forwarded.fetch_add(r.size, Ordering::Relaxed);
        }
        self.trace_event(now, TraceKind::ReplicaFailover { key: r.key.clone() });
        self.trace_event(
            now,
            TraceKind::RefResolved { key: r.key.clone(), source: ResolveSource::Replica },
        );
        self.cache_insert(r, frame.clone());
        Some(frame)
    }

    /// Eagerly reclaim the frame behind `r` from its owning store — the
    /// consumed-result GC path: once a result ref has been retrieved (or
    /// its consuming chain task has completed), the frame need not sit
    /// in the owner's store until TTL. Reaches the local store or a
    /// connected peer, and always drops any cached copy so the bytes are
    /// actually freed. Returns whether the owner's copy was removed (a
    /// vanished frame or unreachable owner is not an error — GC is
    /// best-effort).
    pub fn reclaim(&self, r: &DataRef) -> bool {
        // Drop the cached copy regardless of owner reachability.
        {
            let mut c = self.cache.lock().expect("fabric cache poisoned");
            if let Some(e) = c.entries.remove(&cache_key(r)) {
                c.bytes -= e.frame.len();
            }
        }
        let removed = if r.owner == self.local.owner() && r.epoch == self.local.epoch() {
            self.local.remove(&r.key).unwrap_or(false)
        } else {
            let peer = self.peer_of(r.owner);
            match peer {
                Some(p) if p.epoch() == r.epoch => p.remove(&r.key).unwrap_or(false),
                _ => false,
            }
        };
        if removed {
            self.stats.frames_reclaimed.fetch_add(1, Ordering::Relaxed);
        }
        removed
    }

    /// The ladder decision for `r` without fetching anything. TTL-aware:
    /// an expired local entry reports `Unavailable`, matching what
    /// [`DataFabric::resolve`] at the same `now` would return.
    pub fn plan(&self, r: &DataRef, now: Time) -> FetchPlan {
        if r.owner == self.local.owner() && r.epoch == self.local.epoch() {
            return match self.local.live_tier(&r.key, now) {
                Some(Tier::Memory) => FetchPlan::LocalMemory,
                Some(Tier::Disk) => FetchPlan::LocalDisk,
                None => FetchPlan::Unavailable,
            };
        }
        if self
            .cache
            .lock()
            .expect("fabric cache poisoned")
            .entries
            .get(&cache_key(r))
            .is_some_and(|e| e.checksum == r.checksum)
        {
            return FetchPlan::Cache;
        }
        // Read-only reachability: a connected peer, or an owner the
        // lazy source could supply — `plan` never connects anything.
        let reachable = self.peers.lock().expect("fabric peers poisoned").contains_key(&r.owner)
            || self.peer_source.get().is_some_and(|source| source(r.owner).is_some());
        if reachable {
            if let Some(est_s) = self.estimate_globus(r) {
                return FetchPlan::Globus { est_s };
            }
            return FetchPlan::PeerForward;
        }
        FetchPlan::Unavailable
    }

    /// How often the cached copy of `r` has been consulted.
    pub fn cache_hits_of(&self, r: &DataRef) -> u64 {
        self.cache
            .lock()
            .expect("fabric cache poisoned")
            .entries
            .get(&cache_key(r))
            .map(|e| e.hits)
            .unwrap_or(0)
    }

    /// Estimated wide-area duration for `r`, when the ladder would route
    /// it through Globus.
    fn estimate_globus(&self, r: &DataRef) -> Option<f64> {
        let g = self.wide_area.lock().expect("fabric wide-area poisoned");
        let wa = g.as_ref()?;
        if r.size < wa.threshold_bytes {
            return None;
        }
        let src = *wa.storage_of.get(&r.owner)?;
        let dst = *wa.storage_of.get(&self.local.owner())?;
        let file =
            GlobusFile { endpoint: src, path: format!("/spool/{}", r.key), size_bytes: r.size };
        wa.transfer.estimate_file(&file, dst).ok()
    }

    /// Submit the modeled third-party transfer for a GlobusFile-sized
    /// ref; returns its completion time when the fallback applies.
    fn submit_globus(&self, r: &DataRef, now: Time) -> Option<Time> {
        let g = self.wide_area.lock().expect("fabric wide-area poisoned");
        let wa = g.as_ref()?;
        if r.size < wa.threshold_bytes {
            return None;
        }
        let src = *wa.storage_of.get(&r.owner)?;
        let dst = *wa.storage_of.get(&self.local.owner())?;
        let file =
            GlobusFile { endpoint: src, path: format!("/spool/{}", r.key), size_bytes: r.size };
        let id = wa.transfer.submit(&file, dst, &format!("/spool/{}", r.key), now).ok()?;
        wa.transfer.completion_time(id).ok()
    }

    /// Bytes currently held by the resolve cache (telemetry/tests).
    pub fn cache_bytes(&self) -> usize {
        self.cache.lock().expect("fabric cache poisoned").bytes
    }

    fn cache_lookup(&self, r: &DataRef) -> Option<Buffer> {
        let mut c = self.cache.lock().expect("fabric cache poisoned");
        let e = c.entries.get_mut(&cache_key(r))?;
        if e.checksum != r.checksum {
            return None;
        }
        e.hits += 1;
        e.last_used = self.cache_seq.fetch_add(1, Ordering::Relaxed);
        Some(e.frame.clone())
    }

    fn cache_insert(&self, r: &DataRef, frame: Buffer) {
        let size = frame.len();
        let mut c = self.cache.lock().expect("fabric cache poisoned");
        // Replace-in-place re-accounts the old size; no victim needed
        // for a same-key overwrite that doesn't grow the cache.
        if let Some(old) = c.entries.insert(
            cache_key(r),
            CacheEntry {
                frame,
                checksum: r.checksum,
                hits: 0,
                last_used: self.cache_seq.fetch_add(1, Ordering::Relaxed),
            },
        ) {
            c.bytes -= old.frame.len();
        }
        c.bytes += size;
        // Evict least-recently-used entries (NOT fewest-hits: that
        // would make every fresh insert the next victim while old
        // once-hit frames pinned their allocations forever) until the
        // byte budget holds. A single frame larger than the budget is
        // simply not retained.
        while c.bytes > CACHE_MAX_BYTES && !c.entries.is_empty() {
            let victim = c
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            if let Some(e) = c.entries.remove(&k) {
                c.bytes -= e.frame.len();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::tiered::TieredConfig;
    use std::sync::atomic::Ordering::Relaxed;

    fn store() -> Arc<TieredStore> {
        Arc::new(
            TieredStore::new(
                EndpointId::new(),
                TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 0.0, spool_dir: None },
            )
            .unwrap(),
        )
    }

    fn frame(len: usize) -> Buffer {
        Buffer::from_vec(vec![0x42; len])
    }

    #[test]
    fn local_resolution() {
        let s = store();
        let fab = DataFabric::new(s.clone());
        let r = fab.put("k", frame(256), 0.0).unwrap();
        assert_eq!(fab.plan(&r, 0.0), FetchPlan::LocalMemory);
        let got = fab.resolve(&r, 0.0).unwrap();
        assert_eq!(got.len(), 256);
        assert_eq!(fab.stats.local_hits.load(Relaxed), 1);
    }

    #[test]
    fn peer_forward_shares_the_frame_and_caches() {
        let a = store();
        let b = store();
        let fab = DataFabric::new(b);
        fab.connect_peer(a.owner(), a.clone());
        let f = frame(1024);
        let r = a.put("k", f.clone(), 0.0).unwrap();
        assert_eq!(fab.plan(&r, 0.0), FetchPlan::PeerForward);
        let got = fab.resolve(&r, 0.0).unwrap();
        assert!(got.same_allocation(&f), "peer forward must hand over the raw frame");
        assert_eq!(fab.stats.frames_forwarded.load(Relaxed), 1);
        assert_eq!(fab.stats.bytes_forwarded.load(Relaxed), 1024);
        // Second resolve: cache hit, counted on the entry.
        assert_eq!(fab.plan(&r, 0.0), FetchPlan::Cache);
        let again = fab.resolve(&r, 0.0).unwrap();
        assert!(again.same_allocation(&f));
        assert_eq!(fab.stats.cache_hits.load(Relaxed), 1);
        assert_eq!(fab.cache_hits_of(&r), 1);
        assert_eq!(fab.stats.frames_forwarded.load(Relaxed), 1, "no re-fetch");
    }

    /// Lazy peering: no hand-wired `connect_peer` — the first miss on a
    /// foreign owner pulls the store from the peer source, counts the
    /// lazy connect, and later resolves ride the connected peer.
    #[test]
    fn first_miss_connects_peer_from_source() {
        let owner = store();
        let fab = DataFabric::new(store());
        let supply = owner.clone();
        let asked = Arc::new(AtomicU64::new(0));
        let asked_in = asked.clone();
        fab.with_peer_source(Box::new(move |id| {
            asked_in.fetch_add(1, Relaxed);
            (id == supply.owner()).then(|| supply.clone())
        }));
        let f = frame(1024);
        let r = owner.put("k", f.clone(), 0.0).unwrap();
        // plan() sees reachability without connecting anything.
        assert_eq!(fab.plan(&r, 0.0), FetchPlan::PeerForward);
        assert_eq!(fab.stats.lazy_peers.load(Relaxed), 0, "plan is read-only");
        let got = fab.resolve(&r, 0.0).unwrap();
        assert!(got.same_allocation(&f), "lazy peer still forwards the raw frame");
        assert_eq!(fab.stats.lazy_peers.load(Relaxed), 1);
        assert_eq!(fab.stats.frames_forwarded.load(Relaxed), 1);
        // The peer is connected now: a cache-missed re-resolve must not
        // consult the source again.
        let before = asked.load(Relaxed);
        fab.reclaim(&r); // drops the cached copy
        let r2 = owner.put("k", f.clone(), 0.0).unwrap();
        fab.resolve(&r2, 0.0).unwrap();
        assert_eq!(asked.load(Relaxed), before, "second resolve rides the connected peer");
        assert_eq!(fab.stats.lazy_peers.load(Relaxed), 1);
        // An owner the source cannot supply still types NotFound.
        let dead = store().put("x", frame(16), 0.0).unwrap();
        assert!(matches!(fab.resolve(&dead, 0.0), Err(Error::NotFound(_))));
        assert_eq!(fab.plan(&dead, 0.0), FetchPlan::Unavailable);
    }

    #[test]
    fn unreachable_owner_is_not_found() {
        let fab = DataFabric::new(store());
        let r = DataRef {
            owner: EndpointId::new(),
            epoch: 1,
            key: "k".into(),
            size: 1,
            checksum: 0,
            replicas: Vec::new(),
        };
        assert!(matches!(fab.resolve(&r, 0.0), Err(Error::NotFound(_))));
        assert_eq!(fab.plan(&r, 0.0), FetchPlan::Unavailable);
        assert_eq!(fab.stats.misses.load(Relaxed), 1);
    }

    #[test]
    fn globus_fallback_for_large_refs() {
        let a = store();
        let b = store();
        let fab = DataFabric::new(b.clone());
        fab.connect_peer(a.owner(), a.clone());
        let ts = TransferService::new();
        let ga = ts.register_endpoint("a#dtn", 1.25e9, 2.0);
        let gb = ts.register_endpoint("b#dtn", 1.25e9, 2.0);
        fab.with_wide_area(ts.clone(), 1 << 20);
        fab.map_storage(a.owner(), ga);
        fab.map_storage(b.owner(), gb);

        // Below threshold: direct forward, no transfer submitted.
        let small = a.put("small", frame(512), 0.0).unwrap();
        assert_eq!(fab.plan(&small, 0.0), FetchPlan::PeerForward);
        fab.resolve(&small, 0.0).unwrap();
        assert_eq!(fab.stats.globus_transfers.load(Relaxed), 0);

        // At/above threshold: the Globus model carries it.
        let big = a.put("big", frame(2 << 20), 0.0).unwrap();
        match fab.plan(&big, 0.0) {
            FetchPlan::Globus { est_s } => assert!(est_s > 2.0, "setup + wire time, got {est_s}"),
            other => panic!("expected Globus plan, got {other:?}"),
        }
        let got = fab.resolve(&big, 0.0).unwrap();
        assert_eq!(got.len(), 2 << 20);
        assert_eq!(fab.stats.globus_transfers.load(Relaxed), 1);
        assert!(ts.in_flight_bytes(ga, gb, 0.5) >= (2 << 20) as u64);
    }

    /// Result-frame GC: reclaiming a consumed ref frees the owner's copy
    /// (local or peer) *and* the resolve-cache copy, after which the ref
    /// is NotFound everywhere — and reclaiming again is a no-op.
    #[test]
    fn reclaim_frees_owner_and_cache_copies() {
        use std::sync::atomic::Ordering::Relaxed;
        // Local owner.
        let s = store();
        let fab = DataFabric::new(s.clone());
        let r = fab.put("task-result:x", frame(512), 0.0).unwrap();
        assert!(fab.reclaim(&r), "local reclaim removes the frame");
        assert!(!fab.reclaim(&r), "second reclaim is a no-op");
        assert!(matches!(fab.resolve(&r, 0.0), Err(Error::NotFound(_))));
        assert_eq!(fab.stats.frames_reclaimed.load(Relaxed), 1);

        // Peer owner, with the frame already verified into the cache.
        let owner = store();
        let fab2 = DataFabric::new(store());
        fab2.connect_peer(owner.owner(), owner.clone());
        let r2 = owner.put("task-result:y", frame(1024), 0.0).unwrap();
        fab2.resolve(&r2, 0.0).unwrap(); // warms the cache
        assert!(fab2.cache_bytes() > 0);
        assert!(fab2.reclaim(&r2), "peer reclaim removes the owner's frame");
        assert_eq!(fab2.cache_bytes(), 0, "cached copy dropped too");
        assert!(matches!(fab2.resolve(&r2, 0.0), Err(Error::NotFound(_))));
    }

    /// Killing the owner must not kill the ref: a replica listed in the
    /// ref's replica set serves the frame (verified against the ref's
    /// checksum) and the failover counters tick.
    #[test]
    fn failover_resolves_via_listed_replica() {
        let owner = store(); // never connected: the owner is "dead"
        let local = store();
        let fab = DataFabric::new(local.clone());
        let f = frame(2048);
        let mut r = owner.put("k", f.clone(), 0.0).unwrap();
        // Replicate the frame into the local store under the replica key.
        local.put(&r.replica_key(), f.clone(), 0.0).unwrap();
        r.replicas = vec![local.owner()];
        let got = fab.resolve(&r, 0.0).unwrap();
        assert_eq!(got.as_slice(), f.as_slice());
        assert_eq!(fab.stats.failovers.load(Relaxed), 1);
        assert_eq!(fab.stats.misses.load(Relaxed), 0, "failover is not a miss");
        let counters = crate::metrics::Counters::new();
        fab.with_counters(counters.clone());
        fab.reclaim(&r); // drop the cached copy so failover runs again
        local.put(&r.replica_key(), f.clone(), 0.0).unwrap();
        fab.resolve(&r, 0.0).unwrap();
        assert_eq!(
            crate::metrics::Counters::get(&counters.failover_resolutions),
            1,
            "failovers land in the deployment-wide sink"
        );
    }

    /// A frame re-homed by a decommission drain lives on an endpoint
    /// the ref never listed: the replica scan over connected peers
    /// still finds it.
    #[test]
    fn failover_scans_unlisted_peers_for_rehomed_frames() {
        let owner = store(); // dead
        let rehome = store(); // where the drain moved the frame
        let fab = DataFabric::new(store());
        fab.connect_peer(rehome.owner(), rehome.clone());
        let f = frame(512);
        let r = owner.put("k", f.clone(), 0.0).unwrap(); // empty replica set
        rehome.put(&r.replica_key(), f.clone(), 0.0).unwrap();
        let got = fab.resolve(&r, 0.0).unwrap();
        assert_eq!(got.as_slice(), f.as_slice());
        assert_eq!(fab.stats.failovers.load(Relaxed), 1);
        assert_eq!(fab.stats.frames_forwarded.load(Relaxed), 1, "served peer-to-peer");
    }

    /// A spool whose reads fail a configured number of times before
    /// recovering — the flapping-link fake behind the retry pins.
    struct FlakyReadSpool {
        inner: crate::datastore::DiskBackend,
        failures_left: AtomicU64,
    }

    impl FlakyReadSpool {
        fn new(failures: u64) -> Arc<Self> {
            Arc::new(FlakyReadSpool {
                inner: crate::datastore::DiskBackend::temp().unwrap(),
                failures_left: AtomicU64::new(failures),
            })
        }
    }

    impl crate::datastore::backend::StoreBackend for FlakyReadSpool {
        fn name(&self) -> &'static str {
            "flaky-read-fake"
        }
        fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
            self.inner.put(key, frame)
        }
        fn get(&self, key: &str) -> Result<Option<Buffer>> {
            let left = self.failures_left.load(Ordering::SeqCst);
            if left > 0 {
                self.failures_left.store(left - 1, Ordering::SeqCst);
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "link flap",
                )));
            }
            self.inner.get(key)
        }
        fn remove(&self, key: &str) -> Result<bool> {
            crate::datastore::backend::StoreBackend::remove(&self.inner, key)
        }
    }

    impl crate::datastore::backend::SpoolStore for FlakyReadSpool {
        fn put_entry(
            &self,
            key: &str,
            frame: &Buffer,
            expires_at: Option<Time>,
        ) -> Result<()> {
            self.inner.put_entry(key, frame, expires_at)
        }
    }

    /// Satellite pin: a flapping peer is retried, not reported missing.
    /// Two transient read faults still resolve (with retries counted);
    /// a permanently faulted peer surfaces the typed transient error —
    /// never `NotFound` — once the bounded retries are exhausted.
    #[test]
    fn transient_peer_faults_retry_before_surfacing() {
        let mk_flaky_peer = |failures: u64| {
            let spool = FlakyReadSpool::new(0);
            let peer = Arc::new(TieredStore::with_spool_for_tests(
                EndpointId::new(),
                TieredConfig { mem_high_watermark: 0, default_ttl_s: 0.0, spool_dir: None },
                spool.clone(),
            ));
            let r = peer.put("k", frame(1024), 0.0).unwrap();
            assert!(peer.settle(std::time::Duration::from_secs(10)));
            assert_eq!(peer.tier_of("k"), Some(Tier::Disk));
            spool.failures_left.store(failures, Ordering::SeqCst);
            (peer, r)
        };

        // Flapping: fails twice, third attempt lands.
        let (peer, r) = mk_flaky_peer(2);
        let fab = DataFabric::new(store());
        fab.connect_peer(peer.owner(), peer.clone());
        let got = fab.resolve(&r, 0.0).unwrap();
        assert_eq!(got.len(), 1024);
        assert_eq!(fab.stats.peer_retries.load(Relaxed), 2);
        assert_eq!(fab.stats.misses.load(Relaxed), 0);

        // Permanently down: typed I/O error after exhausted retries.
        let (peer2, r2) = mk_flaky_peer(u64::MAX);
        let fab2 = DataFabric::new(store());
        fab2.connect_peer(peer2.owner(), peer2.clone());
        match fab2.resolve(&r2, 0.0) {
            Err(Error::Io(_)) => {}
            other => panic!("expected Io after exhausted retries, got {other:?}"),
        }
        assert_eq!(fab2.stats.peer_retries.load(Relaxed), 2);
    }

    #[test]
    fn cache_is_byte_bounded_and_evicts_lru() {
        let a = Arc::new(
            TieredStore::new(
                EndpointId::new(),
                TieredConfig {
                    mem_high_watermark: 1 << 30,
                    default_ttl_s: 0.0,
                    spool_dir: None,
                },
            )
            .unwrap(),
        );
        let fab = DataFabric::new(store());
        fab.connect_peer(a.owner(), a.clone());
        // Fill well past the byte budget with 1 MB frames, keeping the
        // first entry hot throughout.
        let mb = 1 << 20;
        let n = CACHE_MAX_BYTES / mb + 16;
        let hot = a.put("hot", frame(mb), 0.0).unwrap();
        fab.resolve(&hot, 0.0).unwrap();
        for i in 0..n {
            let r = a.put(&format!("k{i}"), frame(mb), 0.0).unwrap();
            fab.resolve(&r, 0.0).unwrap();
            fab.resolve(&hot, 0.0).unwrap(); // refresh the hot entry
        }
        assert!(
            fab.cache_bytes() <= CACHE_MAX_BYTES,
            "cache holds {} bytes over the {CACHE_MAX_BYTES} budget",
            fab.cache_bytes()
        );
        // The hot entry survived the churn; resolving it again is still
        // a cache hit, not a re-fetch.
        let forwarded = fab.stats.frames_forwarded.load(Relaxed);
        fab.resolve(&hot, 0.0).unwrap();
        assert_eq!(fab.stats.frames_forwarded.load(Relaxed), forwarded);
        assert!(fab.cache_hits_of(&hot) > 0);
        // Overwriting a cached key in place re-accounts instead of
        // evicting an innocent sibling.
        let before = fab.cache_bytes();
        let hot2 = a.put("hot", frame(mb / 2), 0.0).unwrap();
        fab.resolve(&hot2, 0.0).unwrap(); // checksum miss -> re-fetch + replace
        assert!(fab.cache_bytes() <= before, "in-place replace must not grow the cache");
    }
}
