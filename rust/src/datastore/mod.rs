//! §5 — the data fabric: a tiered payload store with pass-by-reference
//! dispatch and cross-endpoint frame fetch.
//!
//! funcX routes *references* between endpoints rather than the data
//! itself: intra-endpoint intermediate data lives in an in-memory store
//! (up to 3x faster than the shared file system, Fig. 5 / §5.2), while
//! wide-area movement goes through Globus (§5.1). This module is that
//! data layer as a real subsystem:
//!
//! * [`StoreBackend`] — the frame-holder contract, with two
//!   implementations that hold shared [`crate::serialize::Buffer`]
//!   frames: [`MemoryBackend`] (over the existing lock-striped
//!   [`crate::store::KvStore`] shards) and [`DiskBackend`] (real files
//!   under a spool directory).
//! * [`TieredStore`] — the tiered store behind a configurable memory
//!   high-watermark with background LRU spill to disk, promotion back
//!   on access, and TTL expiry, built around a per-key state machine
//!   ([`EntryState`]) so the index mutex guards metadata only and tier
//!   I/O never runs under it. Frames spill and reload as raw wire
//!   bytes — never decoded or re-encoded on the way through a tier.
//! * [`DataRef`] — the compact (owner, epoch, key, size, checksum)
//!   reference that rides in the task trailer wire format instead of
//!   inline payload bytes once an input exceeds
//!   [`crate::common::config::ServiceConfig::max_payload_bytes`].
//! * [`DataFabric`] — the per-endpoint resolver handle: local store →
//!   hit-counting cache → endpoint-to-endpoint raw-frame forward →
//!   Globus transfer model, in that order (the fetch fallback ladder;
//!   see `docs/data-fabric.md`).

mod backend;
mod dataref;
mod fabric;
mod tiered;

pub use backend::{DiskBackend, MemoryBackend, SpoolEntry, SpoolStore, StoreBackend};
pub use dataref::{checksum, DataRef, SERVICE_OWNER};
pub use fabric::{DataFabric, FabricStats, FetchPlan, PeerSource};
pub use tiered::{EntryState, Tier, TierStats, TieredConfig, TieredStore};
