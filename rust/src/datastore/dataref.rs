//! The pass-by-reference handle: what a task carries instead of inline
//! payload bytes once the input exceeds the service data cap (§5.1).
//!
//! A [`DataRef`] names a frame in some endpoint's [`super::TieredStore`]:
//! which endpoint owns it, which store generation (epoch) it was written
//! under, the key, and a size + checksum pair so the resolver can verify
//! the fetched frame bit-for-bit without decoding it.

use crate::common::error::{Error, Result};
use crate::common::ids::{EndpointId, Uuid};
use crate::serialize::{Value, Wire};

/// The owner id used by the cloud service's own payload store (tasks
/// whose oversized inputs were offloaded at submit; resolvable by any
/// endpoint fabric peered with the service store).
pub const SERVICE_OWNER: EndpointId = EndpointId(Uuid::NIL);

/// FNV-1a over a byte slice — the frame checksum carried in every
/// [`DataRef`] (cheap, dependency-free; collisions are a non-goal, the
/// check guards against truncation/corruption, not adversaries).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h = (h ^ u64::from(*b)).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A compact reference to a frame held in a data-fabric store.
#[derive(Clone, Debug, PartialEq)]
pub struct DataRef {
    /// Endpoint whose store holds the frame ([`SERVICE_OWNER`] for the
    /// cloud service's store).
    pub owner: EndpointId,
    /// Store generation the frame was written under; a restarted or
    /// recreated store has a fresh epoch, so stale refs resolve to
    /// [`Error::NotFound`] instead of wrong data.
    pub epoch: u64,
    pub key: String,
    /// Exact frame length in bytes.
    pub size: u64,
    /// [`checksum`] of the frame bytes.
    pub checksum: u64,
    /// Endpoints holding a replica of the frame (under
    /// [`DataRef::replica_key`] in *their* stores), in preference
    /// order. Empty for unreplicated refs; resolvers fail over
    /// owner → replicas → Globus, and routing treats replica endpoints
    /// as data-local. Absent on the wire when empty, so refs minted by
    /// older writers decode unchanged.
    pub replicas: Vec<EndpointId>,
}

impl DataRef {
    /// Verify a fetched frame against the size/checksum pair. Both
    /// failure shapes are [`Error::Corrupt`]: the bytes were found but
    /// cannot be trusted (truncation or bit corruption), as opposed to
    /// [`Error::NotFound`] for refs whose frame is simply gone.
    pub fn verify(&self, frame: &[u8]) -> Result<()> {
        if frame.len() as u64 != self.size {
            return Err(Error::Corrupt(format!(
                "ref {}: frame is {} bytes, expected {}",
                self.key,
                frame.len(),
                self.size
            )));
        }
        if checksum(frame) != self.checksum {
            return Err(Error::Corrupt(format!("ref {}: checksum mismatch", self.key)));
        }
        Ok(())
    }

    /// The key a *replica* of this frame is stored under in a peer
    /// store. Namespaced by owner + epoch so replicas of identically
    /// named frames from different owners (or store generations) never
    /// collide, and a stale replica can never satisfy a re-minted ref —
    /// the checksum verify backstops even that.
    pub fn replica_key(&self) -> String {
        format!("replica:{}:{}:{}", self.owner, self.epoch, self.key)
    }
}

impl Wire for DataRef {
    fn to_value(&self) -> Value {
        let mut fields = vec![
            ("owner", self.owner.to_value()),
            ("epoch", self.epoch.to_value()),
            ("key", Value::Str(self.key.clone())),
            ("size", self.size.to_value()),
            ("sum", self.checksum.to_value()),
        ];
        if !self.replicas.is_empty() {
            fields.push((
                "reps",
                Value::List(self.replicas.iter().map(Wire::to_value).collect()),
            ));
        }
        Value::map(fields)
    }

    fn from_value(v: &Value) -> Result<Self> {
        let field = |name: &str| {
            v.get(name)
                .ok_or_else(|| Error::Serialization(format!("dataref: missing {name}")))
        };
        // "reps" is optional on the wire: unreplicated refs (and refs
        // from pre-replication writers) simply omit it.
        let replicas = match v.get("reps") {
            Some(Value::List(l)) => l.iter().map(EndpointId::from_value).collect::<Result<_>>()?,
            Some(other) => {
                return Err(Error::Serialization(format!("dataref: bad reps {other:?}")))
            }
            None => Vec::new(),
        };
        Ok(DataRef {
            owner: EndpointId::from_value(field("owner")?)?,
            epoch: u64::from_value(field("epoch")?)?,
            key: String::from_value(field("key")?)?,
            size: u64::from_value(field("size")?)?,
            checksum: u64::from_value(field("sum")?)?,
            replicas,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_ref(bytes: &[u8]) -> DataRef {
        DataRef {
            owner: EndpointId::new(),
            epoch: 7,
            key: "k/part-0".into(),
            size: bytes.len() as u64,
            checksum: checksum(bytes),
            replicas: Vec::new(),
        }
    }

    #[test]
    fn wire_roundtrip() {
        let r = mk_ref(&[1, 2, 3]);
        let back = DataRef::from_bytes(&r.to_bytes()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn wire_roundtrip_with_replicas() {
        let mut r = mk_ref(&[1, 2, 3]);
        r.replicas = vec![EndpointId::new(), EndpointId::new()];
        let back = DataRef::from_bytes(&r.to_bytes()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn wire_without_reps_decodes_empty_replica_set() {
        // A ref encoded before replication existed has no "reps" field;
        // it must still decode (empty replica set), not error.
        let r = mk_ref(&[4, 5]);
        let v = crate::serialize::Value::map([
            ("owner", r.owner.to_value()),
            ("epoch", r.epoch.to_value()),
            ("key", crate::serialize::Value::Str(r.key.clone())),
            ("size", r.size.to_value()),
            ("sum", r.checksum.to_value()),
        ]);
        let back = DataRef::from_value(&v).unwrap();
        assert_eq!(back, r);
        assert!(back.replicas.is_empty());
    }

    #[test]
    fn verify_accepts_exact_frame() {
        let data = vec![9u8; 4096];
        assert!(mk_ref(&data).verify(&data).is_ok());
    }

    #[test]
    fn verify_rejects_truncation_and_corruption() {
        let data = vec![9u8; 4096];
        let r = mk_ref(&data);
        assert!(matches!(r.verify(&data[..4095]), Err(Error::Corrupt(_))));
        let mut flipped = data.clone();
        flipped[100] ^= 0xFF;
        assert!(matches!(r.verify(&flipped), Err(Error::Corrupt(_))));
    }

    #[test]
    fn checksum_distinguishes_content() {
        assert_ne!(checksum(b"abc"), checksum(b"abd"));
        assert_eq!(checksum(b""), 0xcbf2_9ce4_8422_2325);
    }
}
