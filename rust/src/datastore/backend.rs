//! Frame-holder backends behind the tiered store.
//!
//! A backend stores packed frames (shared [`Buffer`] handles) under
//! string keys and hands them back verbatim — no backend ever decodes or
//! re-encodes a frame. The memory tier keeps refcounted handles in the
//! existing lock-striped [`KvStore`] shards (put/get are O(1) in payload
//! size); the disk tier writes the raw wire bytes to real files under a
//! spool directory and reloads them with a single read.

use std::path::{Path, PathBuf};

use crate::common::error::Result;
use crate::serialize::Buffer;
use crate::store::KvStore;

/// One storage tier: holds frames by key, byte-for-byte.
pub trait StoreBackend: Send + Sync {
    fn name(&self) -> &'static str;
    /// Store a frame under `key` (overwrites).
    fn put(&self, key: &str, frame: &Buffer) -> Result<()>;
    /// Fetch the frame under `key`, or `None` when absent.
    fn get(&self, key: &str) -> Result<Option<Buffer>>;
    /// Drop the frame under `key`; returns whether it existed.
    fn remove(&self, key: &str) -> Result<bool>;
}

/// In-memory tier over the sharded [`KvStore`]: the store keeps another
/// handle on the frame's allocation, so `put` + `get` round-trips the
/// *same* allocation (pointer-pinned in `tests/data_fabric.rs`).
#[derive(Clone, Default)]
pub struct MemoryBackend {
    kv: KvStore,
}

impl MemoryBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StoreBackend for MemoryBackend {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
        self.kv.set(key, frame.clone());
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Buffer>> {
        Ok(self.kv.get(key))
    }

    fn remove(&self, key: &str) -> Result<bool> {
        Ok(self.kv.del(key))
    }
}

/// Disk tier: one file per key under a spool directory (the Lustre/GPFS
/// stand-in, but holding *wire frames*, not decoded values). Spill is
/// `fs::write` of the frame bytes; reload is `fs::read` wrapped into a
/// fresh shared allocation — zero decode/re-encode either way.
pub struct DiskBackend {
    root: PathBuf,
    /// Temp-dir spools are removed on drop; explicit spool dirs are not.
    owned: bool,
}

impl DiskBackend {
    /// Spool under an explicit directory (created if missing; kept on
    /// drop).
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        Ok(DiskBackend { root, owned: false })
    }

    /// Spool under a unique temp directory (removed on drop).
    pub fn temp() -> Result<Self> {
        let root = std::env::temp_dir().join(format!("funcx-datastore-{}", crate::Uuid::new()));
        std::fs::create_dir_all(&root)?;
        Ok(DiskBackend { root, owned: true })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Sanitized, collision-proofed file name: keys may contain
    /// separators from namespacing, and two keys must never map to the
    /// same file, so the key's own hash is appended.
    fn path_for(&self, key: &str) -> PathBuf {
        let safe: String = key
            .chars()
            .take(64)
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.root
            .join(format!("{safe}.{:016x}", super::dataref::checksum(key.as_bytes())))
    }
}

impl StoreBackend for DiskBackend {
    fn name(&self) -> &'static str {
        "disk"
    }

    fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
        Ok(std::fs::write(self.path_for(key), frame.as_slice())?)
    }

    fn get(&self, key: &str) -> Result<Option<Buffer>> {
        match std::fs::read(self.path_for(key)) {
            Ok(v) => Ok(Some(Buffer::from_vec(v))),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn remove(&self, key: &str) -> Result<bool> {
        match std::fs::remove_file(self.path_for(key)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }
}

impl Drop for DiskBackend {
    fn drop(&mut self) {
        if self.owned {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(b: &dyn StoreBackend) {
        let frame = Buffer::from_vec(vec![0xAB; 512]);
        assert!(b.get("k").unwrap().is_none());
        b.put("k", &frame).unwrap();
        assert_eq!(b.get("k").unwrap().unwrap().as_slice(), frame.as_slice());
        b.put("k", &Buffer::from_vec(vec![1])).unwrap();
        assert_eq!(b.get("k").unwrap().unwrap().as_slice(), [1]);
        assert!(b.remove("k").unwrap());
        assert!(!b.remove("k").unwrap());
        assert!(b.get("k").unwrap().is_none());
    }

    #[test]
    fn memory_contract() {
        exercise(&MemoryBackend::new());
    }

    #[test]
    fn disk_contract() {
        exercise(&DiskBackend::temp().unwrap());
    }

    #[test]
    fn memory_get_shares_allocation() {
        let b = MemoryBackend::new();
        let frame = Buffer::from_vec(vec![7; 4096]);
        b.put("k", &frame).unwrap();
        assert!(b.get("k").unwrap().unwrap().same_allocation(&frame));
    }

    #[test]
    fn disk_keys_do_not_collide_after_sanitizing() {
        let b = DiskBackend::temp().unwrap();
        // Both sanitize to "a_b" — the appended key hash keeps them apart.
        b.put("a/b", &Buffer::from_vec(vec![1])).unwrap();
        b.put("a:b", &Buffer::from_vec(vec![2])).unwrap();
        assert_eq!(b.get("a/b").unwrap().unwrap().as_slice(), [1]);
        assert_eq!(b.get("a:b").unwrap().unwrap().as_slice(), [2]);
    }

    #[test]
    fn temp_spool_removed_on_drop() {
        let root;
        {
            let b = DiskBackend::temp().unwrap();
            root = b.root().to_path_buf();
            b.put("k", &Buffer::from_vec(vec![1])).unwrap();
            assert!(root.exists());
        }
        assert!(!root.exists());
    }
}
