//! Frame-holder backends behind the tiered store.
//!
//! A backend stores packed frames (shared [`Buffer`] handles) under
//! string keys and hands them back verbatim — no backend ever decodes or
//! re-encodes a frame. The memory tier keeps refcounted handles in the
//! existing lock-striped [`KvStore`] shards (put/get are O(1) in payload
//! size); the disk tier writes the raw wire bytes to real files under a
//! spool directory and reloads them with a single read.
//!
//! # Spool manifest & crash recovery
//!
//! The disk tier keeps an epoch-stamped manifest (`spool.manifest`)
//! alongside its frame files: one line per spilled key recording the
//! frame's size, checksum, and expiry stamp. Frame files are written
//! *before* the manifest updates, and the manifest is replaced via
//! write-to-temp + rename, so at any crash point the invariant holds:
//! every manifest entry names a fully-written file, and a file without a
//! manifest entry is an interrupted spill. [`DiskBackend::recover`]
//! readopts the former (after re-verifying size + checksum) and reclaims
//! the latter, closing the "crashed endpoint leaks spool files" gap;
//! [`DiskBackend::new`] reclaims everything, for callers that want a
//! clean store over a dirty directory.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::common::error::Result;
use crate::common::time::Time;
use crate::serialize::Buffer;
use crate::store::KvStore;

/// One storage tier: holds frames by key, byte-for-byte.
pub trait StoreBackend: Send + Sync {
    fn name(&self) -> &'static str;
    /// Store a frame under `key` (overwrites).
    fn put(&self, key: &str, frame: &Buffer) -> Result<()>;
    /// Fetch the frame under `key`, or `None` when absent.
    fn get(&self, key: &str) -> Result<Option<Buffer>>;
    /// Drop the frame under `key`; returns whether it existed.
    fn remove(&self, key: &str) -> Result<bool>;
}

/// In-memory tier over the sharded [`KvStore`]: the store keeps another
/// handle on the frame's allocation, so `put` + `get` round-trips the
/// *same* allocation (pointer-pinned in `tests/data_fabric.rs`).
#[derive(Clone, Default)]
pub struct MemoryBackend {
    kv: KvStore,
}

impl MemoryBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StoreBackend for MemoryBackend {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
        self.kv.set(key, frame.clone());
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Buffer>> {
        Ok(self.kv.get(key))
    }

    fn remove(&self, key: &str) -> Result<bool> {
        Ok(self.kv.del(key))
    }
}

/// What the spool manifest records for one spilled key (everything
/// [`DiskBackend::recover`] needs to readopt the frame into a restarted
/// store's index without decoding it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpoolEntry {
    /// Exact frame length in bytes.
    pub size: u64,
    /// [`super::dataref::checksum`] of the frame bytes.
    pub checksum: u64,
    /// Owner-stamped expiry (absent = no TTL).
    pub expires_at: Option<Time>,
}

struct Manifest {
    /// The owning store's generation, so readopted frames keep
    /// resolving refs minted before the crash.
    epoch: u64,
    entries: HashMap<String, SpoolEntry>,
}

const MANIFEST_FILE: &str = "spool.manifest";

/// Disk tier: one file per key under a spool directory (the Lustre/GPFS
/// stand-in, but holding *wire frames*, not decoded values). Spill is
/// `fs::write` of the frame bytes; reload is `fs::read` wrapped into a
/// fresh shared allocation — zero decode/re-encode either way. Every
/// mutation also updates the epoch-stamped manifest (module docs).
pub struct DiskBackend {
    root: PathBuf,
    /// Temp-dir spools are removed on drop; explicit spool dirs are not.
    owned: bool,
    manifest: Mutex<Manifest>,
}

impl DiskBackend {
    /// Spool under an explicit directory (created if missing; kept on
    /// drop). Starts **clean**: leftover frame files and manifest from a
    /// previous store generation are reclaimed — use
    /// [`DiskBackend::recover`] to readopt them instead.
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let b = DiskBackend {
            root,
            owned: false,
            manifest: Mutex::new(Manifest { epoch: 0, entries: HashMap::new() }),
        };
        b.reclaim_unlisted()?;
        b.write_manifest()?;
        Ok(b)
    }

    /// Spool under a unique temp directory (removed on drop).
    pub fn temp() -> Result<Self> {
        let root = std::env::temp_dir().join(format!("funcx-datastore-{}", crate::Uuid::new()));
        std::fs::create_dir_all(&root)?;
        let b = DiskBackend {
            root,
            owned: true,
            manifest: Mutex::new(Manifest { epoch: 0, entries: HashMap::new() }),
        };
        b.write_manifest()?;
        Ok(b)
    }

    /// Reopen a spool directory after a crash: every manifest entry
    /// whose file re-verifies (size + checksum) is readopted and
    /// returned; entries whose file is missing or damaged are dropped,
    /// and frame files with no manifest entry (interrupted spills) are
    /// reclaimed. The manifest's epoch survives, so refs minted before
    /// the crash keep resolving against the recovered store.
    pub fn recover(root: impl Into<PathBuf>) -> Result<(Self, Vec<(String, SpoolEntry)>)> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let loaded = load_manifest(&root.join(MANIFEST_FILE));
        let mut adopted = Vec::new();
        let mut manifest = Manifest { epoch: loaded.epoch, entries: HashMap::new() };
        for (key, entry) in loaded.entries {
            let path = path_for(&root, &key);
            let ok = match std::fs::read(&path) {
                Ok(bytes) => {
                    bytes.len() as u64 == entry.size
                        && super::dataref::checksum(&bytes) == entry.checksum
                }
                Err(_) => false,
            };
            if ok {
                manifest.entries.insert(key.clone(), entry);
                adopted.push((key, entry));
            } else {
                let _ = std::fs::remove_file(&path);
            }
        }
        let b = DiskBackend { root, owned: false, manifest: Mutex::new(manifest) };
        b.reclaim_unlisted()?;
        b.write_manifest()?;
        Ok((b, adopted))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The manifest's store generation (0 = never stamped).
    pub fn epoch(&self) -> u64 {
        self.manifest.lock().expect("spool manifest poisoned").epoch
    }

    /// Stamp the owning store's generation into the manifest.
    pub fn set_epoch(&self, epoch: u64) -> Result<()> {
        self.manifest.lock().expect("spool manifest poisoned").epoch = epoch;
        self.write_manifest()
    }

    /// Store a frame with its manifest record (the tiered store's spill
    /// path; the trait `put` records no expiry). File first, manifest
    /// second — see the module docs' crash invariant.
    pub fn put_entry(&self, key: &str, frame: &Buffer, expires_at: Option<Time>) -> Result<()> {
        std::fs::write(path_for(&self.root, key), frame.as_slice())?;
        self.manifest.lock().expect("spool manifest poisoned").entries.insert(
            key.to_string(),
            SpoolEntry {
                size: frame.len() as u64,
                checksum: super::dataref::checksum(frame.as_slice()),
                expires_at,
            },
        );
        self.write_manifest()
    }

    /// Delete every frame file the manifest does not list (stale
    /// generations, interrupted spills). The manifest itself and
    /// non-spool files are left alone.
    fn reclaim_unlisted(&self) -> Result<()> {
        let g = self.manifest.lock().expect("spool manifest poisoned");
        let listed: std::collections::HashSet<PathBuf> =
            g.entries.keys().map(|k| path_for(&self.root, k)).collect();
        drop(g);
        for entry in std::fs::read_dir(&self.root)? {
            let path = entry?.path();
            if is_frame_file(&path) && !listed.contains(&path) {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(())
    }

    /// Serialize the manifest via write-to-temp + rename, so a crash
    /// mid-write leaves the previous manifest intact. The snapshot is
    /// written and renamed *while holding the manifest lock*: dropping
    /// it earlier would let two concurrent mutators race their renames
    /// and persist the older snapshot (losing a fully-spilled frame to
    /// the next recovery's orphan reclaim).
    fn write_manifest(&self) -> Result<()> {
        let g = self.manifest.lock().expect("spool manifest poisoned");
        let mut out = format!("v1 {}\n", g.epoch);
        for (key, e) in &g.entries {
            let exp = match e.expires_at {
                Some(t) => format!("{t}"),
                None => "-".into(),
            };
            out.push_str(&format!("{} {} {} {}\n", hex(key.as_bytes()), e.size, e.checksum, exp));
        }
        let tmp = self.root.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, out)?;
        std::fs::rename(&tmp, self.root.join(MANIFEST_FILE))?;
        drop(g);
        Ok(())
    }
}

/// Sanitized, collision-proofed file name: keys may contain separators
/// from namespacing, and two keys must never map to the same file, so
/// the key's own hash is appended.
fn path_for(root: &Path, key: &str) -> PathBuf {
    let safe: String = key
        .chars()
        .take(64)
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    root.join(format!("{safe}.{:016x}", super::dataref::checksum(key.as_bytes())))
}

/// Spool frame files end in a 16-hex-digit key hash; the manifest and
/// its temp file do not, so reclaim passes never touch them.
fn is_frame_file(path: &Path) -> bool {
    path.file_name()
        .and_then(|n| n.to_str())
        .and_then(|n| n.rsplit_once('.'))
        .is_some_and(|(_, suffix)| {
            suffix.len() == 16 && suffix.bytes().all(|b| b.is_ascii_hexdigit())
        })
}

fn hex(bytes: &[u8]) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

fn unhex(s: &str) -> Option<String> {
    if s.len() % 2 != 0 {
        return None;
    }
    let bytes: Option<Vec<u8>> = (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).ok())
        .collect();
    String::from_utf8(bytes?).ok()
}

/// Parse a manifest file; unreadable or malformed content degrades to an
/// empty manifest (recovery then reclaims everything — safe, not wrong).
fn load_manifest(path: &Path) -> Manifest {
    let mut m = Manifest { epoch: 0, entries: HashMap::new() };
    let Ok(text) = std::fs::read_to_string(path) else {
        return m;
    };
    let mut lines = text.lines();
    match lines.next().and_then(|h| h.strip_prefix("v1 ")).and_then(|e| e.parse::<u64>().ok()) {
        Some(epoch) => m.epoch = epoch,
        None => return m,
    }
    for line in lines {
        let mut parts = line.split_ascii_whitespace();
        let (Some(hkey), Some(size), Some(sum), Some(exp)) =
            (parts.next(), parts.next(), parts.next(), parts.next())
        else {
            continue;
        };
        let (Some(key), Ok(size), Ok(checksum)) =
            (unhex(hkey), size.parse::<u64>(), sum.parse::<u64>())
        else {
            continue;
        };
        let expires_at = if exp == "-" { None } else { exp.parse::<Time>().ok() };
        if exp != "-" && expires_at.is_none() {
            continue;
        }
        m.entries.insert(key, SpoolEntry { size, checksum, expires_at });
    }
    m
}

impl StoreBackend for DiskBackend {
    fn name(&self) -> &'static str {
        "disk"
    }

    fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
        self.put_entry(key, frame, None)
    }

    fn get(&self, key: &str) -> Result<Option<Buffer>> {
        match std::fs::read(path_for(&self.root, key)) {
            Ok(v) => Ok(Some(Buffer::from_vec(v))),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn remove(&self, key: &str) -> Result<bool> {
        let existed = match std::fs::remove_file(path_for(&self.root, key)) {
            Ok(()) => true,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => false,
            Err(e) => return Err(e.into()),
        };
        let listed = self
            .manifest
            .lock()
            .expect("spool manifest poisoned")
            .entries
            .remove(key)
            .is_some();
        if listed {
            self.write_manifest()?;
        }
        Ok(existed)
    }
}

impl Drop for DiskBackend {
    fn drop(&mut self) {
        if self.owned {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(b: &dyn StoreBackend) {
        let frame = Buffer::from_vec(vec![0xAB; 512]);
        assert!(b.get("k").unwrap().is_none());
        b.put("k", &frame).unwrap();
        assert_eq!(b.get("k").unwrap().unwrap().as_slice(), frame.as_slice());
        b.put("k", &Buffer::from_vec(vec![1])).unwrap();
        assert_eq!(b.get("k").unwrap().unwrap().as_slice(), [1]);
        assert!(b.remove("k").unwrap());
        assert!(!b.remove("k").unwrap());
        assert!(b.get("k").unwrap().is_none());
    }

    #[test]
    fn memory_contract() {
        exercise(&MemoryBackend::new());
    }

    #[test]
    fn disk_contract() {
        exercise(&DiskBackend::temp().unwrap());
    }

    #[test]
    fn memory_get_shares_allocation() {
        let b = MemoryBackend::new();
        let frame = Buffer::from_vec(vec![7; 4096]);
        b.put("k", &frame).unwrap();
        assert!(b.get("k").unwrap().unwrap().same_allocation(&frame));
    }

    #[test]
    fn disk_keys_do_not_collide_after_sanitizing() {
        let b = DiskBackend::temp().unwrap();
        // Both sanitize to "a_b" — the appended key hash keeps them apart.
        b.put("a/b", &Buffer::from_vec(vec![1])).unwrap();
        b.put("a:b", &Buffer::from_vec(vec![2])).unwrap();
        assert_eq!(b.get("a/b").unwrap().unwrap().as_slice(), [1]);
        assert_eq!(b.get("a:b").unwrap().unwrap().as_slice(), [2]);
    }

    #[test]
    fn temp_spool_removed_on_drop() {
        let root;
        {
            let b = DiskBackend::temp().unwrap();
            root = b.root().to_path_buf();
            b.put("k", &Buffer::from_vec(vec![1])).unwrap();
            assert!(root.exists());
        }
        assert!(!root.exists());
    }

    fn crash_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("funcx-spool-{tag}-{}", crate::Uuid::new()))
    }

    #[test]
    fn recover_readopts_listed_and_reclaims_orphans() {
        let dir = crash_dir("recover");
        let frame = Buffer::from_vec(vec![0x5C; 2048]);
        {
            let b = DiskBackend::new(&dir).unwrap();
            b.set_epoch(42).unwrap();
            b.put_entry("task-result:a", &frame, Some(99.5)).unwrap();
            b.put_entry("task-result:b", &Buffer::from_vec(vec![2; 64]), None).unwrap();
            // Crash: the backend never runs cleanup.
            std::mem::forget(b);
        }
        // Interrupted spill: a frame file with no manifest entry.
        std::fs::write(dir.join("orphan.00112233aabbccdd"), [9u8; 100]).unwrap();
        // Damaged file for a listed key: truncate it.
        std::fs::write(path_for(&dir, "task-result:b"), [2u8; 10]).unwrap();

        let (b, adopted) = DiskBackend::recover(&dir).unwrap();
        assert_eq!(b.epoch(), 42, "recovery keeps the stamped epoch");
        assert_eq!(adopted.len(), 1, "only the verifying entry readopts");
        assert_eq!(adopted[0].0, "task-result:a");
        assert_eq!(adopted[0].1.size, 2048);
        assert_eq!(adopted[0].1.expires_at, Some(99.5));
        assert_eq!(
            b.get("task-result:a").unwrap().unwrap().as_slice(),
            frame.as_slice(),
            "readopted frame is byte-identical"
        );
        assert!(b.get("task-result:b").unwrap().is_none(), "damaged entry reclaimed");
        // No leaked files: exactly one frame file + the manifest remain.
        let frames = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| is_frame_file(&e.as_ref().unwrap().path()))
            .count();
        assert_eq!(frames, 1, "orphan and damaged files must be reclaimed");
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn new_reclaims_stale_spool_files() {
        let dir = crash_dir("clean");
        {
            let b = DiskBackend::new(&dir).unwrap();
            b.put("k", &Buffer::from_vec(vec![1; 256])).unwrap();
            std::mem::forget(b); // crash
        }
        let b = DiskBackend::new(&dir).unwrap();
        assert!(b.get("k").unwrap().is_none(), "fresh store starts clean");
        let frames = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| is_frame_file(&e.as_ref().unwrap().path()))
            .count();
        assert_eq!(frames, 0);
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_roundtrips_entries() {
        let dir = crash_dir("manifest");
        let b = DiskBackend::new(&dir).unwrap();
        b.set_epoch(7).unwrap();
        b.put_entry("spaced key/with:sep", &Buffer::from_vec(vec![3; 128]), Some(12.25)).unwrap();
        let m = load_manifest(&dir.join(MANIFEST_FILE));
        assert_eq!(m.epoch, 7);
        let e = m.entries.get("spaced key/with:sep").expect("key survives hex framing");
        assert_eq!(e.size, 128);
        assert_eq!(e.expires_at, Some(12.25));
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
