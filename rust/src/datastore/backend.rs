//! Frame-holder backends behind the tiered store.
//!
//! A backend stores packed frames (shared [`Buffer`] handles) under
//! string keys and hands them back verbatim — no backend ever decodes or
//! re-encodes a frame. [`MemoryBackend`] keeps refcounted handles in the
//! lock-striped [`KvStore`] shards (put/get are O(1) in payload size);
//! [`DiskBackend`] writes the raw wire bytes to real files under a spool
//! directory and reloads them with a single read.
//!
//! [`SpoolStore`] is the disk-tier contract the tiered store drives its
//! spills through — [`DiskBackend`] is the real implementation; tests
//! substitute blocking fakes to pin that spool I/O never runs under the
//! tiered index lock.
//!
//! # Spool manifest: an append-only log
//!
//! The disk tier keeps an epoch-stamped manifest (`spool.manifest`)
//! alongside its frame files. The manifest is a *log*, not a snapshot:
//! a header line `v2 <epoch>` followed by one record per mutation —
//! `+ <hexkey> <size> <checksum> <expiry>` for a spill, `- <hexkey>` for
//! a reclaim — so each spill costs one O(1) append instead of a rewrite
//! of every live entry. When the log grows past a small multiple of the
//! live-entry count it is compacted: the live set is re-written as a
//! fresh log via write-to-temp + rename, so a crash at any point during
//! compaction leaves the previous (complete) log in place.
//!
//! # Crash invariant
//!
//! Frame files are written *before* their manifest append, so at any
//! crash point: every fully-appended `+` record names a fully-written
//! file, a file without a record is an interrupted spill, and a torn
//! final record (crash mid-append) is skipped by the replay without
//! affecting earlier records. [`DiskBackend::recover`] replays the log,
//! readopts every surviving entry whose file re-verifies (size +
//! checksum), and reclaims orphans; [`DiskBackend::new`] reclaims
//! everything, for callers that want a clean store over a dirty
//! directory.

use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::common::error::Result;
use crate::common::time::Time;
use crate::serialize::Buffer;
use crate::store::KvStore;

/// One storage tier: holds frames by key, byte-for-byte.
pub trait StoreBackend: Send + Sync {
    fn name(&self) -> &'static str;
    /// Store a frame under `key` (overwrites).
    fn put(&self, key: &str, frame: &Buffer) -> Result<()>;
    /// Fetch the frame under `key`, or `None` when absent.
    fn get(&self, key: &str) -> Result<Option<Buffer>>;
    /// Drop the frame under `key`; returns whether it existed.
    fn remove(&self, key: &str) -> Result<bool>;
}

/// The spool contract the tiered store's spill/promote/reclaim paths
/// drive: a [`StoreBackend`] whose writes also carry the manifest record
/// (expiry stamp) crash recovery needs. [`DiskBackend`] is the real
/// implementation; tests inject blocking fakes through
/// `TieredStore::with_spool_for_tests` to pin the locking discipline.
pub trait SpoolStore: StoreBackend {
    /// Store a frame together with its manifest record (file first,
    /// manifest second — the crash invariant in the module docs).
    fn put_entry(&self, key: &str, frame: &Buffer, expires_at: Option<Time>) -> Result<()>;
}

/// In-memory tier over the sharded [`KvStore`]: the store keeps another
/// handle on the frame's allocation, so `put` + `get` round-trips the
/// *same* allocation (pointer-pinned in `tests/data_fabric.rs`).
#[derive(Clone, Default)]
pub struct MemoryBackend {
    kv: KvStore,
}

impl MemoryBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StoreBackend for MemoryBackend {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
        self.kv.set(key, frame.clone());
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Buffer>> {
        Ok(self.kv.get(key))
    }

    fn remove(&self, key: &str) -> Result<bool> {
        Ok(self.kv.del(key))
    }
}

/// What the spool manifest records for one spilled key (everything
/// [`DiskBackend::recover`] needs to readopt the frame into a restarted
/// store's index without decoding it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpoolEntry {
    /// Exact frame length in bytes.
    pub size: u64,
    /// [`super::dataref::checksum`] of the frame bytes.
    pub checksum: u64,
    /// Owner-stamped expiry (absent = no TTL).
    pub expires_at: Option<Time>,
}

struct Manifest {
    /// The owning store's generation, so readopted frames keep
    /// resolving refs minted before the crash.
    epoch: u64,
    entries: HashMap<String, SpoolEntry>,
    /// Log records (`+`/`-` lines) written since the last compaction;
    /// compared against the live-entry count to trigger the next one.
    records: u64,
}

const MANIFEST_FILE: &str = "spool.manifest";

/// Compact when the log holds more than `COMPACT_FACTOR`x the live
/// entries (plus a floor so tiny spools never compact): bounds replay
/// cost at O(live) amortized while each spill stays an O(1) append.
const COMPACT_FACTOR: u64 = 4;
const COMPACT_FLOOR: u64 = 64;

/// Disk tier: one file per key under a spool directory (the Lustre/GPFS
/// stand-in, but holding *wire frames*, not decoded values). Spill is
/// `fs::write` of the frame bytes; reload is `fs::read` wrapped into a
/// fresh shared allocation — zero decode/re-encode either way. Every
/// mutation appends one record to the epoch-stamped manifest log
/// (module docs).
pub struct DiskBackend {
    root: PathBuf,
    /// Temp-dir spools are removed on drop; explicit spool dirs are not.
    owned: bool,
    manifest: Mutex<Manifest>,
}

impl DiskBackend {
    /// Spool under an explicit directory (created if missing; kept on
    /// drop). Starts **clean**: leftover frame files and manifest from a
    /// previous store generation are reclaimed — use
    /// [`DiskBackend::recover`] to readopt them instead.
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let b = DiskBackend {
            root,
            owned: false,
            manifest: Mutex::new(Manifest { epoch: 0, entries: HashMap::new(), records: 0 }),
        };
        b.reclaim_unlisted()?;
        b.write_snapshot(&mut b.manifest.lock().expect("spool manifest poisoned"))?;
        Ok(b)
    }

    /// Spool under a unique temp directory (removed on drop).
    pub fn temp() -> Result<Self> {
        let root = std::env::temp_dir().join(format!("funcx-datastore-{}", crate::Uuid::new()));
        std::fs::create_dir_all(&root)?;
        let b = DiskBackend {
            root,
            owned: true,
            manifest: Mutex::new(Manifest { epoch: 0, entries: HashMap::new(), records: 0 }),
        };
        b.write_snapshot(&mut b.manifest.lock().expect("spool manifest poisoned"))?;
        Ok(b)
    }

    /// Reopen a spool directory after a crash: the manifest log is
    /// replayed (a torn final record — crash mid-append — is skipped);
    /// every surviving entry whose file re-verifies (size + checksum) is
    /// readopted and returned; entries whose file is missing or damaged
    /// are dropped, and frame files with no live record (interrupted
    /// spills) are reclaimed. The log's epoch survives, so refs minted
    /// before the crash keep resolving against the recovered store.
    pub fn recover(root: impl Into<PathBuf>) -> Result<(Self, Vec<(String, SpoolEntry)>)> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let loaded = load_manifest(&root.join(MANIFEST_FILE));
        let mut adopted = Vec::new();
        let mut manifest =
            Manifest { epoch: loaded.epoch, entries: HashMap::new(), records: 0 };
        for (key, entry) in loaded.entries {
            let path = path_for(&root, &key);
            let ok = match std::fs::read(&path) {
                Ok(bytes) => {
                    bytes.len() as u64 == entry.size
                        && super::dataref::checksum(&bytes) == entry.checksum
                }
                Err(_) => false,
            };
            if ok {
                manifest.entries.insert(key.clone(), entry);
                adopted.push((key, entry));
            } else {
                let _ = std::fs::remove_file(&path);
            }
        }
        let b = DiskBackend { root, owned: false, manifest: Mutex::new(manifest) };
        b.reclaim_unlisted()?;
        // Recovery compacts by construction: the replayed live set is
        // re-written as a fresh log (any half-finished compaction temp
        // from the crash is simply overwritten by this one).
        b.write_snapshot(&mut b.manifest.lock().expect("spool manifest poisoned"))?;
        Ok((b, adopted))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The manifest's store generation (0 = never stamped).
    pub fn epoch(&self) -> u64 {
        self.manifest.lock().expect("spool manifest poisoned").epoch
    }

    /// Stamp the owning store's generation into the manifest (rewrites
    /// the log header via a compaction — rare: once per store lifetime).
    pub fn set_epoch(&self, epoch: u64) -> Result<()> {
        let mut g = self.manifest.lock().expect("spool manifest poisoned");
        g.epoch = epoch;
        self.write_snapshot(&mut g)
    }

    /// Log records written since the last compaction (telemetry/tests:
    /// pins the amortized-O(1) append discipline).
    pub fn manifest_records(&self) -> u64 {
        self.manifest.lock().expect("spool manifest poisoned").records
    }

    /// Delete every frame file the manifest does not list (stale
    /// generations, interrupted spills). The manifest itself and
    /// non-spool files are left alone.
    fn reclaim_unlisted(&self) -> Result<()> {
        let g = self.manifest.lock().expect("spool manifest poisoned");
        let listed: std::collections::HashSet<PathBuf> =
            g.entries.keys().map(|k| path_for(&self.root, k)).collect();
        drop(g);
        for entry in std::fs::read_dir(&self.root)? {
            let path = entry?.path();
            if is_frame_file(&path) && !listed.contains(&path) {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(())
    }

    /// Append one record to the manifest log, compacting first when the
    /// log has outgrown the live set. Called with the manifest lock held
    /// (the guard *is* the proof), so records hit the file in the same
    /// order the map mutates.
    fn append_record(&self, g: &mut std::sync::MutexGuard<'_, Manifest>, line: &str) -> Result<()> {
        if g.records >= COMPACT_FACTOR * g.entries.len() as u64 + COMPACT_FLOOR {
            return self.write_snapshot(g);
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.root.join(MANIFEST_FILE))?;
        f.write_all(line.as_bytes())?;
        g.records += 1;
        Ok(())
    }

    /// Compaction: serialize the live set as a fresh log via
    /// write-to-temp + rename, so a crash mid-compaction leaves the
    /// previous complete log intact. Runs under the manifest lock:
    /// dropping it earlier would let two concurrent compactions race
    /// their renames and persist the older snapshot (losing a
    /// fully-spilled frame to the next recovery's orphan reclaim).
    fn write_snapshot(&self, g: &mut std::sync::MutexGuard<'_, Manifest>) -> Result<()> {
        let mut out = format!("v2 {}\n", g.epoch);
        for (key, e) in g.entries.iter() {
            out.push_str(&put_line(key, e));
        }
        let tmp = self.root.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, out)?;
        std::fs::rename(&tmp, self.root.join(MANIFEST_FILE))?;
        g.records = g.entries.len() as u64;
        Ok(())
    }
}

fn put_line(key: &str, e: &SpoolEntry) -> String {
    let exp = match e.expires_at {
        Some(t) => format!("{t}"),
        None => "-".into(),
    };
    format!("+ {} {} {} {}\n", hex(key.as_bytes()), e.size, e.checksum, exp)
}

/// Sanitized, collision-proofed file name: keys may contain separators
/// from namespacing, and two keys must never map to the same file, so
/// the key's own hash is appended.
fn path_for(root: &Path, key: &str) -> PathBuf {
    let safe: String = key
        .chars()
        .take(64)
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    root.join(format!("{safe}.{:016x}", super::dataref::checksum(key.as_bytes())))
}

/// Spool frame files end in a 16-hex-digit key hash; the manifest and
/// its temp file do not, so reclaim passes never touch them.
fn is_frame_file(path: &Path) -> bool {
    path.file_name()
        .and_then(|n| n.to_str())
        .and_then(|n| n.rsplit_once('.'))
        .is_some_and(|(_, suffix)| {
            suffix.len() == 16 && suffix.bytes().all(|b| b.is_ascii_hexdigit())
        })
}

fn hex(bytes: &[u8]) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

fn unhex(s: &str) -> Option<String> {
    if s.len() % 2 != 0 {
        return None;
    }
    let bytes: Option<Vec<u8>> = (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).ok())
        .collect();
    String::from_utf8(bytes?).ok()
}

/// Replay a manifest log. Unreadable content or a bad header degrades to
/// an empty manifest (recovery then reclaims everything — safe, not
/// wrong); a malformed record — e.g. the torn final line of a crash
/// mid-append — is skipped without poisoning earlier records.
fn load_manifest(path: &Path) -> Manifest {
    let mut m = Manifest { epoch: 0, entries: HashMap::new(), records: 0 };
    let Ok(text) = std::fs::read_to_string(path) else {
        return m;
    };
    let mut lines = text.lines();
    match lines.next().and_then(|h| h.strip_prefix("v2 ")).and_then(|e| e.parse::<u64>().ok()) {
        Some(epoch) => m.epoch = epoch,
        None => return m,
    }
    for line in lines {
        let mut parts = line.split_ascii_whitespace();
        match parts.next() {
            Some("+") => {
                let (Some(hkey), Some(size), Some(sum), Some(exp)) =
                    (parts.next(), parts.next(), parts.next(), parts.next())
                else {
                    continue;
                };
                let (Some(key), Ok(size), Ok(checksum)) =
                    (unhex(hkey), size.parse::<u64>(), sum.parse::<u64>())
                else {
                    continue;
                };
                let expires_at = if exp == "-" { None } else { exp.parse::<Time>().ok() };
                if exp != "-" && expires_at.is_none() {
                    continue;
                }
                m.entries.insert(key, SpoolEntry { size, checksum, expires_at });
            }
            Some("-") => {
                if let Some(key) = parts.next().and_then(unhex) {
                    m.entries.remove(&key);
                }
            }
            _ => continue,
        }
    }
    m
}

impl StoreBackend for DiskBackend {
    fn name(&self) -> &'static str {
        "disk"
    }

    fn put(&self, key: &str, frame: &Buffer) -> Result<()> {
        self.put_entry(key, frame, None)
    }

    fn get(&self, key: &str) -> Result<Option<Buffer>> {
        match std::fs::read(path_for(&self.root, key)) {
            Ok(v) => Ok(Some(Buffer::from_vec(v))),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn remove(&self, key: &str) -> Result<bool> {
        let existed = match std::fs::remove_file(path_for(&self.root, key)) {
            Ok(()) => true,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => false,
            Err(e) => return Err(e.into()),
        };
        let mut g = self.manifest.lock().expect("spool manifest poisoned");
        if g.entries.remove(key).is_some() {
            let line = format!("- {}\n", hex(key.as_bytes()));
            self.append_record(&mut g, &line)?;
        }
        Ok(existed)
    }
}

impl SpoolStore for DiskBackend {
    /// File first, manifest append second — the module docs' crash
    /// invariant.
    fn put_entry(&self, key: &str, frame: &Buffer, expires_at: Option<Time>) -> Result<()> {
        std::fs::write(path_for(&self.root, key), frame.as_slice())?;
        let entry = SpoolEntry {
            size: frame.len() as u64,
            checksum: super::dataref::checksum(frame.as_slice()),
            expires_at,
        };
        let mut g = self.manifest.lock().expect("spool manifest poisoned");
        g.entries.insert(key.to_string(), entry);
        self.append_record(&mut g, &put_line(key, &entry))
    }
}

impl Drop for DiskBackend {
    fn drop(&mut self) {
        if self.owned {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(b: &dyn StoreBackend) {
        let frame = Buffer::from_vec(vec![0xAB; 512]);
        assert!(b.get("k").unwrap().is_none());
        b.put("k", &frame).unwrap();
        assert_eq!(b.get("k").unwrap().unwrap().as_slice(), frame.as_slice());
        b.put("k", &Buffer::from_vec(vec![1])).unwrap();
        assert_eq!(b.get("k").unwrap().unwrap().as_slice(), [1]);
        assert!(b.remove("k").unwrap());
        assert!(!b.remove("k").unwrap());
        assert!(b.get("k").unwrap().is_none());
    }

    #[test]
    fn memory_contract() {
        exercise(&MemoryBackend::new());
    }

    #[test]
    fn disk_contract() {
        exercise(&DiskBackend::temp().unwrap());
    }

    #[test]
    fn memory_get_shares_allocation() {
        let b = MemoryBackend::new();
        let frame = Buffer::from_vec(vec![7; 4096]);
        b.put("k", &frame).unwrap();
        assert!(b.get("k").unwrap().unwrap().same_allocation(&frame));
    }

    #[test]
    fn disk_keys_do_not_collide_after_sanitizing() {
        let b = DiskBackend::temp().unwrap();
        // Both sanitize to "a_b" — the appended key hash keeps them apart.
        b.put("a/b", &Buffer::from_vec(vec![1])).unwrap();
        b.put("a:b", &Buffer::from_vec(vec![2])).unwrap();
        assert_eq!(b.get("a/b").unwrap().unwrap().as_slice(), [1]);
        assert_eq!(b.get("a:b").unwrap().unwrap().as_slice(), [2]);
    }

    #[test]
    fn temp_spool_removed_on_drop() {
        let root;
        {
            let b = DiskBackend::temp().unwrap();
            root = b.root().to_path_buf();
            b.put("k", &Buffer::from_vec(vec![1])).unwrap();
            assert!(root.exists());
        }
        assert!(!root.exists());
    }

    fn crash_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("funcx-spool-{tag}-{}", crate::Uuid::new()))
    }

    #[test]
    fn recover_readopts_listed_and_reclaims_orphans() {
        let dir = crash_dir("recover");
        let frame = Buffer::from_vec(vec![0x5C; 2048]);
        {
            let b = DiskBackend::new(&dir).unwrap();
            b.set_epoch(42).unwrap();
            b.put_entry("task-result:a", &frame, Some(99.5)).unwrap();
            b.put_entry("task-result:b", &Buffer::from_vec(vec![2; 64]), None).unwrap();
            // Crash: the backend never runs cleanup.
            std::mem::forget(b);
        }
        // Interrupted spill: a frame file with no manifest record.
        std::fs::write(dir.join("orphan.00112233aabbccdd"), [9u8; 100]).unwrap();
        // Damaged file for a listed key: truncate it.
        std::fs::write(path_for(&dir, "task-result:b"), [2u8; 10]).unwrap();

        let (b, adopted) = DiskBackend::recover(&dir).unwrap();
        assert_eq!(b.epoch(), 42, "recovery keeps the stamped epoch");
        assert_eq!(adopted.len(), 1, "only the verifying entry readopts");
        assert_eq!(adopted[0].0, "task-result:a");
        assert_eq!(adopted[0].1.size, 2048);
        assert_eq!(adopted[0].1.expires_at, Some(99.5));
        assert_eq!(
            b.get("task-result:a").unwrap().unwrap().as_slice(),
            frame.as_slice(),
            "readopted frame is byte-identical"
        );
        assert!(b.get("task-result:b").unwrap().is_none(), "damaged entry reclaimed");
        // No leaked files: exactly one frame file + the manifest remain.
        let frames = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| is_frame_file(&e.as_ref().unwrap().path()))
            .count();
        assert_eq!(frames, 1, "orphan and damaged files must be reclaimed");
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn new_reclaims_stale_spool_files() {
        let dir = crash_dir("clean");
        {
            let b = DiskBackend::new(&dir).unwrap();
            b.put("k", &Buffer::from_vec(vec![1; 256])).unwrap();
            std::mem::forget(b); // crash
        }
        let b = DiskBackend::new(&dir).unwrap();
        assert!(b.get("k").unwrap().is_none(), "fresh store starts clean");
        let frames = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| is_frame_file(&e.as_ref().unwrap().path()))
            .count();
        assert_eq!(frames, 0);
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_log_roundtrips_entries_and_removes() {
        let dir = crash_dir("manifest");
        let b = DiskBackend::new(&dir).unwrap();
        b.set_epoch(7).unwrap();
        b.put_entry("spaced key/with:sep", &Buffer::from_vec(vec![3; 128]), Some(12.25)).unwrap();
        b.put_entry("gone", &Buffer::from_vec(vec![4; 32]), None).unwrap();
        assert!(b.remove("gone").unwrap());
        let m = load_manifest(&dir.join(MANIFEST_FILE));
        assert_eq!(m.epoch, 7);
        assert_eq!(m.entries.len(), 1, "the `-` record must mask the earlier `+`");
        let e = m.entries.get("spaced key/with:sep").expect("key survives hex framing");
        assert_eq!(e.size, 128);
        assert_eq!(e.expires_at, Some(12.25));
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The amortized-O(1) pin: a spill appends one record — the log file
    /// grows by one line per mutation, not by the live-set size — and
    /// once the log outgrows the live set it compacts back down.
    #[test]
    fn manifest_appends_then_compacts() {
        let dir = crash_dir("append");
        let b = DiskBackend::new(&dir).unwrap();
        let frame = Buffer::from_vec(vec![1; 64]);
        let lines = |dir: &Path| -> usize {
            std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap().lines().count()
        };
        for i in 0..10 {
            b.put_entry(&format!("k{i}"), &frame, None).unwrap();
            assert_eq!(lines(&dir), 1 + i + 1, "one appended record per spill");
        }
        // Churn one key until the log crosses the compaction bound: the
        // next mutation rewrites it down to the live set.
        let mut peak = 0usize;
        for _ in 0..(COMPACT_FACTOR as usize + 2) * 10 + COMPACT_FLOOR as usize {
            b.put_entry("hot", &frame, None).unwrap();
            peak = peak.max(lines(&dir));
        }
        assert!(
            peak > 11 + COMPACT_FLOOR as usize / 2,
            "log must actually grow before compaction (peak {peak})"
        );
        assert!(
            lines(&dir) <= 1 + 11 + COMPACT_FLOOR as usize,
            "compaction must bound the log near the live set, got {} lines",
            lines(&dir)
        );
        // Everything still replays after the churn.
        let m = load_manifest(&dir.join(MANIFEST_FILE));
        assert_eq!(m.entries.len(), 11);
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Crash tolerance of the log itself: a torn final append (partial
    /// line) and a half-written compaction temp are both survivable —
    /// recovery replays every intact record and ignores the temp.
    #[test]
    fn recover_survives_torn_append_and_interrupted_compaction() {
        let dir = crash_dir("torn");
        let frame = Buffer::from_vec(vec![0x3D; 512]);
        {
            let b = DiskBackend::new(&dir).unwrap();
            b.set_epoch(9).unwrap();
            b.put_entry("a", &frame, None).unwrap();
            b.put_entry("b", &frame, Some(50.0)).unwrap();
            std::mem::forget(b); // crash
        }
        // Torn final append: the record for a third key made it halfway.
        {
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(dir.join(MANIFEST_FILE))
                .unwrap();
            f.write_all(format!("+ {} 51", hex(b"c")).as_bytes()).unwrap();
        }
        // Interrupted compaction: a partial snapshot that never renamed.
        std::fs::write(dir.join(format!("{MANIFEST_FILE}.tmp")), "v2 9\n+ dead").unwrap();

        let (b, adopted) = DiskBackend::recover(&dir).unwrap();
        assert_eq!(b.epoch(), 9);
        assert_eq!(adopted.len(), 2, "both intact records readopt; the torn one is skipped");
        assert_eq!(b.get("a").unwrap().unwrap().as_slice(), frame.as_slice());
        assert_eq!(b.get("b").unwrap().unwrap().as_slice(), frame.as_slice());
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
