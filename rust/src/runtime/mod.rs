//! The PJRT runtime: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only bridge — `HloModuleProto::from_text_file` → `client.compile` →
//! `execute` — so the serving binary is self-contained.

mod artifacts;
mod engine;
mod executor;
mod payload;
mod process;

pub use artifacts::{spec, ArtifactSpec, ElemType, Manifest, ParamSpec, ARTIFACT_SPECS};
pub use engine::{PjrtRuntime, TensorArg};
pub use executor::{BatchItem, WorkerExecutor};
pub use payload::PayloadExecutor;
pub use process::{
    match_reply, read_frame, run_worker_child, write_frame, write_frames, FrameOut, InFlight,
    ProcessExecutor, ProcessExecutorConfig, KIND_READY, KIND_REPLY, KIND_REQUEST,
    MAX_FRAME_BYTES,
};
