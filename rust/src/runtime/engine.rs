//! PJRT engine: compile artifacts once at startup, execute many times.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::common::error::{Error, Result};
use crate::runtime::artifacts::{spec, ElemType, Manifest};

/// A concrete tensor argument for an artifact execution.
#[derive(Clone, Debug)]
pub enum TensorArg {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorArg {
    pub fn len(&self) -> usize {
        match self {
            TensorArg::F32(v) => v.len(),
            TensorArg::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn elem_type(&self) -> ElemType {
        match self {
            TensorArg::F32(_) => ElemType::F32,
            TensorArg::I32(_) => ElemType::I32,
        }
    }
}

/// Loads every artifact in a directory, compiles each once on the PJRT
/// CPU client, and serves executions. Thread-safe; executions are
/// serialized per engine (PJRT CPU executables are not Sync in the 0.1.6
/// crate), so the endpoint runs one engine per worker for parallelism.
pub struct PjrtRuntime {
    inner: Mutex<Inner>,
}

struct Inner {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

// The xla wrappers hold raw pointers; the PJRT CPU client is internally
// synchronized and we guard all use behind the Mutex above.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Load and compile every artifact listed in `dir/manifest.json`.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
        let mut executables = HashMap::new();
        for (name, file) in &manifest.entries {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {file}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {file}: {e}")))?;
            executables.insert(name.clone(), exe);
        }
        Ok(PjrtRuntime { inner: Mutex::new(Inner { client, executables }) })
    }

    /// Names of loaded artifacts.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.inner.lock().unwrap().executables.keys().cloned().collect();
        names.sort();
        names
    }

    /// Execute artifact `name` with `args`, validated against the
    /// compile-time [`spec`]. Returns the output tensors flattened to f32.
    pub fn execute(&self, name: &str, args: &[TensorArg]) -> Result<Vec<Vec<f32>>> {
        let s = spec(name)?;
        if args.len() != s.params.len() {
            return Err(Error::InvalidArgument(format!(
                "artifact {name}: expected {} args, got {}",
                s.params.len(),
                args.len()
            )));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, p) in args.iter().zip(s.params) {
            if arg.elem_type() != p.ty {
                return Err(Error::InvalidArgument(format!(
                    "artifact {name}: param {} type mismatch",
                    p.name
                )));
            }
            if arg.len() != p.elem_count() {
                return Err(Error::InvalidArgument(format!(
                    "artifact {name}: param {} needs {} elements, got {}",
                    p.name,
                    p.elem_count(),
                    arg.len()
                )));
            }
            let lit = match arg {
                TensorArg::F32(v) => xla::Literal::vec1(v),
                TensorArg::I32(v) => xla::Literal::vec1(v),
            };
            let lit = if p.dims.len() == 1 {
                lit
            } else {
                lit.reshape(p.dims).map_err(|e| Error::Runtime(format!("reshape: {e}")))?
            };
            literals.push(lit);
        }

        let inner = self.inner.lock().unwrap();
        let exe = inner
            .executables
            .get(name)
            .ok_or_else(|| Error::NotFound(format!("artifact {name} not loaded")))?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
        if parts.len() != s.outputs {
            return Err(Error::Runtime(format!(
                "artifact {name}: expected {} outputs, got {}",
                s.outputs,
                parts.len()
            )));
        }
        parts
            .into_iter()
            .map(|p| {
                p.to_vec::<f32>().map_err(|e| Error::Runtime(format!("output: {e}")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::OnceLock;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn runtime() -> Option<&'static PjrtRuntime> {
        static RT: OnceLock<Option<PjrtRuntime>> = OnceLock::new();
        RT.get_or_init(|| artifacts_dir().map(|d| PjrtRuntime::load_dir(&d).unwrap()))
            .as_ref()
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        assert_eq!(rt.artifact_names(), vec!["reducer", "stills", "surrogate"]);
    }

    #[test]
    fn surrogate_identity_weights() {
        // With w1 = [I; 0], b = 0, w2 = [I; 0]^T scaled, the MLP reduces to
        // gelu(x) through an identity — but simpler: all-zero weights give
        // logits = 0.
        let Some(rt) = runtime() else {
            return;
        };
        let out = rt
            .execute(
                "surrogate",
                &[
                    TensorArg::F32(vec![0.5; 128 * 256]),
                    TensorArg::F32(vec![0.0; 256 * 512]),
                    TensorArg::F32(vec![0.0; 512]),
                    TensorArg::F32(vec![0.0; 512 * 128]),
                    TensorArg::F32(vec![0.0; 128]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 128 * 128);
        assert!(out[0].iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn stills_counts_planted_peak() {
        let Some(rt) = runtime() else {
            return;
        };
        let mut img = vec![0.0f32; 512 * 512];
        img[100 * 512 + 100] = 50.0; // tile (0,0)
        img[300 * 512 + 400] = 60.0; // tile (1,1)
        let out = rt
            .execute("stills", &[TensorArg::F32(img), TensorArg::F32(vec![1.0])])
            .unwrap();
        assert_eq!(out.len(), 3);
        let counts = &out[0]; // f32[2,2] row-major
        assert_eq!(counts[0], 1.0);
        assert_eq!(counts[3], 1.0);
        assert_eq!(counts[1] + counts[2], 0.0);
        let total = out[2][0];
        assert_eq!(total, 2.0);
    }

    #[test]
    fn reducer_segment_sums() {
        let Some(rt) = runtime() else {
            return;
        };
        let ids: Vec<i32> = (0..4096).map(|i| (i % 256) as i32).collect();
        let vals = vec![1.0f32; 4096];
        let out = rt.execute("reducer", &[TensorArg::I32(ids), TensorArg::F32(vals)]).unwrap();
        assert_eq!(out[0].len(), 256);
        assert!(out[0].iter().all(|v| (*v - 16.0).abs() < 1e-5));
    }

    #[test]
    fn arg_validation() {
        let Some(rt) = runtime() else {
            return;
        };
        // Wrong arity.
        assert!(rt.execute("reducer", &[TensorArg::F32(vec![1.0])]).is_err());
        // Wrong element count.
        assert!(rt
            .execute("reducer", &[TensorArg::I32(vec![0; 7]), TensorArg::F32(vec![0.0; 4096])])
            .is_err());
        // Wrong dtype.
        assert!(rt
            .execute(
                "reducer",
                &[TensorArg::F32(vec![0.0; 4096]), TensorArg::F32(vec![0.0; 4096])]
            )
            .is_err());
        // Unknown artifact.
        assert!(rt.execute("nope", &[]).is_err());
    }
}
