//! The worker-executor abstraction: how a manager's workers actually
//! run task payloads inside a "container" slot.
//!
//! Two backends implement it:
//!
//! - [`PayloadExecutor`](crate::runtime::PayloadExecutor): in-process
//!   execution (the original behavior). Slot lifecycle is a no-op and
//!   cold-start costs are *modeled* — `start_slot` returns `Ok(None)`,
//!   telling the manager to sample its [`StartCostModel`] and sleep.
//! - [`ProcessExecutor`](crate::runtime::ProcessExecutor): each slot is
//!   a real forked child process speaking length-prefixed wire frames
//!   over stdin/stdout. `start_slot` returns `Ok(Some(seconds))` — the
//!   *measured* spawn-plus-handshake cost — which the manager feeds
//!   into the pool's start-cost EWMA so routing and predictive sizing
//!   operate on observed numbers instead of Table-3 samples.
//!
//! Slots are keyed `(pool, slot)`: `pool` is a process-wide unique id
//! minted per manager, so one executor instance can safely back many
//! managers without slot-index collisions.

use crate::common::error::Result;
use crate::common::task::Payload;
use crate::serialize::{pack, unpack, Buffer, Value};

/// One unit of batched dispatch: the payload plus its already-packed
/// input frame (empty when the payload reads no input). Carrying the
/// packed [`Buffer`] — an O(1) refcounted view of the task's frame —
/// keeps batch dispatch allocation-clean: no `Value` clone of the
/// input, no intermediate map, no re-serialization on the way out.
#[derive(Clone, Debug)]
pub struct BatchItem {
    pub payload: Payload,
    pub input: Buffer,
}

/// Executes payloads in (real or virtual) container slots. Implementors
/// must be `Send + Sync`: one executor is shared by every worker thread
/// on an endpoint.
pub trait WorkerExecutor: Send + Sync {
    /// Bring the slot's execution environment up (cold start). Returns
    /// `Ok(Some(seconds))` when the backend *measured* the start cost,
    /// `Ok(None)` when the backend has no real environment to start and
    /// the caller should model the cost instead.
    fn start_slot(&self, pool: u64, slot: usize) -> Result<Option<f64>>;

    /// Tear the slot's environment down (reap/evict). Idempotent; a
    /// slot that was never started is a no-op.
    fn stop_slot(&self, pool: u64, slot: usize);

    /// Run one payload in the slot; returns (output, exec_seconds).
    /// The slot must have been started (backends may lazily start it).
    fn execute_in(
        &self,
        pool: u64,
        slot: usize,
        payload: &Payload,
        input: &Value,
    ) -> Result<(Value, f64)>;

    /// Run a batch of payloads in one slot, invoking `complete(index,
    /// result)` exactly once per item — possibly out of submission
    /// order (a pipelined backend demuxes replies by frame id). Each
    /// success is the *packed* output frame plus exec seconds, so
    /// callers forward results without a re-serialization hop.
    ///
    /// The default implementation degrades to serial [`execute_in`]
    /// calls, which keeps single-exchange backends (the in-process
    /// [`PayloadExecutor`](crate::runtime::PayloadExecutor), the sim)
    /// working unchanged.
    ///
    /// [`execute_in`]: WorkerExecutor::execute_in
    fn execute_batch(
        &self,
        pool: u64,
        slot: usize,
        items: &[BatchItem],
        complete: &mut dyn FnMut(usize, Result<(Buffer, f64)>),
    ) {
        for (i, item) in items.iter().enumerate() {
            let input = if item.payload.reads_input() && !item.input.is_empty() {
                unpack(&item.input).unwrap_or(Value::Null)
            } else {
                Value::Null
            };
            let r = self
                .execute_in(pool, slot, &item.payload, &input)
                .and_then(|(out, exec_s)| Ok((pack(&out, 0)?, exec_s)));
            complete(i, r);
        }
    }

    /// Start costs the backend measured out of band — lazily spawned or
    /// restarted children — since the last drain, for the caller's
    /// warm-pool EWMA. Backends with no out-of-band spawns return none.
    fn drain_start_costs(&self, _pool: u64) -> Vec<f64> {
        Vec::new()
    }

    /// Backend name for metrics/introspection.
    fn backend(&self) -> &'static str;
}

impl WorkerExecutor for crate::runtime::PayloadExecutor {
    fn start_slot(&self, _pool: u64, _slot: usize) -> Result<Option<f64>> {
        Ok(None) // nothing real to start: caller models the cold cost
    }

    fn stop_slot(&self, _pool: u64, _slot: usize) {}

    fn execute_in(
        &self,
        _pool: u64,
        _slot: usize,
        payload: &Payload,
        input: &Value,
    ) -> Result<(Value, f64)> {
        self.execute(payload, input)
    }

    fn backend(&self) -> &'static str {
        "in-process"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::PayloadExecutor;

    #[test]
    fn in_process_backend_models_start_cost() {
        let ex = PayloadExecutor::bare();
        assert_eq!(ex.start_slot(1, 0).unwrap(), None);
        ex.stop_slot(1, 0);
        let (out, _) = ex.execute_in(1, 0, &Payload::Noop, &Value::Null).unwrap();
        assert_eq!(out, Value::Null);
        assert_eq!(WorkerExecutor::backend(&ex), "in-process");
    }

    /// The default batch path degrades to serial execute_in calls and
    /// hands back *packed* output frames, so single-exchange backends
    /// ride the batched manager unchanged.
    #[test]
    fn default_batch_impl_serializes_and_completes_every_item() {
        let ex = PayloadExecutor::bare();
        let items: Vec<BatchItem> = (0..4)
            .map(|i| BatchItem {
                payload: Payload::Echo,
                input: pack(&Value::Int(i), 0).unwrap(),
            })
            .collect();
        let mut seen = Vec::new();
        ex.execute_batch(1, 0, &items, &mut |i, r| {
            let (frame, _) = r.unwrap();
            seen.push((i, unpack(&frame).unwrap()));
        });
        assert_eq!(
            seen,
            (0..4).map(|i| (i as usize, Value::Int(i))).collect::<Vec<_>>()
        );
        assert!(ex.drain_start_costs(1).is_empty(), "no out-of-band spawns in-process");
    }

    #[test]
    fn in_process_backend_types_fault_payloads() {
        let ex = PayloadExecutor::bare();
        let err = ex.execute_in(1, 0, &Payload::Exit(3), &Value::Null).unwrap_err();
        assert_eq!(err.kind(), "WorkerExited");
        let err = ex.execute_in(1, 0, &Payload::Abort, &Value::Null).unwrap_err();
        assert_eq!(err.kind(), "WorkerSignaled");
    }
}
