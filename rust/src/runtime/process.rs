//! The process-executor backend: every container slot is a real forked
//! child process (`funcx worker-child`) speaking length-prefixed,
//! facade-packed [`Value`] frames over stdin/stdout.
//!
//! Protocol (all frames are `u32` little-endian length + packed body):
//!
//! - child → parent on boot: `{ready: true, pid}` — the parent measures
//!   spawn → ready as the slot's cold-start cost.
//! - parent → child per task: `{payload, input}`.
//! - child → parent per task: `{ok: true, out, exec_s}` on success,
//!   `{ok: false, err, exec_s}` when the payload itself failed.
//!
//! A child that exits or is killed mid-task surfaces as a typed
//! [`Error::WorkerExited`] / [`Error::WorkerSignaled`]; a task that
//! overruns the configured timeout kills the child and surfaces
//! [`Error::Timeout`]. Children are killed on drop, so reaping a slot
//! (or dropping the executor) never leaks processes or pipe fds.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::common::error::{Error, Result};
use crate::common::task::Payload;
use crate::runtime::executor::WorkerExecutor;
use crate::serialize::{pack, unpack, Buffer, Value, Wire};

/// Upper bound on a single frame body; a parent/child that claims more
/// is desynced and gets treated as a protocol error.
const MAX_FRAME_BYTES: usize = 64 << 20;

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, v: &Value) -> std::io::Result<()> {
    let body = pack(v, 0)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    let bytes = body.as_slice();
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Read one length-prefixed frame. `Ok(None)` on clean EOF at a frame
/// boundary; errors on truncation, oversized claims, or decode failure.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<Value>> {
    let mut len = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len[filled..])? {
            0 if filled == 0 => return Ok(None), // clean EOF
            0 => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside frame length",
                ))
            }
            n => filled += n,
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {n} bytes exceeds cap"),
        ));
    }
    let mut body = vec![0u8; n];
    r.read_exact(&mut body)?;
    unpack(&Buffer::from_vec(body))
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

/// The `funcx worker-child` entrypoint: frame loop on stdin/stdout with
/// a bare in-process payload executor. Returns the process exit code.
/// Fault-injection payloads really do take the process down — that is
/// their point.
pub fn run_worker_child() -> i32 {
    let executor = crate::runtime::PayloadExecutor::bare();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut output = stdout.lock();

    let ready = Value::map([
        ("ready", Value::Bool(true)),
        ("pid", Value::Int(std::process::id() as i64)),
    ]);
    if write_frame(&mut output, &ready).is_err() {
        return 1;
    }

    loop {
        let frame = match read_frame(&mut input) {
            Ok(Some(v)) => v,
            Ok(None) => return 0, // parent closed stdin: clean shutdown
            Err(_) => return 1,
        };
        let payload = match frame.get("payload").map(Payload::from_value) {
            Some(Ok(p)) => p,
            _ => return 1,
        };
        let task_input = frame.get("input").cloned().unwrap_or(Value::Null);
        match payload {
            Payload::Exit(code) => std::process::exit(code),
            Payload::Abort => std::process::abort(),
            p => {
                let reply = match executor.execute(&p, &task_input) {
                    Ok((out, exec_s)) => Value::map([
                        ("ok", Value::Bool(true)),
                        ("out", out),
                        ("exec_s", Value::Float(exec_s)),
                    ]),
                    Err(e) => Value::map([
                        ("ok", Value::Bool(false)),
                        ("err", Value::Str(e.to_string())),
                        ("exec_s", Value::Float(0.0)),
                    ]),
                };
                if write_frame(&mut output, &reply).is_err() {
                    return 1;
                }
            }
        }
    }
}

/// Map a reaped child's exit status to the typed worker error.
fn status_error(status: std::process::ExitStatus) -> Error {
    #[cfg(unix)]
    {
        use std::os::unix::process::ExitStatusExt;
        if let Some(signal) = status.signal() {
            return Error::WorkerSignaled { signal };
        }
    }
    Error::WorkerExited { code: status.code().unwrap_or(-1) }
}

/// One live worker child: the process, its stdin, and a reader thread
/// draining stdout frames into a channel (so the parent can wait with a
/// timeout — blocking reads on pipes have none).
struct WorkerChild {
    child: Child,
    stdin: ChildStdin,
    frames: mpsc::Receiver<Value>,
}

impl WorkerChild {
    /// Kill and reap, returning the typed error for the exit status.
    fn reap(mut self) -> Error {
        let _ = self.child.kill();
        match self.child.wait() {
            Ok(status) => status_error(status),
            Err(e) => Error::Io(e),
        }
    }
}

impl Drop for WorkerChild {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Configuration for the process executor.
#[derive(Clone, Debug)]
pub struct ProcessExecutorConfig {
    /// Binary to spawn with the `worker-child` argument. Tests and
    /// benches pass `env!("CARGO_BIN_EXE_funcx")`; embedders default to
    /// the current executable.
    pub binary: std::path::PathBuf,
    /// Per-task wall-clock budget; an overrun kills the child.
    pub task_timeout_s: f64,
    /// Spawn → ready-frame handshake budget.
    pub start_timeout_s: f64,
}

impl ProcessExecutorConfig {
    pub fn new(binary: impl Into<std::path::PathBuf>) -> Self {
        ProcessExecutorConfig {
            binary: binary.into(),
            task_timeout_s: 300.0,
            start_timeout_s: 30.0,
        }
    }

    /// Spawn children from the currently running executable.
    pub fn current_exe() -> Result<Self> {
        Ok(Self::new(std::env::current_exe()?))
    }
}

/// The process-backed [`WorkerExecutor`]: one child process per started
/// `(pool, slot)` key, measured cold starts, kill-on-drop.
pub struct ProcessExecutor {
    cfg: ProcessExecutorConfig,
    workers: Mutex<HashMap<(u64, usize), WorkerChild>>,
    spawned: AtomicU64,
    stopped: AtomicU64,
    timeouts: AtomicU64,
    worker_faults: AtomicU64,
}

impl ProcessExecutor {
    pub fn new(cfg: ProcessExecutorConfig) -> Self {
        ProcessExecutor {
            cfg,
            workers: Mutex::new(HashMap::new()),
            spawned: AtomicU64::new(0),
            stopped: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            worker_faults: AtomicU64::new(0),
        }
    }

    /// Total children forked over the executor's lifetime.
    pub fn spawned(&self) -> u64 {
        self.spawned.load(Ordering::Relaxed)
    }

    /// Slots explicitly stopped (reaped) over the lifetime.
    pub fn stopped(&self) -> u64 {
        self.stopped.load(Ordering::Relaxed)
    }

    /// Tasks killed for overrunning the task timeout.
    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }

    /// Children that died mid-task (exit or signal).
    pub fn worker_faults(&self) -> u64 {
        self.worker_faults.load(Ordering::Relaxed)
    }

    /// Currently live children.
    pub fn active_workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Fork a child and wait for its ready frame; returns the child and
    /// the measured spawn-plus-handshake seconds.
    fn spawn_child(&self) -> Result<(WorkerChild, f64)> {
        let t0 = Instant::now();
        let mut child = Command::new(&self.cfg.binary)
            .arg("worker-child")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()?;
        let stdin = child.stdin.take().expect("stdin piped");
        let mut stdout = child.stdout.take().expect("stdout piped");
        let (tx, rx) = mpsc::channel();
        std::thread::spawn(move || {
            // Drain frames until EOF/error; dropping `tx` disconnects
            // the receiver, which the parent reads as "child is gone".
            while let Ok(Some(v)) = read_frame(&mut stdout) {
                if tx.send(v).is_err() {
                    break;
                }
            }
        });
        let worker = WorkerChild { child, stdin, frames: rx };
        let start_budget = Duration::from_secs_f64(self.cfg.start_timeout_s.max(0.001));
        match worker.frames.recv_timeout(start_budget) {
            Ok(ready) if ready.get("ready").is_some() => {
                self.spawned.fetch_add(1, Ordering::Relaxed);
                Ok((worker, t0.elapsed().as_secs_f64()))
            }
            Ok(_) => {
                worker.reap();
                Err(Error::Runtime("worker child sent a non-ready first frame".into()))
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                worker.reap();
                Err(Error::Timeout(format!(
                    "worker child not ready within {:.1}s",
                    self.cfg.start_timeout_s
                )))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(worker.reap()),
        }
    }

    /// Run one framed request/response exchange against a live child.
    fn exchange(&self, worker: &mut WorkerChild, req: &Value) -> Result<Value> {
        if let Err(e) = write_frame(&mut worker.stdin, req) {
            // Write failure means the child is dead or dying; reaping
            // happens in the caller (which owns the worker).
            return Err(Error::Io(e));
        }
        let budget = Duration::from_secs_f64(self.cfg.task_timeout_s.max(0.001));
        match worker.frames.recv_timeout(budget) {
            Ok(v) => Ok(v),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.timeouts.fetch_add(1, Ordering::Relaxed);
                Err(Error::Timeout(format!(
                    "task exceeded {:.1}s in worker child",
                    self.cfg.task_timeout_s
                )))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Child closed stdout: it exited or was killed. The
                // caller reaps it for the precise typed status.
                Err(Error::Shutdown("worker child closed its pipe".into()))
            }
        }
    }
}

impl WorkerExecutor for ProcessExecutor {
    fn start_slot(&self, pool: u64, slot: usize) -> Result<Option<f64>> {
        let (worker, seconds) = self.spawn_child()?;
        let prev = self.workers.lock().unwrap().insert((pool, slot), worker);
        drop(prev); // kill any forgotten predecessor for this slot
        Ok(Some(seconds))
    }

    fn stop_slot(&self, pool: u64, slot: usize) {
        if self.workers.lock().unwrap().remove(&(pool, slot)).is_some() {
            self.stopped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn execute_in(
        &self,
        pool: u64,
        slot: usize,
        payload: &Payload,
        input: &Value,
    ) -> Result<(Value, f64)> {
        // Take the child out of the map for the duration of the task so
        // one slow task never serializes the other workers.
        let mut worker = match self.workers.lock().unwrap().remove(&(pool, slot)) {
            Some(w) => w,
            None => {
                // Lazily started slot: pay (and report via the typed
                // path below, not here) the spawn cost.
                self.spawn_child()?.0
            }
        };
        let req = Value::map([("payload", payload.to_value()), ("input", input.clone())]);
        match self.exchange(&mut worker, &req) {
            Ok(reply) => {
                // Healthy exchange: return the slot to the map.
                self.workers.lock().unwrap().insert((pool, slot), worker);
                let ok = matches!(reply.get("ok"), Some(Value::Bool(true)));
                let exec_s = reply.get("exec_s").and_then(Value::as_float).unwrap_or(0.0);
                if ok {
                    Ok((reply.get("out").cloned().unwrap_or(Value::Null), exec_s))
                } else {
                    let msg = reply
                        .get("err")
                        .and_then(Value::as_str)
                        .unwrap_or("unknown worker error")
                        .to_string();
                    Err(Error::TaskFailed(msg))
                }
            }
            Err(Error::Timeout(m)) => {
                // Kill the overrunning child; the slot is poisoned.
                worker.reap();
                Err(Error::Timeout(m))
            }
            Err(_) => {
                // Pipe-level failure: reap for the precise exit status.
                self.worker_faults.fetch_add(1, Ordering::Relaxed);
                Err(worker.reap())
            }
        }
    }

    fn backend(&self) -> &'static str {
        "process"
    }
}

impl Drop for ProcessExecutor {
    fn drop(&mut self) {
        // WorkerChild::drop kills each remaining child.
        self.workers.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let v = Value::map([
            ("payload", Payload::Sleep(0.25).to_value()),
            ("input", Value::Int(42)),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        let mut r = Cursor::new(buf);
        let back = read_frame(&mut r).unwrap().expect("one frame");
        let p = Payload::from_value(back.get("payload").unwrap()).unwrap();
        assert_eq!(p, Payload::Sleep(0.25));
        assert_eq!(back.get("input"), Some(&Value::Int(42)));
        // Clean EOF after the frame.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn frame_rejects_truncation_and_oversize() {
        // Truncated length prefix.
        let mut r = Cursor::new(vec![1u8, 0]);
        assert!(read_frame(&mut r).is_err());
        // Truncated body.
        let mut buf = Vec::new();
        write_frame(&mut buf, &Value::Int(7)).unwrap();
        buf.truncate(buf.len() - 1);
        let mut r = Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
        // Oversized claim.
        let mut r = Cursor::new(((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
    }

    #[cfg(unix)]
    #[test]
    fn status_error_types_exits_and_signals() {
        use std::os::unix::process::ExitStatusExt;
        // Raw wait status: exit code in bits 8..16, signal in bits 0..7.
        let exited = std::process::ExitStatus::from_raw(3 << 8);
        assert_eq!(status_error(exited).kind(), "WorkerExited");
        let signaled = std::process::ExitStatus::from_raw(9);
        match status_error(signaled) {
            Error::WorkerSignaled { signal } => assert_eq!(signal, 9),
            e => panic!("expected WorkerSignaled, got {e}"),
        }
    }
}
