//! The process-executor backend: every container slot is a real forked
//! child process (`funcx worker-child`) speaking frame-multiplexed v2
//! wire frames over stdin/stdout.
//!
//! v2 frame layout (all integers little-endian):
//!
//! ```text
//! u32 length | u64 frame id | u8 kind | body[length - 9]
//! ```
//!
//! The length covers the id, kind, and body. Kinds:
//!
//! - `KIND_READY` (child → parent on boot): body is the packed
//!   `{ready: true, pid}` map — the parent measures spawn → ready as the
//!   slot's cold-start cost.
//! - `KIND_REQUEST` (parent → child): body is the packed
//!   `{payload}` meta immediately followed by the task's input frame as
//!   a raw trailer (empty when the payload reads no input). Because the
//!   facade header carries its own body length, the concatenation is
//!   exactly the trailer codec's layout: the child splits it back with
//!   one zero-copy [`unpack_with_trailer`](crate::serialize::unpack_with_trailer).
//! - `KIND_REPLY` (child → parent): body is the packed
//!   `{ok, err?, exec_s}` meta followed by the packed output frame as
//!   the trailer (empty on failure). The reply echoes the request's
//!   frame id, which is how the parent demuxes pipelined completions.
//!
//! A per-child writer keeps up to `pipeline_depth` request frames in
//! flight, flushed as one vectored write straight from the caller's
//! buffers — the parent never copies an input into an intermediate
//! buffer or `Value`. Replies may complete out of order; a timeout fires
//! only when the *oldest* outstanding frame exceeds the task budget. A
//! child that exits, is killed, or desyncs fails exactly its in-flight
//! frames typed ([`Error::WorkerExited`] / [`Error::WorkerSignaled`] /
//! [`Error::Timeout`]) and is restarted in place — counted in
//! `slot_restarts` — so a crash never poisons the slot. Children are
//! killed on drop, so reaping a slot (or dropping the executor) never
//! leaks processes or pipe fds.

use std::collections::HashMap;
use std::io::{IoSlice, Read, Write};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::common::error::{Error, Result};
use crate::common::task::Payload;
use crate::runtime::executor::{BatchItem, WorkerExecutor};
use crate::serialize::{pack, unpack, unpack_with_trailer, Buffer, Value, Wire};

/// Upper bound on a single frame; a parent/child that claims more is
/// desynced and gets treated as a protocol error.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Child → parent boot handshake frame.
pub const KIND_READY: u8 = 0;
/// Parent → child task request frame.
pub const KIND_REQUEST: u8 = 1;
/// Child → parent task reply frame (echoes the request's id).
pub const KIND_REPLY: u8 = 2;

/// One outbound frame: (frame id, kind, packed meta, raw trailer). The
/// meta and trailer are written back to back as the frame body.
pub type FrameOut<'a> = (u64, u8, &'a [u8], &'a [u8]);

/// Write a batch of v2 frames with ONE vectored write: per frame a
/// 13-byte header (length, id, kind), the packed meta, and the raw
/// trailer straight from the caller's buffer — input bytes never pass
/// through an intermediate copy on the way to the pipe.
pub fn write_frames<W: Write>(w: &mut W, frames: &[FrameOut<'_>]) -> std::io::Result<()> {
    if frames.is_empty() {
        return Ok(());
    }
    let mut headers = Vec::with_capacity(frames.len());
    for (id, kind, meta, trailer) in frames {
        let n = 9 + meta.len() + trailer.len();
        if n > MAX_FRAME_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame of {n} bytes exceeds cap"),
            ));
        }
        let mut h = [0u8; 13];
        h[..4].copy_from_slice(&(n as u32).to_le_bytes());
        h[4..12].copy_from_slice(&id.to_le_bytes());
        h[12] = *kind;
        headers.push(h);
    }
    let mut slices = Vec::with_capacity(frames.len() * 3);
    for ((_, _, meta, trailer), h) in frames.iter().zip(&headers) {
        slices.push(IoSlice::new(h));
        slices.push(IoSlice::new(meta));
        if !trailer.is_empty() {
            slices.push(IoSlice::new(trailer));
        }
    }
    // Manual write_all_vectored (the std one is unstable): one writev
    // covers the common case; a short write falls back to write_all on
    // the remaining tail.
    let mut skip = w.write_vectored(&slices)?;
    for s in &slices {
        if skip >= s.len() {
            skip -= s.len();
            continue;
        }
        w.write_all(&s[skip..])?;
        skip = 0;
    }
    w.flush()
}

/// Write one v2 frame (see [`write_frames`] for the batched form).
pub fn write_frame<W: Write>(w: &mut W, id: u64, kind: u8, body: &[u8]) -> std::io::Result<()> {
    write_frames(w, &[(id, kind, body, &[])])
}

/// Read one v2 frame as `(id, kind, body)`. `Ok(None)` on clean EOF at
/// a frame boundary; errors on truncated length prefixes, truncated
/// bodies, oversized claims, or frames too short to carry an id + kind.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<(u64, u8, Buffer)>> {
    let mut len = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len[filled..])? {
            0 if filled == 0 => return Ok(None), // clean EOF
            0 => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside frame length",
                ))
            }
            n => filled += n,
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {n} bytes exceeds cap"),
        ));
    }
    if n < 9 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {n} bytes too short for id and kind"),
        ));
    }
    let mut body = vec![0u8; n];
    r.read_exact(&mut body)?;
    let id = u64::from_le_bytes(body[..8].try_into().expect("8 length bytes"));
    let kind = body[8];
    Ok(Some((id, kind, Buffer::from_vec(body).slice(9, n - 9))))
}

/// One outstanding request frame in a child's pipeline window.
#[derive(Clone, Copy, Debug)]
pub struct InFlight {
    /// Index into the batch the frame belongs to.
    pub item: usize,
    /// The frame id the reply must echo.
    pub id: u64,
    /// When the request was flushed (per-frame deadline anchor).
    pub sent: Instant,
}

/// Demux one received frame against the in-flight window: the position
/// of the matching outstanding frame, or a typed protocol error for a
/// non-reply kind or an unknown id. A duplicate reply is unknown by
/// construction — an id leaves the window the moment it completes — so
/// duplicates fail the same typed way instead of corrupting a slot.
pub fn match_reply(pending: &[InFlight], id: u64, kind: u8) -> Result<usize> {
    if kind != KIND_REPLY {
        return Err(Error::Runtime(format!(
            "worker protocol desync: unexpected frame kind {kind}"
        )));
    }
    pending.iter().position(|f| f.id == id).ok_or_else(|| {
        Error::Runtime(format!(
            "worker protocol desync: reply for unknown or duplicate frame id {id}"
        ))
    })
}

/// The `funcx worker-child` entrypoint: v2 frame loop on stdin/stdout
/// with a bare in-process payload executor. Returns the process exit
/// code. Fault-injection payloads really do take the process down —
/// that is their point.
pub fn run_worker_child() -> i32 {
    let executor = crate::runtime::PayloadExecutor::bare();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut output = stdout.lock();

    let ready = Value::map([
        ("ready", Value::Bool(true)),
        ("pid", Value::Int(std::process::id() as i64)),
    ]);
    let Ok(ready) = pack(&ready, 0) else { return 1 };
    if write_frame(&mut output, 0, KIND_READY, ready.as_slice()).is_err() {
        return 1;
    }

    loop {
        let (id, kind, body) = match read_frame(&mut input) {
            Ok(Some(f)) => f,
            Ok(None) => return 0, // parent closed stdin: clean shutdown
            Err(_) => return 1,
        };
        if kind != KIND_REQUEST {
            return 1; // desynced parent: bail so it reaps a typed status
        }
        let Ok((meta, trailer)) = unpack_with_trailer(&body) else { return 1 };
        let payload = match meta.get("payload").map(Payload::from_value) {
            Some(Ok(p)) => p,
            _ => return 1,
        };
        match payload {
            Payload::Exit(code) => std::process::exit(code),
            Payload::Abort => std::process::abort(),
            p => {
                let task_input = if trailer.is_empty() {
                    Value::Null
                } else {
                    unpack(&trailer).unwrap_or(Value::Null)
                };
                let (meta, out_frame) = match executor.execute(&p, &task_input) {
                    Ok((out, exec_s)) => match pack(&out, 0) {
                        Ok(frame) => (
                            Value::map([
                                ("ok", Value::Bool(true)),
                                ("exec_s", Value::Float(exec_s)),
                            ]),
                            frame,
                        ),
                        Err(e) => (
                            Value::map([
                                ("ok", Value::Bool(false)),
                                ("err", Value::Str(e.to_string())),
                                ("exec_s", Value::Float(0.0)),
                            ]),
                            Buffer::empty(),
                        ),
                    },
                    Err(e) => (
                        Value::map([
                            ("ok", Value::Bool(false)),
                            ("err", Value::Str(e.to_string())),
                            ("exec_s", Value::Float(0.0)),
                        ]),
                        Buffer::empty(),
                    ),
                };
                let Ok(meta) = pack(&meta, 0) else { return 1 };
                let reply = [(id, KIND_REPLY, meta.as_slice(), out_frame.as_slice())];
                if write_frames(&mut output, &reply).is_err() {
                    return 1;
                }
            }
        }
    }
}

/// Map a reaped child's exit status to the typed worker error.
fn status_error(status: std::process::ExitStatus) -> Error {
    #[cfg(unix)]
    {
        use std::os::unix::process::ExitStatusExt;
        if let Some(signal) = status.signal() {
            return Error::WorkerSignaled { signal };
        }
    }
    Error::WorkerExited { code: status.code().unwrap_or(-1) }
}

/// Re-materialize a typed worker error for each additional in-flight
/// frame that died with the child ([`Error`] is not `Clone`).
fn replicate(e: &Error) -> Error {
    match e {
        Error::WorkerExited { code } => Error::WorkerExited { code: *code },
        Error::WorkerSignaled { signal } => Error::WorkerSignaled { signal: *signal },
        Error::Timeout(m) => Error::Timeout(m.clone()),
        Error::Runtime(m) => Error::Runtime(m.clone()),
        other => Error::Shutdown(other.to_string()),
    }
}

/// Parse a reply body (`{ok, err?, exec_s}` meta + packed output
/// trailer). `None` means the body did not parse — a protocol desync —
/// unlike a well-formed `{ok: false}`, which is a healthy task-level
/// failure.
fn parse_reply(body: &Buffer) -> Option<Result<(Buffer, f64)>> {
    let (meta, out) = unpack_with_trailer(body).ok()?;
    let exec_s = meta.get("exec_s").and_then(Value::as_float).unwrap_or(0.0);
    if matches!(meta.get("ok"), Some(Value::Bool(true))) {
        Some(Ok((out, exec_s)))
    } else {
        let msg = meta
            .get("err")
            .and_then(Value::as_str)
            .unwrap_or("unknown worker error")
            .to_string();
        Some(Err(Error::TaskFailed(msg)))
    }
}

/// One live worker child: the process, its stdin, and a reader thread
/// draining stdout frames into a channel (so the parent can wait with a
/// timeout — blocking reads on pipes have none).
struct WorkerChild {
    child: Child,
    stdin: ChildStdin,
    frames: mpsc::Receiver<(u64, u8, Buffer)>,
}

impl WorkerChild {
    /// Kill and reap, returning the typed error for the exit status.
    fn reap(mut self) -> Error {
        let _ = self.child.kill();
        match self.child.wait() {
            Ok(status) => status_error(status),
            Err(e) => Error::Io(e),
        }
    }
}

impl Drop for WorkerChild {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Configuration for the process executor.
#[derive(Clone, Debug)]
pub struct ProcessExecutorConfig {
    /// Binary to spawn with the `worker-child` argument. Tests and
    /// benches pass `env!("CARGO_BIN_EXE_funcx")`; embedders default to
    /// the current executable.
    pub binary: std::path::PathBuf,
    /// Per-task wall-clock budget, measured per frame from its flush; an
    /// overrun by the *oldest* outstanding frame kills the child.
    pub task_timeout_s: f64,
    /// Spawn → ready-frame handshake budget.
    pub start_timeout_s: f64,
    /// In-flight request frames the per-child writer keeps outstanding
    /// (the v2 pipeline window). 1 restores strict request/reply.
    pub pipeline_depth: usize,
}

impl ProcessExecutorConfig {
    pub fn new(binary: impl Into<std::path::PathBuf>) -> Self {
        ProcessExecutorConfig {
            binary: binary.into(),
            task_timeout_s: 300.0,
            start_timeout_s: 30.0,
            pipeline_depth: 4,
        }
    }

    /// Spawn children from the currently running executable.
    pub fn current_exe() -> Result<Self> {
        Ok(Self::new(std::env::current_exe()?))
    }
}

/// The process-backed [`WorkerExecutor`]: one child process per started
/// `(pool, slot)` key, measured cold starts, pipelined v2 exchanges,
/// restart-in-place on faults, kill-on-drop.
pub struct ProcessExecutor {
    cfg: ProcessExecutorConfig,
    workers: Mutex<HashMap<(u64, usize), WorkerChild>>,
    spawned: AtomicU64,
    stopped: AtomicU64,
    timeouts: AtomicU64,
    worker_faults: AtomicU64,
    slot_restarts: AtomicU64,
    next_frame_id: AtomicU64,
    /// Start costs measured outside `start_slot` (lazy spawns and
    /// in-place restarts), parked per pool until the manager drains
    /// them into its warm-pool EWMA via `drain_start_costs`.
    lazy_costs: Mutex<HashMap<u64, Vec<f64>>>,
}

impl ProcessExecutor {
    pub fn new(cfg: ProcessExecutorConfig) -> Self {
        ProcessExecutor {
            cfg,
            workers: Mutex::new(HashMap::new()),
            spawned: AtomicU64::new(0),
            stopped: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            worker_faults: AtomicU64::new(0),
            slot_restarts: AtomicU64::new(0),
            next_frame_id: AtomicU64::new(1),
            lazy_costs: Mutex::new(HashMap::new()),
        }
    }

    /// Total children forked over the executor's lifetime.
    pub fn spawned(&self) -> u64 {
        self.spawned.load(Ordering::Relaxed)
    }

    /// Slots explicitly stopped (reaped) over the lifetime.
    pub fn stopped(&self) -> u64 {
        self.stopped.load(Ordering::Relaxed)
    }

    /// Tasks killed for overrunning the task timeout.
    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }

    /// Children that died mid-task (exit or signal).
    pub fn worker_faults(&self) -> u64 {
        self.worker_faults.load(Ordering::Relaxed)
    }

    /// Children restarted in place after a timeout kill, crash, or
    /// protocol desync — the slot keeps serving instead of going cold.
    pub fn slot_restarts(&self) -> u64 {
        self.slot_restarts.load(Ordering::Relaxed)
    }

    /// Currently live children.
    pub fn active_workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    fn note_lazy_cost(&self, pool: u64, seconds: f64) {
        self.lazy_costs.lock().unwrap().entry(pool).or_default().push(seconds);
    }

    /// Fork a child and wait for its ready frame; returns the child and
    /// the measured spawn-plus-handshake seconds.
    fn spawn_child(&self) -> Result<(WorkerChild, f64)> {
        let t0 = Instant::now();
        let mut child = Command::new(&self.cfg.binary)
            .arg("worker-child")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()?;
        let stdin = child.stdin.take().expect("stdin piped");
        let mut stdout = child.stdout.take().expect("stdout piped");
        let (tx, rx) = mpsc::channel();
        std::thread::spawn(move || {
            // Drain frames until EOF/error; dropping `tx` disconnects
            // the receiver, which the parent reads as "child is gone".
            while let Ok(Some(frame)) = read_frame(&mut stdout) {
                if tx.send(frame).is_err() {
                    break;
                }
            }
        });
        let worker = WorkerChild { child, stdin, frames: rx };
        let start_budget = Duration::from_secs_f64(self.cfg.start_timeout_s.max(0.001));
        match worker.frames.recv_timeout(start_budget) {
            Ok((_, kind, body))
                if kind == KIND_READY
                    && unpack(&body).is_ok_and(|v| v.get("ready").is_some()) =>
            {
                self.spawned.fetch_add(1, Ordering::Relaxed);
                Ok((worker, t0.elapsed().as_secs_f64()))
            }
            Ok(_) => {
                worker.reap();
                Err(Error::Runtime("worker child sent a non-ready first frame".into()))
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                worker.reap();
                Err(Error::Timeout(format!(
                    "worker child not ready within {:.1}s",
                    self.cfg.start_timeout_s
                )))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(worker.reap()),
        }
    }

    /// Restart a slot's child in place after a kill: the replacement is
    /// live before the next task arrives, so a crash or timeout never
    /// poisons the slot. Counted in `slot_restarts`; the measured
    /// respawn cost is surfaced via `drain_start_costs`. `None` when the
    /// respawn itself failed (the slot then goes cold and the next
    /// acquire re-forks lazily).
    fn respawn(&self, pool: u64) -> Option<WorkerChild> {
        match self.spawn_child() {
            Ok((w, seconds)) => {
                self.slot_restarts.fetch_add(1, Ordering::Relaxed);
                self.note_lazy_cost(pool, seconds);
                Some(w)
            }
            Err(_) => None,
        }
    }

    /// Fail every in-flight frame with the dead child's typed status,
    /// then restart the slot in place. `None` when the respawn failed.
    fn restart_slot(
        &self,
        pool: u64,
        status: &Error,
        pending: &mut Vec<InFlight>,
        complete: &mut dyn FnMut(usize, Result<(Buffer, f64)>),
    ) -> Option<WorkerChild> {
        for f in pending.drain(..) {
            complete(f.item, Err(replicate(status)));
        }
        self.respawn(pool)
    }
}

impl WorkerExecutor for ProcessExecutor {
    fn start_slot(&self, pool: u64, slot: usize) -> Result<Option<f64>> {
        let (worker, seconds) = self.spawn_child()?;
        let prev = self.workers.lock().unwrap().insert((pool, slot), worker);
        drop(prev); // kill any forgotten predecessor for this slot
        Ok(Some(seconds))
    }

    fn stop_slot(&self, pool: u64, slot: usize) {
        if self.workers.lock().unwrap().remove(&(pool, slot)).is_some() {
            self.stopped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn execute_in(
        &self,
        pool: u64,
        slot: usize,
        payload: &Payload,
        input: &Value,
    ) -> Result<(Value, f64)> {
        let input_frame =
            if payload.reads_input() { pack(input, 0)? } else { Buffer::empty() };
        let items = [BatchItem { payload: payload.clone(), input: input_frame }];
        let mut out = None;
        self.execute_batch(pool, slot, &items, &mut |_, r| out = Some(r));
        match out.expect("a single-item batch always completes its item") {
            Ok((frame, exec_s)) => Ok((unpack(&frame)?, exec_s)),
            Err(e) => Err(e),
        }
    }

    /// The pipelined engine. Claims the slot's child for the duration of
    /// the batch, keeps up to `pipeline_depth` request frames in flight
    /// (flushed as one vectored write each round), and completes items
    /// out of order as replies land. The timeout clock always runs
    /// against the oldest outstanding frame; any kill restarts the child
    /// in place and the unsent remainder continues on the replacement.
    fn execute_batch(
        &self,
        pool: u64,
        slot: usize,
        items: &[BatchItem],
        complete: &mut dyn FnMut(usize, Result<(Buffer, f64)>),
    ) {
        if items.is_empty() {
            return;
        }
        let key = (pool, slot);
        let depth = self.cfg.pipeline_depth.max(1);
        let budget = Duration::from_secs_f64(self.cfg.task_timeout_s.max(0.001));
        let existing = self.workers.lock().unwrap().remove(&key);
        let mut worker = match existing {
            Some(w) => w,
            None => match self.spawn_child() {
                Ok((w, seconds)) => {
                    // Lazily started slot: the measured cost feeds the
                    // caller's warm-pool EWMA via drain_start_costs.
                    self.note_lazy_cost(pool, seconds);
                    w
                }
                Err(e) => {
                    let mut first = Some(e);
                    for i in 0..items.len() {
                        let err = match first.take() {
                            Some(e) => e,
                            None => Error::Shutdown("worker child failed to spawn".into()),
                        };
                        complete(i, Err(err));
                    }
                    return;
                }
            },
        };

        let mut next = 0usize; // first item not yet flushed
        let mut pending: Vec<InFlight> = Vec::with_capacity(depth);
        let mut intact = true; // stdin still writable
        'drive: while next < items.len() || !pending.is_empty() {
            // Fill the window and flush it as ONE vectored write: each
            // frame body is the packed {payload} meta followed by the
            // task's input buffer as a raw trailer.
            if intact && next < items.len() && pending.len() < depth {
                let n = (depth - pending.len()).min(items.len() - next);
                let mut metas: Vec<(usize, u64, Buffer)> = Vec::with_capacity(n);
                for (k, item) in items[next..next + n].iter().enumerate() {
                    let meta = Value::map([("payload", item.payload.to_value())]);
                    match pack(&meta, 0) {
                        Ok(frame) => {
                            let id = self.next_frame_id.fetch_add(1, Ordering::Relaxed);
                            metas.push((next + k, id, frame));
                        }
                        Err(e) => complete(next + k, Err(e)),
                    }
                }
                next += n;
                let frames: Vec<FrameOut<'_>> = metas
                    .iter()
                    .map(|(idx, id, meta)| {
                        (*id, KIND_REQUEST, meta.as_slice(), items[*idx].input.as_slice())
                    })
                    .collect();
                intact = write_frames(&mut worker.stdin, &frames).is_ok();
                let sent = Instant::now();
                for (idx, id, _) in &metas {
                    // A failed write still enqueues the frames: the
                    // child is dead or dying, and the reply loop below
                    // surfaces its precise typed status (any buffered
                    // replies drain first, then the disconnect).
                    pending.push(InFlight { item: *idx, id: *id, sent });
                }
            }

            let Some(&InFlight { item: oldest, sent, .. }) = pending.first() else {
                if intact {
                    continue 'drive; // nothing in flight; next round flushes more
                }
                // Broken stdin with nothing in flight: reap the typed
                // status and restart before sending the remainder.
                self.worker_faults.fetch_add(1, Ordering::Relaxed);
                let status = worker.reap();
                match self.restart_slot(pool, &status, &mut pending, complete) {
                    Some(w) => {
                        worker = w;
                        intact = true;
                        continue 'drive;
                    }
                    None => {
                        for i in next..items.len() {
                            complete(i, Err(replicate(&status)));
                        }
                        return;
                    }
                }
            };

            let elapsed = sent.elapsed();
            if elapsed >= budget {
                // The oldest frame overran its budget: kill the child,
                // fail the overrunner as Timeout and every other
                // in-flight frame with the reaped typed status, then
                // restart the slot in place.
                self.timeouts.fetch_add(1, Ordering::Relaxed);
                pending.remove(0);
                complete(
                    oldest,
                    Err(Error::Timeout(format!(
                        "task exceeded {:.1}s in worker child",
                        self.cfg.task_timeout_s
                    ))),
                );
                let status = worker.reap();
                match self.restart_slot(pool, &status, &mut pending, complete) {
                    Some(w) => {
                        worker = w;
                        intact = true;
                        continue 'drive;
                    }
                    None => {
                        for i in next..items.len() {
                            complete(i, Err(replicate(&status)));
                        }
                        return;
                    }
                }
            }

            let received = worker.frames.recv_timeout(budget - elapsed);
            match received {
                Ok((id, kind, body)) => match match_reply(&pending, id, kind) {
                    Ok(pos) => {
                        let InFlight { item, .. } = pending.remove(pos);
                        match parse_reply(&body) {
                            Some(result) => complete(item, result),
                            None => {
                                // The reply matched an in-flight id but
                                // its body didn't parse: the stream is
                                // desynced beyond recovery.
                                let status = Error::Runtime(
                                    "worker protocol desync: unparseable reply body".into(),
                                );
                                complete(item, Err(replicate(&status)));
                                self.worker_faults.fetch_add(1, Ordering::Relaxed);
                                let _ = worker.reap();
                                match self.restart_slot(pool, &status, &mut pending, complete)
                                {
                                    Some(w) => {
                                        worker = w;
                                        intact = true;
                                    }
                                    None => {
                                        for i in next..items.len() {
                                            complete(i, Err(replicate(&status)));
                                        }
                                        return;
                                    }
                                }
                            }
                        }
                    }
                    Err(status) => {
                        // Unknown id, duplicate id, or non-reply kind:
                        // a desynced child cannot be trusted with the
                        // rest of the window.
                        self.worker_faults.fetch_add(1, Ordering::Relaxed);
                        let _ = worker.reap();
                        match self.restart_slot(pool, &status, &mut pending, complete) {
                            Some(w) => {
                                worker = w;
                                intact = true;
                            }
                            None => {
                                for i in next..items.len() {
                                    complete(i, Err(replicate(&status)));
                                }
                                return;
                            }
                        }
                    }
                },
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Loop re-checks the oldest frame's deadline.
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Child exited or was killed mid-flight: reap the
                    // precise typed status, fail exactly the in-flight
                    // frames, restart the slot in place.
                    self.worker_faults.fetch_add(1, Ordering::Relaxed);
                    let status = worker.reap();
                    match self.restart_slot(pool, &status, &mut pending, complete) {
                        Some(w) => {
                            worker = w;
                            intact = true;
                        }
                        None => {
                            for i in next..items.len() {
                                complete(i, Err(replicate(&status)));
                            }
                            return;
                        }
                    }
                }
            }
        }
        // Healthy end of batch: the live child returns to the slot map.
        self.workers.lock().unwrap().insert(key, worker);
    }

    fn drain_start_costs(&self, pool: u64) -> Vec<f64> {
        self.lazy_costs.lock().unwrap().remove(&pool).unwrap_or_default()
    }

    fn backend(&self) -> &'static str {
        "process"
    }
}

impl Drop for ProcessExecutor {
    fn drop(&mut self) {
        // WorkerChild::drop kills each remaining child.
        self.workers.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_carries_id_kind_and_trailer() {
        let meta =
            pack(&Value::map([("payload", Payload::Sleep(0.25).to_value())]), 0).unwrap();
        let input = pack(&Value::Int(42), 0).unwrap();
        let mut buf = Vec::new();
        write_frames(&mut buf, &[(7, KIND_REQUEST, meta.as_slice(), input.as_slice())])
            .unwrap();
        let mut r = Cursor::new(buf);
        let (id, kind, body) = read_frame(&mut r).unwrap().expect("one frame");
        assert_eq!((id, kind), (7, KIND_REQUEST));
        // The meta ‖ trailer concatenation is exactly the trailer
        // codec's layout: one zero-copy split recovers both halves.
        let (back, trailer) = unpack_with_trailer(&body).unwrap();
        let p = Payload::from_value(back.get("payload").unwrap()).unwrap();
        assert_eq!(p, Payload::Sleep(0.25));
        assert_eq!(unpack(&trailer).unwrap(), Value::Int(42));
        assert!(trailer.same_allocation(&body), "trailer is a view, not a copy");
        // Clean EOF after the frame.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn batched_frames_arrive_in_order_and_intact() {
        let metas: Vec<Buffer> =
            (0..3).map(|i| pack(&Value::Int(i), 0).unwrap()).collect();
        let frames: Vec<FrameOut<'_>> = metas
            .iter()
            .enumerate()
            .map(|(i, m)| (10 + i as u64, KIND_REQUEST, m.as_slice(), &[] as &[u8]))
            .collect();
        let mut buf = Vec::new();
        write_frames(&mut buf, &frames).unwrap();
        let mut r = Cursor::new(buf);
        for i in 0..3u64 {
            let (id, kind, body) = read_frame(&mut r).unwrap().expect("frame");
            assert_eq!((id, kind), (10 + i, KIND_REQUEST));
            assert_eq!(unpack(&body).unwrap(), Value::Int(i as i64));
        }
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn frame_rejects_truncation_oversize_and_short_claims() {
        // Truncated length prefix.
        let mut r = Cursor::new(vec![1u8, 0]);
        assert!(read_frame(&mut r).is_err());
        // Truncated body.
        let body = pack(&Value::Int(7), 0).unwrap();
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, KIND_REPLY, body.as_slice()).unwrap();
        buf.truncate(buf.len() - 1);
        let mut r = Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
        // Oversized claim.
        let mut r = Cursor::new(((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
        // Too short to carry a frame id and kind.
        let mut short = 5u32.to_le_bytes().to_vec();
        short.extend_from_slice(&[0u8; 5]);
        let mut r = Cursor::new(short);
        assert!(read_frame(&mut r).is_err());
    }

    #[cfg(unix)]
    #[test]
    fn status_error_types_exits_and_signals() {
        use std::os::unix::process::ExitStatusExt;
        // Raw wait status: exit code in bits 8..16, signal in bits 0..7.
        let exited = std::process::ExitStatus::from_raw(3 << 8);
        assert_eq!(status_error(exited).kind(), "WorkerExited");
        let signaled = std::process::ExitStatus::from_raw(9);
        match status_error(signaled) {
            Error::WorkerSignaled { signal } => assert_eq!(signal, 9),
            e => panic!("expected WorkerSignaled, got {e}"),
        }
    }
}
