//! The artifact shape contract — the Rust mirror of
//! `python/compile/model.py`'s constants — and the manifest reader.

use std::path::Path;

use crate::common::error::{Error, Result};
use crate::serialize::{json, Value};

/// Element type of a tensor argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElemType {
    F32,
    I32,
}

/// Shape/dtype signature of one artifact parameter.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: &'static str,
    pub dims: &'static [i64],
    pub ty: ElemType,
}

impl ParamSpec {
    pub fn elem_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }
}

/// Compile-time contract for one AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: &'static str,
    pub file: &'static str,
    pub params: &'static [ParamSpec],
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

/// The three science payloads (see model.py's ARTIFACTS and docstring).
pub const ARTIFACT_SPECS: [ArtifactSpec; 3] = [
    ArtifactSpec {
        name: "surrogate",
        file: "surrogate.hlo.txt",
        params: &[
            ParamSpec { name: "x", dims: &[128, 256], ty: ElemType::F32 },
            ParamSpec { name: "w1", dims: &[256, 512], ty: ElemType::F32 },
            ParamSpec { name: "b1", dims: &[512], ty: ElemType::F32 },
            ParamSpec { name: "w2", dims: &[512, 128], ty: ElemType::F32 },
            ParamSpec { name: "b2", dims: &[128], ty: ElemType::F32 },
        ],
        outputs: 1,
    },
    ArtifactSpec {
        name: "stills",
        file: "stills.hlo.txt",
        params: &[
            ParamSpec { name: "img", dims: &[512, 512], ty: ElemType::F32 },
            ParamSpec { name: "thresh", dims: &[1], ty: ElemType::F32 },
        ],
        outputs: 3,
    },
    ArtifactSpec {
        name: "reducer",
        file: "reducer.hlo.txt",
        params: &[
            ParamSpec { name: "ids", dims: &[4096], ty: ElemType::I32 },
            ParamSpec { name: "vals", dims: &[4096], ty: ElemType::F32 },
        ],
        outputs: 1,
    },
];

/// Look up a spec by name.
pub fn spec(name: &str) -> Result<&'static ArtifactSpec> {
    ARTIFACT_SPECS
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| Error::NotFound(format!("artifact spec {name}")))
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<(String, String)>, // (name, file)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::Runtime(format!("manifest.json: {e}")))?;
        let v = json::from_str(&text)?;
        let m = match &v {
            Value::Map(m) => m,
            _ => return Err(Error::Runtime("manifest.json: not an object".into())),
        };
        let mut entries = Vec::new();
        for (name, entry) in m {
            let file = entry
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| Error::Runtime(format!("manifest entry {name}: no file")))?;
            entries.push((name.clone(), file.to_string()));
        }
        entries.sort();
        Ok(Manifest { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_consistent() {
        assert_eq!(ARTIFACT_SPECS.len(), 3);
        for s in &ARTIFACT_SPECS {
            assert!(!s.params.is_empty());
            assert!(s.outputs >= 1);
            assert!(s.file.ends_with(".hlo.txt"));
            for p in s.params {
                assert!(p.elem_count() > 0);
            }
        }
        // Surrogate contract mirrors model.py: 128x256 @ 256x512 @ 512x128.
        let sur = spec("surrogate").unwrap();
        assert_eq!(sur.params[0].dims, &[128, 256]);
        assert_eq!(sur.params[1].dims, &[256, 512]);
        assert!(spec("nope").is_err());
    }

    #[test]
    fn manifest_parses_generated_file() {
        // Uses the real artifacts/ when present (built by `make artifacts`).
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let names: Vec<&str> = m.entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["reducer", "stills", "surrogate"]);
    }
}
