//! Worker-side payload execution: turns a [`Payload`] + input [`Value`]
//! into an output [`Value`]. This is what actually runs inside a worker
//! (optionally inside a "container" — a warm slot with a start cost).

use std::sync::Arc;
use std::time::Instant;

use crate::common::error::{Error, Result};
use crate::common::task::Payload;
use crate::data::DataChannel;
use crate::runtime::engine::{PjrtRuntime, TensorArg};
use crate::runtime::spec;
use crate::serialize::Value;

/// Executes payloads; shared by every worker on an endpoint.
pub struct PayloadExecutor {
    runtime: Option<Arc<PjrtRuntime>>,
    channel: Option<Arc<dyn DataChannel>>,
}

impl PayloadExecutor {
    pub fn new(
        runtime: Option<Arc<PjrtRuntime>>,
        channel: Option<Arc<dyn DataChannel>>,
    ) -> Self {
        PayloadExecutor { runtime, channel }
    }

    /// A bare executor for microbenchmark payloads only.
    pub fn bare() -> Self {
        Self::new(None, None)
    }

    /// Execute `payload` with `input`; returns (output, exec_seconds).
    pub fn execute(&self, payload: &Payload, input: &Value) -> Result<(Value, f64)> {
        let t0 = Instant::now();
        let out = self.run(payload, input)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    }

    fn run(&self, payload: &Payload, input: &Value) -> Result<Value> {
        match payload {
            Payload::Noop => Ok(Value::Null),
            Payload::Echo => Ok(input.clone()),
            Payload::Sleep(s) => {
                std::thread::sleep(std::time::Duration::from_secs_f64(*s));
                Ok(Value::Null)
            }
            Payload::Stress(s) => {
                // Busy-spin one core at 100% (§7.2's "stress" function).
                let deadline = Instant::now() + std::time::Duration::from_secs_f64(*s);
                let mut x = 0x9E3779B97F4A7C15u64;
                while Instant::now() < deadline {
                    for _ in 0..4096 {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    }
                    std::hint::black_box(x);
                }
                Ok(Value::Null)
            }
            Payload::Simulated { .. } => Err(Error::InvalidArgument(
                "simulated payloads only run in the discrete-event simulator".into(),
            )),
            // Fault-injection payloads are meant to kill a worker
            // *process*. Running in-process, we surface the same typed
            // error the process executor would have produced instead of
            // taking the host down with us.
            Payload::Exit(code) => Err(Error::WorkerExited { code: *code }),
            Payload::Abort => Err(Error::WorkerSignaled { signal: 6 }),
            Payload::DataOp => {
                let ch = self
                    .channel
                    .as_ref()
                    .ok_or_else(|| Error::Data("no data channel attached".into()))?;
                // input: {op: "put"|"get"|"delete", key, data?}
                let op = input
                    .get("op")
                    .and_then(Value::as_str)
                    .ok_or_else(|| Error::InvalidArgument("dataop: missing op".into()))?;
                let key = input
                    .get("key")
                    .and_then(Value::as_str)
                    .ok_or_else(|| Error::InvalidArgument("dataop: missing key".into()))?;
                match op {
                    "put" => {
                        // Accept owned Bytes or a zero-copy Blob view.
                        let data = match input.get("data").and_then(Value::as_bytes) {
                            Some(b) => b,
                            None => {
                                return Err(Error::InvalidArgument(
                                    "dataop put: missing bytes data".into(),
                                ))
                            }
                        };
                        ch.put(key, data)?;
                        Ok(Value::Null)
                    }
                    "get" => Ok(Value::Bytes(ch.get(key)?)),
                    "delete" => Ok(Value::Bool(ch.delete(key)?)),
                    o => Err(Error::InvalidArgument(format!("dataop: bad op {o}"))),
                }
            }
            Payload::Artifact(name) => {
                let rt = self
                    .runtime
                    .as_ref()
                    .ok_or_else(|| Error::Runtime("no PJRT runtime attached".into()))?;
                let s = spec(name)?;
                // input: map from param name -> F32s/I32s.
                let mut args = Vec::with_capacity(s.params.len());
                for p in s.params {
                    let v = input.get(p.name).ok_or_else(|| {
                        Error::InvalidArgument(format!("artifact {name}: missing arg {}", p.name))
                    })?;
                    let arg = match v {
                        Value::F32s(f) => TensorArg::F32(f.clone()),
                        Value::I32s(i) => TensorArg::I32(i.clone()),
                        _ => {
                            return Err(Error::InvalidArgument(format!(
                                "artifact {name}: arg {} must be a tensor",
                                p.name
                            )))
                        }
                    };
                    args.push(arg);
                }
                let outputs = rt.execute(name, &args)?;
                Ok(Value::List(outputs.into_iter().map(Value::F32s).collect()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::InMemoryChannel;

    #[test]
    fn noop_and_echo() {
        let ex = PayloadExecutor::bare();
        let (out, t) = ex.execute(&Payload::Noop, &Value::Null).unwrap();
        assert_eq!(out, Value::Null);
        assert!(t < 0.1);
        let input = Value::map([("x", Value::Int(3))]);
        let (out, _) = ex.execute(&Payload::Echo, &input).unwrap();
        assert_eq!(out, input);
    }

    #[test]
    fn sleep_takes_time() {
        let ex = PayloadExecutor::bare();
        let (_, t) = ex.execute(&Payload::Sleep(0.05), &Value::Null).unwrap();
        assert!(t >= 0.05);
    }

    #[test]
    fn stress_spins() {
        let ex = PayloadExecutor::bare();
        let (_, t) = ex.execute(&Payload::Stress(0.05), &Value::Null).unwrap();
        assert!(t >= 0.05 && t < 1.0);
    }

    #[test]
    fn dataop_roundtrip() {
        let ex = PayloadExecutor::new(None, Some(Arc::new(InMemoryChannel::default())));
        let put = Value::map([
            ("op", Value::Str("put".into())),
            ("key", Value::Str("k1".into())),
            ("data", Value::Bytes(vec![1, 2, 3])),
        ]);
        ex.execute(&Payload::DataOp, &put).unwrap();
        let get = Value::map([
            ("op", Value::Str("get".into())),
            ("key", Value::Str("k1".into())),
        ]);
        let (out, _) = ex.execute(&Payload::DataOp, &get).unwrap();
        assert_eq!(out, Value::Bytes(vec![1, 2, 3]));
    }

    #[test]
    fn missing_capabilities_error() {
        let ex = PayloadExecutor::bare();
        assert!(ex.execute(&Payload::DataOp, &Value::Null).is_err());
        assert!(ex.execute(&Payload::Artifact("surrogate".into()), &Value::Null).is_err());
        assert!(ex
            .execute(&Payload::Simulated { duration_s: 1.0 }, &Value::Null)
            .is_err());
    }

    #[test]
    fn artifact_via_executor() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Arc::new(PjrtRuntime::load_dir(&dir).unwrap());
        let ex = PayloadExecutor::new(Some(rt), None);
        let ids: Vec<i32> = (0..4096).map(|i| (i % 2) as i32).collect();
        let input = Value::map([
            ("ids", Value::I32s(ids)),
            ("vals", Value::F32s(vec![0.5; 4096])),
        ]);
        let (out, _) = ex.execute(&Payload::Artifact("reducer".into()), &input).unwrap();
        match out {
            Value::List(parts) => match &parts[0] {
                Value::F32s(sums) => {
                    assert_eq!(sums.len(), 256);
                    assert!((sums[0] - 1024.0).abs() < 1e-3);
                    assert!((sums[1] - 1024.0).abs() < 1e-3);
                    assert!(sums[2].abs() < 1e-6);
                }
                _ => panic!("expected f32s"),
            },
            _ => panic!("expected list"),
        }
    }
}
