//! Shared primitives: ids, errors, task model, virtual time, config,
//! wakeup plumbing.

pub mod config;
pub mod error;
pub mod ids;
pub mod rng;
pub mod sync;
pub mod task;
pub mod time;
