//! Shared primitives: ids, errors, task model, virtual time, config.

pub mod config;
pub mod error;
pub mod ids;
pub mod rng;
pub mod task;
pub mod time;
