//! UUIDs for functions, endpoints, tasks, users, containers.
//!
//! funcX assigns a universally unique identifier to every registered
//! entity (§3). We use a 128-bit random id with the RFC-4122 v4 layout,
//! generated from a per-call entropy-seeded RNG (or deterministically in
//! the simulator via [`Uuid::from_bits`]).

use std::fmt;
use std::str::FromStr;

use crate::common::rng::Rng;
use crate::serialize::{Value, Wire};

/// A 128-bit universally unique identifier (v4 layout).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Uuid(pub u128);

impl Uuid {
    /// Generate a fresh random v4 UUID.
    pub fn new() -> Self {
        Self::from_bits(Rng::from_entropy().next_u128())
    }

    /// Deterministic construction from raw bits, normalised to the v4
    /// version/variant layout (used by the simulator for reproducibility).
    pub fn from_bits(bits: u128) -> Self {
        let mut b = bits;
        b = (b & !(0xf000 << 64)) | (0x4000 << 64); // version 4
        b = (b & !(0xc000 << 48)) | (0x8000 << 48); // RFC variant
        Uuid(b)
    }

    /// The nil UUID (all zeros) — used as a sentinel.
    pub const NIL: Uuid = Uuid(0);

    pub fn is_nil(&self) -> bool {
        self.0 == 0
    }
}

impl Default for Uuid {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Display for Uuid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0;
        write!(
            f,
            "{:08x}-{:04x}-{:04x}-{:04x}-{:012x}",
            (b >> 96) as u32,
            (b >> 80) as u16,
            (b >> 64) as u16,
            (b >> 48) as u16,
            b as u64 & 0xffff_ffff_ffff
        )
    }
}

impl fmt::Debug for Uuid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl FromStr for Uuid {
    type Err = crate::common::error::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let hex: String = s.chars().filter(|c| *c != '-').collect();
        if hex.len() != 32 {
            return Err(crate::Error::InvalidArgument(format!("bad uuid: {s}")));
        }
        let bits = u128::from_str_radix(&hex, 16)
            .map_err(|_| crate::Error::InvalidArgument(format!("bad uuid: {s}")))?;
        Ok(Uuid(bits))
    }
}

impl Wire for Uuid {
    fn to_value(&self) -> Value {
        Value::Str(self.to_string())
    }

    fn from_value(v: &Value) -> crate::Result<Self> {
        v.as_str()
            .ok_or_else(|| crate::Error::Serialization("uuid: expected string".into()))?
            .parse()
    }
}

/// Typed id wrappers so a task id cannot be passed where an endpoint id
/// is expected.
macro_rules! typed_id {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
        pub struct $name(pub Uuid);

        impl $name {
            pub fn new() -> Self {
                Self(Uuid::new())
            }
            pub fn from_bits(bits: u128) -> Self {
                Self(Uuid::from_bits(bits))
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new()
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{}", self.0)
            }
        }

        impl Wire for $name {
            fn to_value(&self) -> Value {
                self.0.to_value()
            }
            fn from_value(v: &Value) -> crate::Result<Self> {
                Ok(Self(Uuid::from_value(v)?))
            }
        }
    };
}

typed_id!(
    /// Id of a registered function.
    FunctionId
);
typed_id!(
    /// Id of a registered endpoint.
    EndpointId
);
typed_id!(
    /// Id of a task (one invocation of a function; paper §3).
    TaskId
);
typed_id!(
    /// Id of a user identity.
    UserId
);
typed_id!(
    /// Id of a registered container image.
    ContainerId
);
typed_id!(
    /// Id of a manager (one per provisioned node).
    ManagerId
);
typed_id!(
    /// Id of a worker (one per container slot).
    WorkerId
);
typed_id!(
    /// Id of an inter-endpoint transfer task (Globus-like; §5.1).
    TransferId
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uuid_display_roundtrip() {
        for _ in 0..64 {
            let u = Uuid::new();
            let s = u.to_string();
            assert_eq!(s.len(), 36);
            assert_eq!(s.parse::<Uuid>().unwrap(), u);
        }
    }

    #[test]
    fn uuid_v4_layout() {
        let u = Uuid::from_bits(u128::MAX);
        let s = u.to_string();
        assert_eq!(&s[14..15], "4", "version nibble");
        assert!(matches!(&s[19..20], "8" | "9" | "a" | "b"), "variant nibble");
    }

    #[test]
    fn uuid_uniqueness_smoke() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            assert!(seen.insert(Uuid::new()));
        }
    }

    #[test]
    fn nil_uuid() {
        assert!(Uuid::NIL.is_nil());
        assert!(!Uuid::new().is_nil());
    }

    #[test]
    fn typed_ids_distinct_types() {
        let t = TaskId::new();
        let e = EndpointId::new();
        assert_ne!(t.0, e.0);
    }

    #[test]
    fn bad_uuid_parse() {
        assert!("nope".parse::<Uuid>().is_err());
        assert!("zz".repeat(16).parse::<Uuid>().is_err());
    }

    #[test]
    fn wire_roundtrip() {
        let t = TaskId::new();
        let v = t.to_value();
        assert_eq!(TaskId::from_value(&v).unwrap(), t);
        assert!(TaskId::from_value(&Value::Int(3)).is_err());
    }
}
