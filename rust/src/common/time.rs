//! Time abstraction shared by the live engine and the simulator.
//!
//! All policy code takes a [`Clock`] so that the discrete-event simulator
//! can drive the *same* routing/warming/provisioning logic under virtual
//! time while the live engine uses wall-clock time.

use std::sync::{Arc, RwLock};
use std::time::Instant;

/// Seconds since an arbitrary epoch. f64 gives µs resolution over any
/// experiment horizon we use and keeps the simulator arithmetic simple.
pub type Time = f64;

/// A time source.
pub trait Clock: Send + Sync {
    /// Current time in seconds.
    fn now(&self) -> Time;
}

/// Wall-clock time, anchored at construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Time {
        self.start.elapsed().as_secs_f64()
    }
}

/// Shared virtual clock advanced by the simulator's event loop.
#[derive(Clone, Default)]
pub struct VirtualClock {
    now: Arc<RwLock<Time>>,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance to `t`. Panics if time would run backwards (event-order
    /// invariant; property-tested in `sim`).
    pub fn advance_to(&self, t: Time) {
        let mut now = self.now.write().unwrap();
        assert!(t >= *now, "virtual time ran backwards: {t} < {}", *now);
        *now = t;
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Time {
        *self.now.read().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert_eq!(c.now(), 1.5);
        c.advance_to(1.5); // equal is fine
        assert_eq!(c.now(), 1.5);
    }

    #[test]
    #[should_panic(expected = "ran backwards")]
    fn virtual_clock_rejects_backwards() {
        let c = VirtualClock::new();
        c.advance_to(2.0);
        c.advance_to(1.0);
    }
}
