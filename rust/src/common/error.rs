//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the funcX service, endpoints, and substrates.
#[derive(Debug)]
pub enum Error {
    /// Caller error: malformed id, bad argument, etc.
    InvalidArgument(String),
    /// Entity (function/endpoint/task/user) not found.
    NotFound(String),
    /// Authentication failed (missing/expired token).
    Unauthenticated(String),
    /// Authenticated but not allowed (scope/ownership; §4.7).
    Forbidden(String),
    /// Payload exceeds the service data limit (10 MB; §5.1).
    PayloadTooLarge { size: usize, limit: usize },
    /// Serialization facade exhausted all strategies (§4.5).
    Serialization(String),
    /// Endpoint is not connected / lost (heartbeat timeout).
    EndpointDisconnected(String),
    /// Task failed during execution on a worker.
    TaskFailed(String),
    /// A queue/channel was closed or a component shut down.
    Shutdown(String),
    /// The provider (scheduler/cloud) rejected a request.
    Provider(String),
    /// Data-plane (store/transfer) failure.
    Data(String),
    /// The store shed the write to bound memory growth (spill
    /// backpressure): the spool is persistently failing and the memory
    /// tier is already past its shed limit, so accepting the frame
    /// would grow the tier unboundedly. Retryable once the spool
    /// recovers or occupancy drains.
    Overloaded(String),
    /// A fetched frame failed its [`crate::datastore::DataRef`]
    /// size/checksum verification (truncation or bit corruption — the
    /// bytes exist but cannot be trusted, unlike [`Error::NotFound`]).
    Corrupt(String),
    /// PJRT runtime failure (artifact load/compile/execute).
    Runtime(String),
    /// Operation timed out.
    Timeout(String),
    /// A worker child process exited with a non-zero status while a
    /// task was in flight (process executor backend).
    WorkerExited { code: i32 },
    /// A worker child process was killed by a signal (crash/OOM/abort)
    /// while a task was in flight.
    WorkerSignaled { signal: i32 },
    /// I/O error wrapper.
    Io(std::io::Error),
}

impl Error {
    /// The variant name — the stable vocabulary used by typed trace
    /// terminals ([`crate::metrics::TraceKind::TaskFailed`] and
    /// `ResolveFailed` carry exactly these strings).
    pub fn kind(&self) -> &'static str {
        match self {
            Error::InvalidArgument(_) => "InvalidArgument",
            Error::NotFound(_) => "NotFound",
            Error::Unauthenticated(_) => "Unauthenticated",
            Error::Forbidden(_) => "Forbidden",
            Error::PayloadTooLarge { .. } => "PayloadTooLarge",
            Error::Serialization(_) => "Serialization",
            Error::EndpointDisconnected(_) => "EndpointDisconnected",
            Error::TaskFailed(_) => "TaskFailed",
            Error::Shutdown(_) => "Shutdown",
            Error::Provider(_) => "Provider",
            Error::Data(_) => "Data",
            Error::Overloaded(_) => "Overloaded",
            Error::Corrupt(_) => "Corrupt",
            Error::Runtime(_) => "Runtime",
            Error::Timeout(_) => "Timeout",
            Error::WorkerExited { .. } => "WorkerExited",
            Error::WorkerSignaled { .. } => "WorkerSignaled",
            Error::Io(_) => "Io",
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::NotFound(m) => write!(f, "not found: {m}"),
            Error::Unauthenticated(m) => write!(f, "unauthenticated: {m}"),
            Error::Forbidden(m) => write!(f, "forbidden: {m}"),
            Error::PayloadTooLarge { size, limit } => {
                write!(f, "payload of {size} bytes exceeds service limit of {limit}")
            }
            Error::Serialization(m) => write!(f, "serialization: {m}"),
            Error::EndpointDisconnected(m) => write!(f, "endpoint disconnected: {m}"),
            Error::TaskFailed(m) => write!(f, "task failed: {m}"),
            Error::Shutdown(m) => write!(f, "shutdown: {m}"),
            Error::Provider(m) => write!(f, "provider: {m}"),
            Error::Data(m) => write!(f, "data: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::Corrupt(m) => write!(f, "corrupt: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::WorkerExited { code } => {
                write!(f, "worker process exited with status {code}")
            }
            Error::WorkerSignaled { signal } => {
                write!(f, "worker process killed by signal {signal}")
            }
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_covers_variants() {
        let cases: Vec<Error> = vec![
            Error::InvalidArgument("x".into()),
            Error::NotFound("x".into()),
            Error::Unauthenticated("x".into()),
            Error::Forbidden("x".into()),
            Error::PayloadTooLarge { size: 11, limit: 10 },
            Error::Serialization("x".into()),
            Error::EndpointDisconnected("x".into()),
            Error::TaskFailed("x".into()),
            Error::Shutdown("x".into()),
            Error::Provider("x".into()),
            Error::Data("x".into()),
            Error::Overloaded("x".into()),
            Error::Corrupt("x".into()),
            Error::Runtime("x".into()),
            Error::Timeout("x".into()),
            Error::WorkerExited { code: 3 },
            Error::WorkerSignaled { signal: 9 },
            Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x")),
        ];
        for c in cases {
            assert!(!c.to_string().is_empty());
        }
    }
}
