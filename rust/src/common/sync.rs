//! Wakeup plumbing for the dispatch hot path.
//!
//! [`Notify`] is an epoch-counting condvar: producers call [`Notify::notify`]
//! after publishing work; consumers snapshot the epoch with
//! [`Notify::epoch`] *before* checking for work and then block in
//! [`Notify::wait_newer`] only if the epoch is unchanged. Because the
//! epoch is read before the work check, a notification that races with
//! the check is never lost — the wait returns immediately.
//!
//! One `Notify` can be attached to several sources (the forwarder waits
//! on its link *and* its task-queue watch through a single handle), which
//! is what lets the control loops block instead of sleep-polling across
//! heterogeneous wake sources (mpsc channels, KV pushes, result stores).
//!
//! Each latch keeps two relaxed counters — signals published
//! ([`Notify::notify_count`]) and waits that actually observed a newer
//! epoch ([`Notify::wakeup_count`]) — so benches can measure wakeups per
//! unit of work (e.g. per consumed frame on a hot watched key) before
//! investing in coalescing. The counters are telemetry only: nothing in
//! the wait protocol reads them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// An epoch-counting wakeup latch (see module docs for the protocol).
#[derive(Default)]
pub struct Notify {
    epoch: Mutex<u64>,
    cv: Condvar,
    /// Signals published via [`Notify::notify`].
    notifies: AtomicU64,
    /// Waits that returned having observed an epoch newer than `seen`
    /// (immediately-stale waits included; timeouts excluded).
    wakeups: AtomicU64,
}

impl Notify {
    pub fn new() -> Self {
        Notify::default()
    }

    /// Current epoch. Snapshot this *before* checking for work.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().expect("notify poisoned")
    }

    /// Publish a wakeup: bump the epoch and wake every waiter.
    pub fn notify(&self) {
        let mut g = self.epoch.lock().expect("notify poisoned");
        *g = g.wrapping_add(1);
        drop(g);
        self.notifies.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_all();
    }

    /// Block until the epoch differs from `seen` or `timeout` elapses.
    /// Returns the epoch observed on wakeup.
    pub fn wait_newer(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.epoch.lock().expect("notify poisoned");
        while *g == seen {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(g, remaining).expect("notify poisoned");
            g = guard;
        }
        let out = *g;
        drop(g);
        if out != seen {
            self.wakeups.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// How many signals have been published on this latch.
    pub fn notify_count(&self) -> u64 {
        self.notifies.load(Ordering::Relaxed)
    }

    /// How many waits returned because the epoch moved (not timeouts).
    pub fn wakeup_count(&self) -> u64 {
        self.wakeups.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn wait_returns_on_notify() {
        let n = Arc::new(Notify::new());
        let n2 = n.clone();
        let seen = n.epoch();
        let h = thread::spawn(move || n2.wait_newer(seen, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        n.notify();
        assert_ne!(h.join().unwrap(), seen);
    }

    #[test]
    fn stale_epoch_returns_immediately() {
        let n = Notify::new();
        let seen = n.epoch();
        n.notify(); // epoch moves past `seen` before the wait starts
        let t0 = Instant::now();
        n.wait_newer(seen, Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_millis(500), "missed-wakeup race");
    }

    #[test]
    fn wait_times_out() {
        let n = Notify::new();
        let seen = n.epoch();
        let t0 = Instant::now();
        assert_eq!(n.wait_newer(seen, Duration::from_millis(30)), seen);
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn counters_track_signals_and_wakeups() {
        let n = Notify::new();
        assert_eq!((n.notify_count(), n.wakeup_count()), (0, 0));
        let seen = n.epoch();
        n.notify();
        n.notify();
        assert_eq!(n.notify_count(), 2);
        // A wait observing a newer epoch counts as one wakeup…
        n.wait_newer(seen, Duration::from_secs(1));
        assert_eq!(n.wakeup_count(), 1);
        // …a timed-out wait does not.
        let seen = n.epoch();
        n.wait_newer(seen, Duration::from_millis(5));
        assert_eq!(n.wakeup_count(), 1);
    }
}
