//! Wakeup plumbing for the dispatch hot path.
//!
//! [`Notify`] is an epoch-counting condvar: producers call [`Notify::notify`]
//! after publishing work; consumers snapshot the epoch with
//! [`Notify::epoch`] *before* checking for work and then block in
//! [`Notify::wait_newer`] only if the epoch is unchanged. Because the
//! epoch is read before the work check, a notification that races with
//! the check is never lost — the wait returns immediately.
//!
//! One `Notify` can be attached to several sources (the forwarder waits
//! on its link *and* its task-queue watch through a single handle), which
//! is what lets the control loops block instead of sleep-polling across
//! heterogeneous wake sources (mpsc channels, KV pushes, result stores).

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// An epoch-counting wakeup latch (see module docs for the protocol).
#[derive(Default)]
pub struct Notify {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Notify {
    pub fn new() -> Self {
        Notify { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    /// Current epoch. Snapshot this *before* checking for work.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().expect("notify poisoned")
    }

    /// Publish a wakeup: bump the epoch and wake every waiter.
    pub fn notify(&self) {
        let mut g = self.epoch.lock().expect("notify poisoned");
        *g = g.wrapping_add(1);
        drop(g);
        self.cv.notify_all();
    }

    /// Block until the epoch differs from `seen` or `timeout` elapses.
    /// Returns the epoch observed on wakeup.
    pub fn wait_newer(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.epoch.lock().expect("notify poisoned");
        while *g == seen {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(g, remaining).expect("notify poisoned");
            g = guard;
        }
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn wait_returns_on_notify() {
        let n = Arc::new(Notify::new());
        let n2 = n.clone();
        let seen = n.epoch();
        let h = thread::spawn(move || n2.wait_newer(seen, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        n.notify();
        assert_ne!(h.join().unwrap(), seen);
    }

    #[test]
    fn stale_epoch_returns_immediately() {
        let n = Notify::new();
        let seen = n.epoch();
        n.notify(); // epoch moves past `seen` before the wait starts
        let t0 = Instant::now();
        n.wait_newer(seen, Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_millis(500), "missed-wakeup race");
    }

    #[test]
    fn wait_times_out() {
        let n = Notify::new();
        let seen = n.epoch();
        let t0 = Instant::now();
        assert_eq!(n.wait_newer(seen, Duration::from_millis(30)), seen);
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }
}
