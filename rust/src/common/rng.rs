//! Deterministic RNG + distributions (in-tree: the build is offline).
//!
//! SplitMix64 core with Box–Muller normals and the log-normal sampler the
//! container cost models use. Seedable for reproducible experiments; a
//! process-global entropy source seeds fresh UUIDs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// SplitMix64 — tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Seed from process entropy (time ^ counter), for id generation.
    pub fn from_entropy() -> Self {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap().subsec_nanos() as u64;
        let pid = std::process::id() as u64;
        let c = COUNTER.fetch_add(1, Ordering::Relaxed);
        let tid = {
            // hash the thread id via its Debug formatting
            let s = format!("{:?}", std::thread::current().id());
            s.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64))
        };
        Rng::new(t ^ (pid << 32) ^ c.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ tid.rotate_left(17))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u128(&mut self) -> u128 {
        ((self.next_u64() as u128) << 64) | self.next_u64() as u128
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free for our scales (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the *resulting distribution's* mean/sigma expressed
    /// via underlying mu/sigma (natural-log parameters).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (inter-arrival sampling).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> Option<&'a T> {
        if v.is_empty() {
            None
        } else {
            Some(&v[self.below(v.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn determinism() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn entropy_rngs_differ() {
        let a = Rng::from_entropy().next_u64();
        // not asserting inequality of two entropy draws strictly — but the
        // state mixing should essentially never collide
        let b = Rng::from_entropy().next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

fn _next_u64_static() {}
