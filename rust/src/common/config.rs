//! Configuration for the service, endpoints, and experiments.
//!
//! The defaults encode the paper's stated parameters (heartbeat 30 s,
//! 10 MB payload cap, 10-minute container idle timeout, 2-minute resource
//! idle timeout, prefetch batching, …) so a default deployment behaves
//! like the published system.

/// Cloud-service configuration (§4.1).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Max serialized input size carried *inline* through the service
    /// queues (paper §5.1: 10 MB). With [`ServiceConfig::ref_dispatch`]
    /// enabled, larger inputs are offloaded to the data fabric and the
    /// task carries a [`crate::datastore::DataRef`] instead; disabled,
    /// they are rejected as in the original system.
    pub max_payload_bytes: usize,
    /// Dispatch oversized inputs by reference through the tiered
    /// payload store (§5 data layer) instead of rejecting them.
    pub ref_dispatch: bool,
    /// Memory high-watermark of the service-side tiered payload store;
    /// offloaded inputs beyond this spill to the disk tier.
    pub store_mem_watermark_bytes: usize,
    /// Forwarder heartbeat period (paper §4.1: 30 s default).
    pub heartbeat_period_s: f64,
    /// Heartbeats missed before an agent is declared lost.
    pub heartbeat_misses_allowed: u32,
    /// Retrieved results are purged from the store after this long
    /// (paper §4.1 "periodically purge results").
    pub result_ttl_s: f64,
    /// Max times a task is re-dispatched after agent loss before being
    /// marked [`crate::common::task::TaskState::Abandoned`].
    pub max_redispatch: u32,
    /// Copies of each by-ref result frame pushed to *other*
    /// registry-advertised endpoint stores when the result is stored
    /// (survivability: the ref then resolves via a replica after its
    /// owner dies — see `docs/data-fabric.md`). `0` disables
    /// replication; the effective count is capped by how many peer
    /// stores are advertised.
    pub replication_factor: usize,
    /// Shards the service plane is split into (§4.1 "the funcX service
    /// is designed to scale horizontally"): each shard owns its own KV
    /// store, payload store, result latch, and forwarder loops, with
    /// tasks/endpoints placed by the consistent-hash
    /// [`crate::service::ShardMap`]. 1 reproduces the unsharded
    /// service exactly.
    pub service_shards: usize,
    /// Per-component ring capacity of the task flight recorder
    /// ([`crate::metrics::FlightRecorder`]): each component (shard,
    /// endpoint, fabric, store) keeps at most this many trace events,
    /// oldest dropped. `0` disables recording entirely (the bench
    /// baseline for measuring observability overhead).
    pub trace_ring_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_payload_bytes: 10 * 1024 * 1024,
            ref_dispatch: true,
            store_mem_watermark_bytes: 256 * 1024 * 1024,
            heartbeat_period_s: 30.0,
            heartbeat_misses_allowed: 2,
            result_ttl_s: 3600.0,
            max_redispatch: 3,
            replication_factor: 0,
            service_shards: 1,
            trace_ring_capacity: crate::metrics::trace::DEFAULT_RING_CAPACITY,
        }
    }
}

/// Endpoint (funcX agent) configuration (§4.3, §6).
#[derive(Clone, Debug)]
pub struct EndpointConfig {
    /// Worker slots per node (containers per manager).
    pub workers_per_node: usize,
    /// Min/max nodes the elastic strategy may hold (§6.3).
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// Container idle timeout before tear-down (§6.1: e.g. 10 min).
    pub container_idle_timeout_s: f64,
    /// Node idle timeout before release (§6.3: 2 min default).
    pub node_idle_timeout_s: f64,
    /// Strategy monitoring period (§6.3: e.g. every second).
    pub strategy_period_s: f64,
    /// Pending tasks per additional node requested (scaling
    /// aggressiveness; §6.3 "one more resource per ten waiting").
    pub tasks_per_node_scaling: usize,
    /// Manager prefetch depth beyond current idle capacity (§6.2).
    pub prefetch: usize,
    /// Internal batching enabled (§4.6): managers request tasks in bulk.
    pub internal_batching: bool,
    /// Manager-side result buffering (§4.6 on the return path): the
    /// *floor* of the adaptive flush threshold. Workers append completed
    /// results to a per-manager buffer whose size threshold adapts to an
    /// EWMA of the completion rate, never dropping below this value (see
    /// [`crate::batching::ResultBuffer`]). 1 disables buffering.
    pub result_batch: usize,
    /// Max serialized *output* size carried inline through the result
    /// queues (the return-path mirror of
    /// [`ServiceConfig::max_payload_bytes`]). A successful result larger
    /// than this is `put()` into the endpoint's data-fabric store and
    /// the [`crate::common::task::TaskResult`] carries a
    /// [`crate::datastore::DataRef`] (`"rref"` trailer-meta field)
    /// instead of the bytes; `get_result` resolves it through the
    /// service-side fabric ladder. Endpoints without a fabric attached
    /// always return results inline.
    pub max_result_bytes: usize,
    /// Per-task wall-clock budget enforced by the process executor
    /// backend; an overrunning task gets its worker child killed and
    /// fails with [`crate::common::error::Error::Timeout`].
    pub task_timeout_s: f64,
    /// In-flight task frames one worker may pipeline into a single
    /// container slot (the frame-multiplexed v2 child protocol; see
    /// `docs/containers.md`). A worker claims up to this many queued
    /// same-type tasks per dispatch — each holding one lease on the
    /// busy slot — and the process backend keeps that many request
    /// frames outstanding per child, completing replies out of order
    /// by frame id. 1 restores strict one-task-per-slot request/reply.
    pub worker_pipeline_depth: usize,
    /// Predictive warm-pool sizing (see `docs/containers.md`): the
    /// agent keeps a per-container-type arrival-rate EWMA and prewarms
    /// slots ahead of the predicted load / reaps idle slots above the
    /// predicted floor. Disabled, pools only warm on demand and reap on
    /// the idle timeout.
    pub predictive_sizing: bool,
    /// Smoothing factor of the per-type arrival-rate EWMA (0–1; higher
    /// chases bursts faster).
    pub arrival_ewma_alpha: f64,
    /// Safety multiplier on the predicted warm floor
    /// (`ceil(rate × cold_start × safety)` slots per type): headroom so
    /// a small rate underestimate doesn't force a cold start.
    pub warm_floor_safety: f64,
    /// Idle grace before a slot above the predicted floor may be
    /// reaped — much shorter than `container_idle_timeout_s`, which
    /// stays the backstop for non-predictive reaping.
    pub predictive_reap_grace_s: f64,
}

impl Default for EndpointConfig {
    fn default() -> Self {
        EndpointConfig {
            workers_per_node: 4,
            min_nodes: 0,
            max_nodes: 8,
            container_idle_timeout_s: 600.0,
            node_idle_timeout_s: 120.0,
            strategy_period_s: 1.0,
            tasks_per_node_scaling: 10,
            prefetch: 4,
            internal_batching: true,
            result_batch: 32,
            max_result_bytes: 10 * 1024 * 1024,
            task_timeout_s: 300.0,
            worker_pipeline_depth: 4,
            predictive_sizing: true,
            arrival_ewma_alpha: 0.3,
            warm_floor_safety: 1.5,
            predictive_reap_grace_s: 5.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let s = ServiceConfig::default();
        assert_eq!(s.max_payload_bytes, 10 * 1024 * 1024); // §5.1
        assert!(s.ref_dispatch, "oversized inputs dispatch by reference by default");
        assert_eq!(s.heartbeat_period_s, 30.0); // §4.1
        let e = EndpointConfig::default();
        assert_eq!(e.container_idle_timeout_s, 600.0); // §6.1
        assert_eq!(e.node_idle_timeout_s, 120.0); // §6.3
        assert_eq!(e.tasks_per_node_scaling, 10); // §6.3
    }
}
