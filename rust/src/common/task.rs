//! The task model: one task = one invocation of a registered function
//! on a chosen endpoint (paper §3).

use crate::common::error::{Error, Result};
use crate::common::ids::{ContainerId, EndpointId, FunctionId, TaskId, UserId};
use crate::datastore::DataRef;
use crate::serialize::{Buffer, Value, Wire};

/// Task lifecycle states, mirroring Fig. 2's execution path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskState {
    /// Accepted by the web service, stored in Redis (steps 1–2).
    Received,
    /// In the endpoint's service-side task queue (step 3).
    WaitingForEndpoint,
    /// Dispatched by the forwarder to the agent (step 4).
    WaitingForNodes,
    /// Queued at a manager / executing on a worker.
    Running,
    /// Result stored in the result queue (steps 5–6), ready for pickup.
    Success,
    /// Execution raised; the serialized traceback is in the result.
    Failed,
    /// Lost agent and re-dispatch exhausted, or cancelled.
    Abandoned,
}

impl TaskState {
    /// Terminal states are never left once entered.
    pub fn is_terminal(&self) -> bool {
        matches!(self, TaskState::Success | TaskState::Failed | TaskState::Abandoned)
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskState::Received => "received",
            TaskState::WaitingForEndpoint => "waiting-for-ep",
            TaskState::WaitingForNodes => "waiting-for-nodes",
            TaskState::Running => "running",
            TaskState::Success => "success",
            TaskState::Failed => "failed",
            TaskState::Abandoned => "abandoned",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "received" => TaskState::Received,
            "waiting-for-ep" => TaskState::WaitingForEndpoint,
            "waiting-for-nodes" => TaskState::WaitingForNodes,
            "running" => TaskState::Running,
            "success" => TaskState::Success,
            "failed" => TaskState::Failed,
            "abandoned" => TaskState::Abandoned,
            _ => return Err(Error::Serialization(format!("bad task state: {s}"))),
        })
    }
}

/// What the worker should run. In real funcX this is always serialized
/// Python; here payloads are either built-in microbenchmark bodies
/// (no-op/sleep/stress, §7.2), data-plane operations, or AOT-compiled
/// compute artifacts executed via PJRT (the science payloads).
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Returns immediately ("no-op", §7.2).
    Noop,
    /// Sleeps for the given number of seconds ("sleep").
    Sleep(f64),
    /// Busy-spins one core for the given number of seconds ("stress").
    Stress(f64),
    /// Echo the input buffer back (latency probes).
    Echo,
    /// Execute a named AOT artifact (e.g. "surrogate", "stills",
    /// "reducer") with the input deserialized to f32/i32 arrays.
    Artifact(String),
    /// A data-plane op against the endpoint's intra-endpoint store
    /// (§5.2): the worker get/puts keys to move intermediate data.
    DataOp,
    /// Simulated opaque function body with a fixed duration (used by the
    /// discrete-event simulator, where nothing actually executes).
    Simulated { duration_s: f64 },
    /// Fault-injection body: the executing worker process exits with
    /// the given status code mid-task (crash testing the process
    /// executor's typed exit-status errors).
    Exit(i32),
    /// Fault-injection body: the executing worker process aborts
    /// (SIGABRT), exercising the killed-by-signal error path.
    Abort,
}

impl Payload {
    /// Nominal execution duration, used by the simulator's cost model.
    pub fn nominal_duration(&self) -> f64 {
        match self {
            Payload::Noop | Payload::Echo | Payload::DataOp => 0.0,
            Payload::Sleep(s) | Payload::Stress(s) => *s,
            Payload::Artifact(_) => 0.005,
            Payload::Simulated { duration_s } => *duration_s,
            Payload::Exit(_) | Payload::Abort => 0.0,
        }
    }

    /// Whether execution reads the task input at all. Workers skip
    /// deserializing the input buffer for payloads that ignore it
    /// (no-op/sleep/stress storms are the §7.2 throughput workloads).
    pub fn reads_input(&self) -> bool {
        match self {
            Payload::Noop
            | Payload::Sleep(_)
            | Payload::Stress(_)
            | Payload::Simulated { .. }
            | Payload::Exit(_)
            | Payload::Abort => false,
            Payload::Echo | Payload::Artifact(_) | Payload::DataOp => true,
        }
    }
}

impl Wire for Payload {
    fn to_value(&self) -> Value {
        match self {
            Payload::Noop => Value::map([("k", Value::Str("noop".into()))]),
            Payload::Sleep(s) => {
                Value::map([("k", Value::Str("sleep".into())), ("s", Value::Float(*s))])
            }
            Payload::Stress(s) => {
                Value::map([("k", Value::Str("stress".into())), ("s", Value::Float(*s))])
            }
            Payload::Echo => Value::map([("k", Value::Str("echo".into()))]),
            Payload::Artifact(name) => Value::map([
                ("k", Value::Str("artifact".into())),
                ("name", Value::Str(name.clone())),
            ]),
            Payload::DataOp => Value::map([("k", Value::Str("dataop".into()))]),
            Payload::Simulated { duration_s } => Value::map([
                ("k", Value::Str("sim".into())),
                ("s", Value::Float(*duration_s)),
            ]),
            Payload::Exit(code) => {
                Value::map([("k", Value::Str("exit".into())), ("c", Value::Int(*code as i64))])
            }
            Payload::Abort => Value::map([("k", Value::Str("abort".into()))]),
        }
    }

    fn from_value(v: &Value) -> Result<Self> {
        let kind = v
            .get("k")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Serialization("payload: missing kind".into()))?;
        let secs = || {
            v.get("s")
                .and_then(Value::as_float)
                .ok_or_else(|| Error::Serialization("payload: missing seconds".into()))
        };
        Ok(match kind {
            "noop" => Payload::Noop,
            "sleep" => Payload::Sleep(secs()?),
            "stress" => Payload::Stress(secs()?),
            "echo" => Payload::Echo,
            "artifact" => Payload::Artifact(
                v.get("name")
                    .and_then(Value::as_str)
                    .ok_or_else(|| Error::Serialization("payload: missing name".into()))?
                    .to_string(),
            ),
            "dataop" => Payload::DataOp,
            "sim" => Payload::Simulated { duration_s: secs()? },
            "exit" => Payload::Exit(
                v.get("c")
                    .and_then(Value::as_int)
                    .ok_or_else(|| Error::Serialization("payload: missing code".into()))?
                    as i32,
            ),
            "abort" => Payload::Abort,
            k => return Err(Error::Serialization(format!("payload: bad kind {k}"))),
        })
    }
}

/// A task record as brokered through the service and endpoint queues.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub function: FunctionId,
    pub endpoint: EndpointId,
    pub user: UserId,
    /// Container image the function was registered with (§4.2);
    /// `None` runs in the worker's bare environment.
    pub container: Option<ContainerId>,
    pub payload: Payload,
    /// Serialized input arguments (facade-packed buffer; §4.5). Empty
    /// when the task dispatches by reference.
    pub input: Buffer,
    /// Pass-by-reference input (§5): set when the input exceeded the
    /// service data cap and was offloaded to the data fabric. The worker
    /// resolves it through its endpoint's
    /// [`crate::datastore::DataFabric`] handle; `input` is an empty
    /// placeholder frame in that case.
    pub input_ref: Option<DataRef>,
    /// Flight-recorder trace id minted at submit (rides the trailer
    /// meta as `"trc"`); `None` for tasks built outside the service
    /// path or decoded from pre-extension frames.
    pub trace: Option<crate::metrics::TraceId>,
}

impl Task {
    pub fn new(
        function: FunctionId,
        endpoint: EndpointId,
        user: UserId,
        container: Option<ContainerId>,
        payload: Payload,
        input: Buffer,
    ) -> Self {
        Task {
            id: TaskId::new(),
            function,
            endpoint,
            user,
            container,
            payload,
            input,
            input_ref: None,
            trace: None,
        }
    }

    /// Convert to pass-by-reference dispatch: the task carries `r` in
    /// its trailer meta instead of inline input bytes.
    pub fn with_input_ref(mut self, r: DataRef) -> Self {
        self.input = Buffer::empty();
        self.input_ref = Some(r);
        self
    }

    /// Whether this task's input travels as a [`DataRef`].
    pub fn dispatches_by_ref(&self) -> bool {
        self.input_ref.is_some()
    }
}

impl Task {
    /// Everything except the input payload — the part that gets encoded
    /// into the frame body; the input rides behind it as a raw trailer.
    /// A pass-by-reference task additionally carries its [`DataRef`]
    /// under `iref` (absent for inline tasks, so pre-extension frames
    /// decode unchanged — see `docs/data-fabric.md`).
    fn meta_value(&self) -> Value {
        let mut m = match Value::map([
            ("id", self.id.to_value()),
            ("fn", self.function.to_value()),
            ("ep", self.endpoint.to_value()),
            ("user", self.user.to_value()),
            (
                "container",
                match &self.container {
                    Some(c) => c.to_value(),
                    None => Value::Null,
                },
            ),
            ("payload", self.payload.to_value()),
        ]) {
            Value::Map(m) => m,
            _ => unreachable!("Value::map builds a map"),
        };
        if let Some(r) = &self.input_ref {
            m.insert("iref".into(), r.to_value());
        }
        if let Some(t) = &self.trace {
            m.insert("trc".into(), Value::Str(t.to_string()));
        }
        Value::Map(m)
    }

    fn from_meta(v: &Value, input: Buffer) -> Result<Self> {
        let field = |name: &str| {
            v.get(name)
                .ok_or_else(|| Error::Serialization(format!("task: missing {name}")))
        };
        let container = match field("container")? {
            Value::Null => None,
            cv => Some(ContainerId::from_value(cv)?),
        };
        let input_ref = match v.get("iref") {
            Some(rv) => Some(DataRef::from_value(rv)?),
            None => None,
        };
        let trace = v
            .get("trc")
            .and_then(Value::as_str)
            .and_then(|s| s.parse::<crate::metrics::TraceId>().ok());
        Ok(Task {
            id: TaskId::from_value(field("id")?)?,
            function: FunctionId::from_value(field("fn")?)?,
            endpoint: EndpointId::from_value(field("ep")?)?,
            user: UserId::from_value(field("user")?)?,
            container,
            payload: Payload::from_value(field("payload")?)?,
            input,
            input_ref,
            trace,
        })
    }
}

impl Wire for Task {
    fn to_value(&self) -> Value {
        match self.meta_value() {
            Value::Map(mut m) => {
                m.insert("input".into(), Value::Bytes(self.input.to_vec()));
                Value::Map(m)
            }
            _ => unreachable!("meta_value is a map"),
        }
    }

    fn from_value(v: &Value) -> Result<Self> {
        let input = v
            .get("input")
            .and_then(Value::as_bytes)
            .ok_or_else(|| Error::Serialization("task: input not bytes".into()))?;
        Self::from_meta(v, Buffer::from_slice(input))
    }

    /// Frame = packed meta + raw input trailer: the input buffer is
    /// appended as-is, not re-encoded into the meta body.
    fn to_buffer(&self) -> Buffer {
        crate::serialize::pack_with_trailer(&self.meta_value(), 0, &self.input)
            .expect("facade always succeeds via BincCodec")
    }

    /// Decoding borrows the input from the frame: `input` is a zero-copy
    /// view sharing the frame's allocation (the queue-pop fast path).
    fn from_buffer(buf: &Buffer) -> Result<Self> {
        let (meta, input) = crate::serialize::unpack_with_trailer(buf)?;
        Self::from_meta(&meta, input)
    }
}

/// Result of one task execution, flowing back up the hierarchy.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub task: TaskId,
    pub state: TaskState,
    /// Serialized output (or traceback when `state == Failed`). Empty
    /// when the result travels by reference.
    pub output: Buffer,
    /// Pass-by-reference output (§5 result offload, the return-path
    /// mirror of [`Task::input_ref`]): set when the worker's output
    /// exceeded [`crate::common::config::EndpointConfig::max_result_bytes`]
    /// and was `put()` into the endpoint's store. Rides in the trailer
    /// meta under `rref` (absent for inline results, so pre-extension
    /// frames decode unchanged); `get_result` resolves it through the
    /// service-side fabric ladder.
    pub output_ref: Option<DataRef>,
    /// Worker-measured execution time t_w (Fig. 3).
    pub exec_time_s: f64,
    /// Whether the serving container was started cold for this task.
    pub cold_start: bool,
}

impl TaskResult {
    /// Whether this result's output travels as a [`DataRef`].
    pub fn returns_by_ref(&self) -> bool {
        self.output_ref.is_some()
    }

    fn meta_value(&self) -> Value {
        let mut m = match Value::map([
            ("task", self.task.to_value()),
            ("state", Value::Str(self.state.name().into())),
            ("t_w", Value::Float(self.exec_time_s)),
            ("cold", Value::Bool(self.cold_start)),
        ]) {
            Value::Map(m) => m,
            _ => unreachable!("Value::map builds a map"),
        };
        if let Some(r) = &self.output_ref {
            m.insert("rref".into(), r.to_value());
        }
        Value::Map(m)
    }

    fn from_meta(v: &Value, output: Buffer) -> Result<Self> {
        let field = |name: &str| {
            v.get(name)
                .ok_or_else(|| Error::Serialization(format!("result: missing {name}")))
        };
        let output_ref = match v.get("rref") {
            Some(rv) => Some(DataRef::from_value(rv)?),
            None => None,
        };
        Ok(TaskResult {
            task: TaskId::from_value(field("task")?)?,
            state: TaskState::from_name(
                field("state")?
                    .as_str()
                    .ok_or_else(|| Error::Serialization("result: state not str".into()))?,
            )?,
            output,
            output_ref,
            exec_time_s: field("t_w")?
                .as_float()
                .ok_or_else(|| Error::Serialization("result: t_w not float".into()))?,
            cold_start: matches!(field("cold")?, Value::Bool(true)),
        })
    }
}

impl Wire for TaskResult {
    fn to_value(&self) -> Value {
        match self.meta_value() {
            Value::Map(mut m) => {
                m.insert("output".into(), Value::Bytes(self.output.to_vec()));
                Value::Map(m)
            }
            _ => unreachable!("meta_value is a map"),
        }
    }

    fn from_value(v: &Value) -> Result<Self> {
        let output = v
            .get("output")
            .and_then(Value::as_bytes)
            .ok_or_else(|| Error::Serialization("result: output not bytes".into()))?;
        Self::from_meta(v, Buffer::from_slice(output))
    }

    /// Frame = packed meta + raw output trailer (mirrors [`Task`]).
    fn to_buffer(&self) -> Buffer {
        crate::serialize::pack_with_trailer(&self.meta_value(), 0, &self.output)
            .expect("facade always succeeds via BincCodec")
    }

    /// Decoding borrows the output from the frame as a zero-copy view
    /// (the result-retrieval fast path out of the KV store).
    fn from_buffer(buf: &Buffer) -> Result<Self> {
        let (meta, output) = crate::serialize::unpack_with_trailer(buf)?;
        Self::from_meta(&meta, output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::*;

    #[test]
    fn terminal_states() {
        assert!(TaskState::Success.is_terminal());
        assert!(TaskState::Failed.is_terminal());
        assert!(TaskState::Abandoned.is_terminal());
        assert!(!TaskState::Received.is_terminal());
        assert!(!TaskState::Running.is_terminal());
        assert!(!TaskState::WaitingForEndpoint.is_terminal());
        assert!(!TaskState::WaitingForNodes.is_terminal());
    }

    #[test]
    fn state_name_roundtrip() {
        for s in [
            TaskState::Received,
            TaskState::WaitingForEndpoint,
            TaskState::WaitingForNodes,
            TaskState::Running,
            TaskState::Success,
            TaskState::Failed,
            TaskState::Abandoned,
        ] {
            assert_eq!(TaskState::from_name(s.name()).unwrap(), s);
        }
        assert!(TaskState::from_name("bogus").is_err());
    }

    #[test]
    fn nominal_durations() {
        assert_eq!(Payload::Noop.nominal_duration(), 0.0);
        assert_eq!(Payload::Sleep(1.5).nominal_duration(), 1.5);
        assert_eq!(Payload::Stress(60.0).nominal_duration(), 60.0);
        assert_eq!(Payload::Simulated { duration_s: 3.0 }.nominal_duration(), 3.0);
    }

    #[test]
    fn payload_wire_roundtrip() {
        for p in [
            Payload::Noop,
            Payload::Sleep(2.5),
            Payload::Stress(60.0),
            Payload::Echo,
            Payload::Artifact("surrogate".into()),
            Payload::DataOp,
            Payload::Simulated { duration_s: 0.25 },
            Payload::Exit(3),
            Payload::Abort,
        ] {
            assert_eq!(Payload::from_value(&p.to_value()).unwrap(), p);
        }
    }

    #[test]
    fn task_wire_roundtrip() {
        let t = Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            Some(ContainerId::new()),
            Payload::Sleep(1.0),
            crate::serialize::pack(&Value::Int(42), 7).unwrap(),
        );
        let back = Task::from_value(&t.to_value()).unwrap();
        assert_eq!(back.id, t.id);
        assert_eq!(back.function, t.function);
        assert_eq!(back.container, t.container);
        assert_eq!(back.payload, t.payload);
        assert_eq!(back.input, t.input);
    }

    #[test]
    fn task_wire_roundtrip_no_container() {
        let t = Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Noop,
            Buffer::empty(),
        );
        let back = Task::from_value(&t.to_value()).unwrap();
        assert_eq!(back.container, None);
    }

    #[test]
    fn ref_task_wire_roundtrip() {
        let r = DataRef {
            owner: EndpointId::new(),
            epoch: 3,
            key: "task-input:abc".into(),
            size: 12345,
            checksum: 0xDEAD_BEEF,
            replicas: Vec::new(),
        };
        let t = Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Echo,
            crate::serialize::pack(&Value::Int(1), 0).unwrap(),
        )
        .with_input_ref(r.clone());
        assert!(t.dispatches_by_ref());
        assert_eq!(t.input, Buffer::empty(), "by-ref task carries a placeholder input");
        // Both framings carry the ref.
        let via_buffer = Task::from_buffer(&t.to_buffer()).unwrap();
        assert_eq!(via_buffer.input_ref, Some(r.clone()));
        let via_value = Task::from_value(&t.to_value()).unwrap();
        assert_eq!(via_value.input_ref, Some(r));
        // Inline tasks stay ref-free through the wire.
        let plain = Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Noop,
            Buffer::empty(),
        );
        assert_eq!(Task::from_buffer(&plain.to_buffer()).unwrap().input_ref, None);
    }

    #[test]
    fn result_wire_roundtrip() {
        let r = TaskResult {
            task: TaskId::new(),
            state: TaskState::Success,
            output: Buffer::empty(),
            output_ref: None,
            exec_time_s: 0.125,
            cold_start: true,
        };
        let back = TaskResult::from_value(&r.to_value()).unwrap();
        assert_eq!(back.task, r.task);
        assert_eq!(back.state, r.state);
        assert_eq!(back.exec_time_s, r.exec_time_s);
        assert!(back.cold_start);
        assert_eq!(back.output_ref, None, "inline results stay ref-free through the wire");
    }

    #[test]
    fn ref_result_wire_roundtrip() {
        let dref = DataRef {
            owner: EndpointId::new(),
            epoch: 5,
            key: "task-result:abc".into(),
            size: 98765,
            checksum: 0xFEED_F00D,
            replicas: Vec::new(),
        };
        let r = TaskResult {
            task: TaskId::new(),
            state: TaskState::Success,
            output: Buffer::empty(),
            output_ref: Some(dref.clone()),
            exec_time_s: 0.5,
            cold_start: false,
        };
        assert!(r.returns_by_ref());
        // Both framings carry the ref; the frame itself stays compact
        // (the offloaded bytes never enter it).
        let frame = r.to_buffer();
        assert!(frame.len() < 256, "by-ref result frame is {} bytes", frame.len());
        let via_buffer = TaskResult::from_buffer(&frame).unwrap();
        assert_eq!(via_buffer.output_ref, Some(dref.clone()));
        assert_eq!(via_buffer.task, r.task);
        let via_value = TaskResult::from_value(&r.to_value()).unwrap();
        assert_eq!(via_value.output_ref, Some(dref));
    }

    #[test]
    fn task_ids_unique() {
        let f = FunctionId::new();
        let e = EndpointId::new();
        let u = UserId::new();
        let t1 = Task::new(f, e, u, None, Payload::Noop, Buffer::empty());
        let t2 = Task::new(f, e, u, None, Payload::Noop, Buffer::empty());
        assert_ne!(t1.id, t2.id);
    }
}
