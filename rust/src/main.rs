//! funcx — the Layer-3 coordinator CLI.
//!
//! Subcommands map 1:1 to the paper's evaluation (§7) plus a live demo:
//!
//! ```text
//! funcx demo                 run a live service+endpoint round trip
//! funcx bench-latency        Fig. 3  latency decomposition
//! funcx bench-scaling        Fig. 4  strong/weak scaling + throughput
//! funcx bench-transfer       Fig. 5  intra-endpoint transports
//! funcx bench-mapreduce      Table 1 MapReduce Redis vs sharedFS
//! funcx bench-colmena        Table 2 Colmena stages
//! funcx bench-containers     Table 3 container cold starts
//! funcx bench-routing        Figs. 6–7 warming-aware vs random
//! funcx bench-batching       §7.5   batching ablation
//! funcx artifacts            list loaded AOT artifacts
//! ```

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::Payload;
use funcx::data::Transport;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::experiments as exp;
use funcx::runtime::PjrtRuntime;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;
use funcx::sim::SimProfile;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "demo" => demo(),
        "bench-latency" => bench_latency(),
        "bench-scaling" => bench_scaling(&args[1..]),
        "bench-transfer" => bench_transfer(),
        "bench-mapreduce" => bench_mapreduce(),
        "bench-colmena" => bench_colmena(),
        "bench-containers" => bench_containers(),
        "bench-routing" => bench_routing(),
        "bench-batching" => bench_batching(),
        "artifacts" => artifacts(),
        // Internal: the process-executor child entrypoint. Parents
        // spawn `funcx worker-child` and speak v2 multiplexed frames
        // (u32 len | u64 frame id | u8 kind) over its pipes, keeping
        // up to `worker_pipeline_depth` requests in flight.
        "worker-child" => funcx::runtime::run_worker_child(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command: {other}\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
funcx — federated FaaS coordinator (TPDS'22 reproduction)

USAGE: funcx <COMMAND>

COMMANDS:
  demo               live service+endpoint round trip (echo + artifact)
  bench-latency      Fig. 3  latency decomposition (live stack)
  bench-scaling      Fig. 4  strong/weak scaling [--mode strong|weak] [--system theta|cori]
  bench-transfer     Fig. 5  intra-endpoint transport comparison
  bench-mapreduce    Table 1 MapReduce WordCount/Sort, Redis vs sharedFS
  bench-colmena      Table 2 Colmena communication stages
  bench-containers   Table 3 container instantiation costs
  bench-routing      Figs. 6-7 warming-aware vs random routing
  bench-batching     §7.5 internal batching ablation
  artifacts          list AOT artifacts loadable by the PJRT runtime
  worker-child       (internal) process-executor worker child
  help               this message
";

fn flag(args: &[String], name: &str, default: &str) -> String {
    args.windows(2)
        .find(|w| w[0] == name)
        .map(|w| w[1].clone())
        .unwrap_or_else(|| default.to_string())
}

fn demo() -> i32 {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("demo@funcx");
    let client = FuncXClient::new(svc.clone(), tok);
    let ep = client.register_endpoint("local", "demo endpoint").unwrap();
    let (fwd, agent) = link();
    let mut builder = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
        .heartbeat_period(0.1);
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        builder = builder.runtime(Arc::new(PjrtRuntime::load_dir(dir).unwrap()));
    }
    let handle = builder.start(agent);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();

    let echo = client.register_function("echo", Payload::Echo).unwrap();
    let input = Value::map([("hello", Value::Str("funcX".into()))]);
    let t = client.run(echo, ep, &input).unwrap();
    let out = client.get_result(t, Duration::from_secs(10)).unwrap();
    println!("echo -> {out:?}");

    if dir.join("manifest.json").exists() {
        let reducer = client
            .register_function("reducer", Payload::Artifact("reducer".into()))
            .unwrap();
        let ids: Vec<i32> = (0..4096).map(|i| (i % 4) as i32).collect();
        let input = Value::map([
            ("ids", Value::I32s(ids)),
            ("vals", Value::F32s(vec![1.0; 4096])),
        ]);
        let t = client.run(reducer, ep, &input).unwrap();
        match client.get_result(t, Duration::from_secs(30)) {
            Ok(Value::List(parts)) => {
                if let Some(Value::F32s(sums)) = parts.first() {
                    println!("reducer -> first buckets {:?}", &sums[..4]);
                }
            }
            other => println!("reducer -> {other:?}"),
        }
    }
    fh.shutdown();
    handle.join();
    println!("demo OK");
    0
}

fn bench_latency() -> i32 {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("bench@funcx");
    let client = FuncXClient::new(svc.clone(), tok);
    let ep = client.register_endpoint("local", "").unwrap();
    let (fwd, agent) = link();
    let handle = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 4, ..Default::default() })
        .latency(svc.latency.clone())
        .clock(svc.clock.clone())
        .heartbeat_period(0.05)
        .start(agent);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = client.register_function("noop", Payload::Noop).unwrap();

    // Warm up, then measure.
    for _ in 0..50 {
        let t = client.run(f, ep, &Value::Null).unwrap();
        client.get_result(t, Duration::from_secs(10)).unwrap();
    }
    let s = svc.latency.stage_summaries();
    println!("Fig. 3 — latency decomposition over {} warm tasks (ms):", s.completed);
    println!("  t_s (service)   {:8.3}  p99 {:8.3}", 1e3 * s.t_s.mean, 1e3 * s.t_s.p99);
    println!("  t_f (forwarder) {:8.3}  p99 {:8.3}", 1e3 * s.t_f.mean, 1e3 * s.t_f.p99);
    println!("  t_e (endpoint)  {:8.3}  p99 {:8.3}", 1e3 * s.t_e.mean, 1e3 * s.t_e.p99);
    println!("  t_w (function)  {:8.3}  p99 {:8.3}", 1e3 * s.t_w.mean, 1e3 * s.t_w.p99);
    println!("  total           {:8.3}  p99 {:8.3}", 1e3 * s.total.mean, 1e3 * s.total.p99);
    fh.shutdown();
    handle.join();
    0
}

fn bench_scaling(args: &[String]) -> i32 {
    let mode = flag(args, "--mode", "both");
    let system = flag(args, "--system", "theta");
    let profile = match system.as_str() {
        "cori" => SimProfile::cori(),
        _ => SimProfile::theta(),
    };
    if mode == "strong" || mode == "both" {
        println!("Fig. 4(a) strong scaling on {system} — 100k concurrent requests");
        for (label, dur) in [("no-op", 0.0), ("1s sleep", 1.0)] {
            let counts = [64, 128, 256, 512, 1024, 2048, 4096, 8192];
            let pts = exp::fig4_strong(profile, 100_000, dur, &counts);
            println!("  {label}:");
            for p in pts {
                println!(
                    "    {:>6} containers  {:>10.1} s  ({:>7.0} tasks/s)",
                    p.containers, p.completion_s, p.throughput
                );
            }
        }
    }
    if mode == "weak" || mode == "both" {
        println!("Fig. 4(b) weak scaling on {system} — 10 requests/container");
        let max = if system == "cori" { 131_072 } else { 16_384 };
        for (label, dur) in [("no-op", 0.0), ("1s sleep", 1.0), ("1min stress", 60.0)] {
            let mut counts = vec![64, 256, 1024, 4096, 16_384];
            if max > 16_384 {
                counts.push(65_536);
                counts.push(131_072);
            }
            let pts = exp::fig4_weak(profile, 10, dur, &counts);
            println!("  {label}:");
            for p in pts {
                println!(
                    "    {:>7} containers ({:>8} tasks)  {:>10.1} s",
                    p.containers,
                    p.containers * 10,
                    p.completion_s
                );
            }
        }
    }
    println!(
        "§7.2.3 peak agent throughput: {:.0} tasks/s (paper: {})",
        exp::peak_throughput(profile),
        if system == "cori" { "1466" } else { "1694" }
    );
    0
}

fn bench_transfer() -> i32 {
    let sizes: Vec<usize> = (0..=10).map(|i| 1024usize << (2 * i)).collect(); // 1kB..1GB
    let pts = exp::fig5_transfer(&sizes);
    println!("Fig. 5 — intra-endpoint transfer time (s) by transport/pattern/size");
    let mut last_pattern = String::new();
    for p in pts {
        let pat = format!("{:?}", p.pattern);
        if pat != last_pattern {
            println!("  {pat}:");
            last_pattern = pat;
        }
        println!(
            "    {:>10} {:>12} B  {:>12.6} s",
            p.transport.name(),
            p.size_bytes,
            p.time_s
        );
    }
    0
}

fn bench_mapreduce() -> i32 {
    println!("Table 1 — MapReduce phase times (s), 30 GB / 300x300 tasks");
    println!(
        "  {:<10} {:<10} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}",
        "app", "transport", "in-read", "map", "iw", "ir", "reduce", "out", "total"
    );
    for r in exp::table1_mapreduce() {
        let p = r.phases;
        println!(
            "  {:<10} {:<10} {:>8.2} {:>8.1} {:>8.2} {:>8.2} {:>8.1} {:>8.2} {:>9.1}",
            r.app,
            r.transport.name(),
            p.input_read_s,
            p.map_process_s,
            p.intermediate_write_s,
            p.intermediate_read_s,
            p.reduce_process_s,
            p.output_write_s,
            p.total()
        );
    }
    println!("  (paper: WordCount iw 3.55/8.15, ir 33.39/43.40; Sort iw 3.27/5.32, ir 11.37/41.77)");
    0
}

fn bench_colmena() -> i32 {
    println!("Table 2 — Colmena communication stages (ms), 1 MB payloads");
    println!(
        "  {:<10} {:>12} {:>12} {:>13} {:>12}",
        "transport", "input-write", "input-read", "result-write", "result-read"
    );
    for r in exp::table2_colmena() {
        println!(
            "  {:<10} {:>12.2} {:>12.2} {:>13.2} {:>12.2}",
            r.transport.name(),
            1e3 * r.stages.input_write_s,
            1e3 * r.stages.input_read_s,
            1e3 * r.stages.result_write_s,
            1e3 * r.stages.result_read_s
        );
    }
    println!("  (paper: Redis 7.15/0.70/18.04/0.11; SharedFS 32.31/11.36/244.72/3.50)");
    0
}

fn bench_containers() -> i32 {
    println!("Table 3 — cold container instantiation (s), 10k samples/model");
    println!("  {:<8} {:<12} {:>8} {:>8} {:>8}", "system", "container", "min", "max", "mean");
    for r in exp::table3_containers(10_000, 42) {
        println!(
            "  {:<8} {:<12} {:>8.2} {:>8.2} {:>8.2}",
            r.system, r.container, r.min_s, r.max_s, r.mean_s
        );
    }
    println!("  (paper: theta 9.83/14.06/10.40, cori 7.25/31.26/8.49,");
    println!("          ec2-docker 1.74/1.88/1.79, ec2-singularity 1.19/1.26/1.22)");
    0
}

fn bench_routing() -> i32 {
    println!("Figs. 6-7 — warming-aware vs random routing");
    println!("  10 nodes x 10 workers, 10 container types, uniform batches");
    println!(
        "  {:>5} {:>6} | {:>12} {:>12} {:>7} | {:>10} {:>10}",
        "dur", "batch", "warming (s)", "random (s)", "gain", "wa colds", "rnd colds"
    );
    let pts = exp::fig6_fig7_routing(
        &[500, 1000, 2000, 3000],
        &[0.0, 1.0, 5.0, 20.0],
        7,
    );
    for p in pts {
        let gain = 100.0 * (p.random_completion_s - p.warming_completion_s)
            / p.random_completion_s;
        println!(
            "  {:>5.0} {:>6} | {:>12.1} {:>12.1} {:>6.1}% | {:>10} {:>10}",
            p.duration_s,
            p.batch,
            p.warming_completion_s,
            p.random_completion_s,
            gain,
            p.warming_cold_starts,
            p.random_cold_starts
        );
    }
    println!("  (paper: up to 61% completion reduction; 22 cold starts at 3000 tasks)");
    0
}

fn bench_batching() -> i32 {
    let r = exp::batching_ablation();
    println!("§7.5 — batching ablation, 10 000 no-ops on 4 Theta nodes:");
    println!("  internal batching ON : {:>8.1} s   (paper: 6.7 s)", r.batched_s);
    println!("  internal batching OFF: {:>8.1} s   (paper: 118 s)", r.unbatched_s);
    println!("  speedup              : {:>8.1}x", r.unbatched_s / r.batched_s);
    0
}

fn artifacts() -> i32 {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts/ — run `make artifacts` first");
        return 1;
    }
    let rt = PjrtRuntime::load_dir(dir).unwrap();
    println!("loaded artifacts: {:?}", rt.artifact_names());
    0
}
