//! Typed wire format: structs convert to/from [`Value`] and ship through
//! queues as facade-packed byte buffers. This is the in-tree equivalent
//! of funcX serializing task records into Redis.

use crate::common::error::Result;
use crate::serialize::facade::Buffer;
use crate::serialize::value::Value;

/// A type that can cross a queue boundary.
///
/// [`Wire::to_buffer`] / [`Wire::from_buffer`] are the hot path: frames
/// are shared [`Buffer`]s end to end, so queue push/pop never copies the
/// frame, and types carrying payload buffers ([`crate::common::task::Task`],
/// [`crate::common::task::TaskResult`]) override them with a trailer
/// framing whose decode *borrows* the payload from the frame instead of
/// copying it. `to_bytes`/`from_bytes` remain as owned-vec conveniences.
pub trait Wire: Sized {
    fn to_value(&self) -> Value;
    fn from_value(v: &Value) -> Result<Self>;

    /// Pack via the facade (tag 0) into a shared frame.
    fn to_buffer(&self) -> Buffer {
        crate::serialize::pack(&self.to_value(), 0)
            .expect("facade always succeeds via BincCodec")
    }

    /// Decode from a shared frame, borrowing the body in place.
    fn from_buffer(buf: &Buffer) -> Result<Self> {
        Self::from_value(&crate::serialize::unpack(buf)?)
    }

    /// Pack via the facade (tag 0) into an owned vec.
    fn to_bytes(&self) -> Vec<u8> {
        self.to_buffer().to_vec()
    }

    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        Self::from_buffer(&Buffer::from_slice(bytes))
    }
}

impl Wire for Value {
    fn to_value(&self) -> Value {
        self.clone()
    }

    fn from_value(v: &Value) -> Result<Self> {
        Ok(v.clone())
    }
}

impl Wire for u32 {
    fn to_value(&self) -> Value {
        Value::Int(*self as i64)
    }

    fn from_value(v: &Value) -> Result<Self> {
        v.as_int()
            .and_then(|i| u32::try_from(i).ok())
            .ok_or_else(|| crate::Error::Serialization("expected u32".into()))
    }
}

impl Wire for u64 {
    fn to_value(&self) -> Value {
        // i64 can't hold all u64; split into two ints.
        Value::List(vec![
            Value::Int((*self >> 32) as i64),
            Value::Int((*self & 0xffff_ffff) as i64),
        ])
    }

    fn from_value(v: &Value) -> Result<Self> {
        match v {
            Value::List(l) if l.len() == 2 => {
                let hi = l[0].as_int().ok_or_else(|| bad())?;
                let lo = l[1].as_int().ok_or_else(|| bad())?;
                Ok(((hi as u64) << 32) | (lo as u64 & 0xffff_ffff))
            }
            _ => Err(bad()),
        }
    }
}

impl Wire for String {
    fn to_value(&self) -> Value {
        Value::Str(self.clone())
    }

    fn from_value(v: &Value) -> Result<Self> {
        v.as_str().map(str::to_string).ok_or_else(|| bad())
    }
}

fn bad() -> crate::Error {
    crate::Error::Serialization("wire type mismatch".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_roundtrips() {
        assert_eq!(u32::from_bytes(&7u32.to_bytes()).unwrap(), 7);
        assert_eq!(u64::from_bytes(&u64::MAX.to_bytes()).unwrap(), u64::MAX);
        assert_eq!(u64::from_bytes(&0u64.to_bytes()).unwrap(), 0);
        assert_eq!(String::from_bytes(&"hi".to_string().to_bytes()).unwrap(), "hi");
    }

    #[test]
    fn value_is_identity() {
        let v = Value::map([("k", Value::Int(1))]);
        assert_eq!(Value::from_bytes(&v.to_bytes()).unwrap(), v);
    }

    #[test]
    fn type_mismatch_errors() {
        let s = "str".to_string().to_bytes();
        assert!(u32::from_bytes(&s).is_err());
        assert!(u64::from_bytes(&s).is_err());
    }
}
