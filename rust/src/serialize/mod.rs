//! §4.5 — the serialization facade.
//!
//! funcX serializes arbitrary inputs/outputs with a *Facade* over several
//! serialization libraries, sorted by speed and tried in order until one
//! succeeds; serialized objects are packed into buffers with headers that
//! carry routing tags and the method id, so only buffers need unpacking
//! at the destination.
//!
//! We reproduce that design with three strategies (analogous to funcX's
//! JSON / pickle / dill ordering):
//!
//! 1. [`RawCodec`]   — zero-copy for byte payloads (fastest, narrowest).
//! 2. [`JsonCodec`]  — human-readable, handles JSON-able values.
//! 3. [`BincCodec`]  — compact tagged binary, handles every [`Value`].
//!
//! [`Wire`] is the typed layer on top: structs convert to/from [`Value`]
//! and ship through queues as facade-packed buffers.

mod codec;
mod facade;
pub mod json;
mod value;
mod wire;

pub use codec::{BincCodec, Codec, JsonCodec, Method, RawCodec};
pub use facade::{pack, unpack, Buffer, Facade, Header};
pub use value::Value;
pub use wire::Wire;

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::{check, Gen};

    fn arb_value(g: &mut Gen, depth: usize) -> Value {
        let pick = if depth == 0 { g.usize(0, 8) } else { g.usize(0, 10) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Int(g.i64(i64::MIN / 2, i64::MAX / 2)),
            // Finite floats only: NaN breaks the roundtrip-equality oracle,
            // and funcX's JSON path has the same restriction.
            3 => Value::Float(g.f64(-1e12, 1e12)),
            4 => Value::Str(g.string(32)),
            5 => Value::Bytes(g.bytes(256)),
            6 => Value::F32s((0..g.usize(0, 64)).map(|_| g.f64(-1e6, 1e6) as f32).collect()),
            7 => Value::I32s((0..g.usize(0, 64)).map(|_| g.i64(i32::MIN as i64, i32::MAX as i64) as i32).collect()),
            8 => Value::List((0..g.usize(0, 5)).map(|_| arb_value(g, depth - 1)).collect()),
            _ => Value::Map(
                (0..g.usize(0, 5))
                    .map(|_| (g.string(8), arb_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn facade_roundtrip_any_value() {
        check("facade-roundtrip", 300, |g| {
            let v = arb_value(g, 3);
            let tag = g.u64() as u32;
            let f = Facade::default();
            let buf = f.pack(&v, tag).unwrap();
            let (header, back) = f.unpack(&buf).unwrap();
            assert_eq!(header.routing_tag, tag);
            assert_eq!(back, v);
        });
    }

    #[test]
    fn bytes_use_raw_path() {
        check("bytes-raw", 100, |g| {
            let f = Facade::default();
            let buf = f.pack(&Value::Bytes(g.bytes(512)), 0).unwrap();
            let (h, _) = f.unpack(&buf).unwrap();
            assert_eq!(h.method, Method::Raw);
        });
    }

    #[test]
    fn header_integrity_any_size() {
        check("header-integrity", 100, |g| {
            let n = g.usize(0, 4096);
            let tag = g.u64() as u32;
            let f = Facade::default();
            let buf = f.pack(&Value::Bytes(vec![0xAB; n]), tag).unwrap();
            assert_eq!(buf.body_len(), n);
            let (h, _) = f.unpack(&buf).unwrap();
            assert_eq!(h.routing_tag, tag);
        });
    }

    #[test]
    fn corrupted_buffers_never_panic() {
        check("corruption-robust", 300, |g| {
            let v = arb_value(g, 2);
            let f = Facade::default();
            let mut buf = f.pack(&v, 1).unwrap();
            if buf.0.is_empty() {
                return;
            }
            // flip a byte or truncate; unpack must return Err or a value,
            // never panic.
            if g.bool() && buf.0.len() > 1 {
                let i = g.usize(0, buf.0.len());
                buf.0[i] ^= 0xFF;
            } else {
                let keep = g.usize(0, buf.0.len());
                buf.0.truncate(keep);
            }
            let _ = f.unpack(&buf);
        });
    }
}
