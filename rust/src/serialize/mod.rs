//! §4.5 — the serialization facade.
//!
//! funcX serializes arbitrary inputs/outputs with a *Facade* over several
//! serialization libraries, sorted by speed and tried in order until one
//! succeeds; serialized objects are packed into buffers with headers that
//! carry routing tags and the method id, so only buffers need unpacking
//! at the destination.
//!
//! We reproduce that design with three strategies (analogous to funcX's
//! JSON / pickle / dill ordering):
//!
//! 1. [`RawCodec`]   — zero-copy for byte payloads (fastest, narrowest).
//! 2. [`JsonCodec`]  — human-readable, handles JSON-able values.
//! 3. [`BincCodec`]  — compact tagged binary, handles every [`Value`].
//!
//! [`Wire`] is the typed layer on top: structs convert to/from [`Value`]
//! and ship through queues as facade-packed buffers.

mod codec;
mod facade;
pub mod json;
mod value;
mod wire;

pub use codec::{BincCodec, Codec, JsonCodec, Method, RawCodec};
pub use facade::{pack, unpack, Buffer, Facade, Header};
pub use value::Value;
pub use wire::Wire;

use crate::common::error::Result;

/// Pack `v` with `trailer` appended raw after the frame — the framing
/// [`crate::common::task::Task`] / [`crate::common::task::TaskResult`]
/// use to carry their already-packed payload buffers without
/// re-encoding them (see `docs/wire-format.md`).
pub fn pack_with_trailer(v: &Value, tag: u32, trailer: &[u8]) -> Result<Buffer> {
    facade::global().pack_with_trailer(v, tag, trailer)
}

/// Split a trailer-framed buffer into its decoded meta value and the
/// trailer as a zero-copy view sharing the frame's allocation.
pub fn unpack_with_trailer(buf: &Buffer) -> Result<(Value, Buffer)> {
    let f = facade::global();
    let (header, end) = f.peek_prefix(buf)?;
    let meta = f.decode_body(header, &buf.as_slice()[facade::HEADER_LEN..end])?;
    Ok((meta, buf.slice(end, buf.len() - end)))
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::{check, Gen};

    fn arb_value(g: &mut Gen, depth: usize) -> Value {
        let pick = if depth == 0 { g.usize(0, 8) } else { g.usize(0, 10) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Int(g.i64(i64::MIN / 2, i64::MAX / 2)),
            // Finite floats only: NaN breaks the roundtrip-equality oracle,
            // and funcX's JSON path has the same restriction.
            3 => Value::Float(g.f64(-1e12, 1e12)),
            4 => Value::Str(g.string(32)),
            5 => Value::Bytes(g.bytes(256)),
            6 => Value::F32s((0..g.usize(0, 64)).map(|_| g.f64(-1e6, 1e6) as f32).collect()),
            7 => Value::I32s((0..g.usize(0, 64)).map(|_| g.i64(i32::MIN as i64, i32::MAX as i64) as i32).collect()),
            8 => Value::List((0..g.usize(0, 5)).map(|_| arb_value(g, depth - 1)).collect()),
            _ => Value::Map(
                (0..g.usize(0, 5))
                    .map(|_| (g.string(8), arb_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn facade_roundtrip_any_value() {
        check("facade-roundtrip", 300, |g| {
            let v = arb_value(g, 3);
            let tag = g.u64() as u32;
            let f = Facade::default();
            let buf = f.pack(&v, tag).unwrap();
            let (header, back) = f.unpack(&buf).unwrap();
            assert_eq!(header.routing_tag, tag);
            assert_eq!(back, v);
        });
    }

    #[test]
    fn bytes_use_raw_path() {
        check("bytes-raw", 100, |g| {
            let f = Facade::default();
            let buf = f.pack(&Value::Bytes(g.bytes(512)), 0).unwrap();
            let (h, _) = f.unpack(&buf).unwrap();
            assert_eq!(h.method, Method::Raw);
        });
    }

    #[test]
    fn header_integrity_any_size() {
        check("header-integrity", 100, |g| {
            let n = g.usize(0, 4096);
            let tag = g.u64() as u32;
            let f = Facade::default();
            let buf = f.pack(&Value::Bytes(vec![0xAB; n]), tag).unwrap();
            assert_eq!(buf.body_len(), n);
            let (h, _) = f.unpack(&buf).unwrap();
            assert_eq!(h.routing_tag, tag);
        });
    }

    #[test]
    fn corrupted_buffers_never_panic() {
        check("corruption-robust", 300, |g| {
            let v = arb_value(g, 2);
            let f = Facade::default();
            let mut raw = f.pack(&v, 1).unwrap().to_vec();
            if raw.is_empty() {
                return;
            }
            // flip a byte or truncate; unpack must return Err or a value,
            // never panic.
            if g.bool() && raw.len() > 1 {
                let i = g.usize(0, raw.len());
                raw[i] ^= 0xFF;
            } else {
                let keep = g.usize(0, raw.len());
                raw.truncate(keep);
            }
            let _ = f.unpack(&Buffer::from_vec(raw));
        });
    }

    /// Every codec that accepts a value must roundtrip it exactly (not
    /// just the facade's first-match choice).
    #[test]
    fn every_codec_roundtrips_what_it_accepts() {
        check("codec-roundtrip-all", 300, |g| {
            let v = arb_value(g, 3);
            let codecs: Vec<Box<dyn Codec>> =
                vec![Box::new(RawCodec), Box::new(JsonCodec), Box::new(BincCodec)];
            let mut accepted = 0;
            for c in &codecs {
                if let Some(body) = c.encode(&v) {
                    accepted += 1;
                    assert_eq!(
                        c.decode(&body).unwrap(),
                        v,
                        "codec {:?} failed to roundtrip",
                        c.method()
                    );
                    // encode_into must agree with encode and leave prior
                    // scratch content untouched (facade contract).
                    let mut out = vec![0xEE; 7];
                    assert!(c.encode_into(&v, &mut out));
                    assert_eq!(&out[..7], [0xEE; 7]);
                    assert_eq!(&out[7..], &body[..]);
                }
            }
            assert!(accepted >= 1, "BincCodec must accept every value");
        });
    }

    /// Hostile headers: arbitrary claimed `body_len` over a short buffer
    /// must produce `Error::Serialization` — never a panic and never an
    /// allocation proportional to the claim.
    #[test]
    fn hostile_headers_error_cleanly() {
        check("hostile-headers", 300, |g| {
            let claimed = g.u64() as u32;
            let actual = g.usize(0, 64);
            let mut raw = vec![0xFC, g.usize(0, 4) as u8]; // magic + method
            raw.extend_from_slice(&(g.u64() as u32).to_le_bytes()); // tag
            raw.extend_from_slice(&claimed.to_le_bytes());
            raw.extend(std::iter::repeat(0xAB).take(actual));
            let f = Facade::default();
            let buf = Buffer::from_vec(raw);
            if claimed as usize != actual {
                match f.unpack(&buf) {
                    Err(crate::common::error::Error::Serialization(_)) => {}
                    other => panic!("claimed {claimed} actual {actual}: {other:?}"),
                }
            } else {
                // Consistent length: decode may still fail (garbage
                // body) but must not panic.
                let _ = f.unpack(&buf);
            }
        });
    }

    /// Trailer framing: any (value, trailer) pair splits back exactly,
    /// with the trailer borrowed from the frame allocation.
    #[test]
    fn trailer_framing_roundtrip() {
        check("trailer-roundtrip", 200, |g| {
            let v = arb_value(g, 2);
            let trailer = g.bytes(512);
            let frame = pack_with_trailer(&v, 9, &trailer).unwrap();
            let (meta, tail) = unpack_with_trailer(&frame).unwrap();
            assert_eq!(meta, v);
            assert_eq!(tail.as_slice(), &trailer[..]);
            assert!(tail.same_allocation(&frame), "trailer must be a borrowed view");
        });
    }
}
