//! Individual serialization strategies behind the facade (§4.5).
//!
//! Mirrors funcX's library chain (JSON / pickle / dill): each codec
//! covers a subset of values at a different speed point; the facade
//! tries them fastest-first.

use crate::common::error::{Error, Result};
use crate::serialize::value::Value;

/// Identifies which strategy produced a buffer (stored in the header so
/// the destination deserializes without trial-and-error).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Method {
    Raw = 0,
    Json = 1,
    Binc = 2,
}

impl Method {
    pub fn from_u8(b: u8) -> Result<Method> {
        match b {
            0 => Ok(Method::Raw),
            1 => Ok(Method::Json),
            2 => Ok(Method::Binc),
            _ => Err(Error::Serialization(format!("unknown method byte {b}"))),
        }
    }
}

/// One serialization strategy.
pub trait Codec: Send + Sync {
    fn method(&self) -> Method;

    /// Append the encoded body to `out` and return `true`, or leave any
    /// partial write behind and return `false` when this codec does not
    /// support the value (the facade truncates and falls through to the
    /// next strategy). Appending into the caller's scratch keeps the
    /// per-value hot path at zero codec-side allocations.
    fn encode_into(&self, v: &Value, out: &mut Vec<u8>) -> bool;

    /// Convenience owned-vec encode (tests, one-off callers).
    fn encode(&self, v: &Value) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        self.encode_into(v, &mut out).then_some(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value>;
}

/// Zero-copy passthrough for `Value::Bytes` — the fastest strategy, and
/// the narrowest (analogous to funcX handing raw buffers straight through).
pub struct RawCodec;

impl Codec for RawCodec {
    fn method(&self) -> Method {
        Method::Raw
    }

    fn encode_into(&self, v: &Value, out: &mut Vec<u8>) -> bool {
        match v {
            Value::Bytes(b) => {
                out.extend_from_slice(b);
                true
            }
            Value::Blob(b) => {
                out.extend_from_slice(b.as_slice());
                true
            }
            _ => false,
        }
    }

    /// Slice-level decode yields owned bytes; the facade's
    /// [`crate::serialize::Facade::unpack`] short-circuits Raw frames to
    /// a zero-copy [`Value::Blob`] view instead of calling this.
    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        Ok(Value::Bytes(bytes.to_vec()))
    }
}

/// JSON text strategy: covers JSON-able values (no bytes / tensor blobs —
/// like real JSON, which forces the facade to fall through, mirroring
/// funcX's "no single library serializes all objects" observation).
pub struct JsonCodec;

impl Codec for JsonCodec {
    fn method(&self) -> Method {
        Method::Json
    }

    fn encode_into(&self, v: &Value, out: &mut Vec<u8>) -> bool {
        fn jsonable(v: &Value) -> bool {
            match v {
                Value::Null | Value::Bool(_) | Value::Int(_) | Value::Float(_) | Value::Str(_) => {
                    true
                }
                Value::Bytes(_) | Value::Blob(_) | Value::F32s(_) | Value::I32s(_) => false,
                Value::List(l) => l.iter().all(jsonable),
                Value::Map(m) => m.values().all(jsonable),
            }
        }
        if !jsonable(v) {
            return false;
        }
        crate::serialize::json::write_value(v, out);
        true
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let s = std::str::from_utf8(bytes).map_err(|e| Error::Serialization(e.to_string()))?;
        crate::serialize::json::from_str(s)
    }
}

/// Compact tagged binary strategy — the "dill" of the chain: slowest to
/// produce small output but handles every value, so the facade always
/// terminates successfully.
pub struct BincCodec;

impl BincCodec {
    fn enc_val(v: &Value, out: &mut Vec<u8>) {
        match v {
            Value::Null => out.push(0),
            Value::Bool(b) => {
                out.push(1);
                out.push(*b as u8);
            }
            Value::Int(i) => {
                out.push(2);
                out.extend_from_slice(&i.to_le_bytes());
            }
            Value::Float(f) => {
                out.push(3);
                out.extend_from_slice(&f.to_le_bytes());
            }
            Value::Str(s) => {
                out.push(4);
                Self::enc_len(s.len(), out);
                out.extend_from_slice(s.as_bytes());
            }
            Value::Bytes(b) => {
                out.push(5);
                Self::enc_len(b.len(), out);
                out.extend_from_slice(b);
            }
            // Blob encodes as bytes (tag 5); decode restores Bytes, which
            // compares equal by content.
            Value::Blob(b) => {
                out.push(5);
                Self::enc_len(b.len(), out);
                out.extend_from_slice(b.as_slice());
            }
            Value::F32s(v) => {
                out.push(6);
                Self::enc_len(v.len(), out);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Value::I32s(v) => {
                out.push(7);
                Self::enc_len(v.len(), out);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Value::List(l) => {
                out.push(8);
                Self::enc_len(l.len(), out);
                for x in l {
                    Self::enc_val(x, out);
                }
            }
            Value::Map(m) => {
                out.push(9);
                Self::enc_len(m.len(), out);
                for (k, x) in m {
                    Self::enc_len(k.len(), out);
                    out.extend_from_slice(k.as_bytes());
                    Self::enc_val(x, out);
                }
            }
        }
    }

    fn enc_len(n: usize, out: &mut Vec<u8>) {
        out.extend_from_slice(&(n as u32).to_le_bytes());
    }

    fn dec_len(bytes: &[u8], pos: &mut usize) -> Result<usize> {
        let b = Self::take(bytes, pos, 4)?;
        Ok(u32::from_le_bytes(b.try_into().unwrap()) as usize)
    }

    fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
        if *pos + n > bytes.len() {
            return Err(Error::Serialization(format!(
                "truncated buffer: need {n} at {} of {}",
                *pos,
                bytes.len()
            )));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    }

    fn dec_val(bytes: &[u8], pos: &mut usize) -> Result<Value> {
        let tag = Self::take(bytes, pos, 1)?[0];
        Ok(match tag {
            0 => Value::Null,
            1 => Value::Bool(Self::take(bytes, pos, 1)?[0] != 0),
            2 => Value::Int(i64::from_le_bytes(Self::take(bytes, pos, 8)?.try_into().unwrap())),
            3 => Value::Float(f64::from_le_bytes(Self::take(bytes, pos, 8)?.try_into().unwrap())),
            4 => {
                let n = Self::dec_len(bytes, pos)?;
                let s = Self::take(bytes, pos, n)?;
                Value::Str(
                    String::from_utf8(s.to_vec())
                        .map_err(|e| Error::Serialization(e.to_string()))?,
                )
            }
            5 => {
                let n = Self::dec_len(bytes, pos)?;
                Value::Bytes(Self::take(bytes, pos, n)?.to_vec())
            }
            6 => {
                let n = Self::dec_len(bytes, pos)?;
                let raw = Self::take(bytes, pos, n * 4)?;
                Value::F32s(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            7 => {
                let n = Self::dec_len(bytes, pos)?;
                let raw = Self::take(bytes, pos, n * 4)?;
                Value::I32s(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            8 => {
                let n = Self::dec_len(bytes, pos)?;
                let mut l = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    l.push(Self::dec_val(bytes, pos)?);
                }
                Value::List(l)
            }
            9 => {
                let n = Self::dec_len(bytes, pos)?;
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..n {
                    let kn = Self::dec_len(bytes, pos)?;
                    let k = String::from_utf8(Self::take(bytes, pos, kn)?.to_vec())
                        .map_err(|e| Error::Serialization(e.to_string()))?;
                    m.insert(k, Self::dec_val(bytes, pos)?);
                }
                Value::Map(m)
            }
            t => return Err(Error::Serialization(format!("unknown value tag {t}"))),
        })
    }
}

impl Codec for BincCodec {
    fn method(&self) -> Method {
        Method::Binc
    }

    fn encode_into(&self, v: &Value, out: &mut Vec<u8>) -> bool {
        Self::enc_val(v, out);
        true
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let mut pos = 0;
        let v = Self::dec_val(bytes, &mut pos)?;
        if pos != bytes.len() {
            return Err(Error::Serialization(format!(
                "trailing garbage: {} of {} consumed",
                pos,
                bytes.len()
            )));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_only_bytes() {
        assert!(RawCodec.encode(&Value::Bytes(vec![1, 2])).is_some());
        assert!(RawCodec.encode(&Value::Int(1)).is_none());
    }

    #[test]
    fn json_rejects_binary() {
        assert!(JsonCodec.encode(&Value::Bytes(vec![1])).is_none());
        assert!(JsonCodec.encode(&Value::F32s(vec![1.0])).is_none());
        assert!(JsonCodec
            .encode(&Value::List(vec![Value::Int(1), Value::Bytes(vec![0])]))
            .is_none());
        assert!(JsonCodec.encode(&Value::Int(1)).is_some());
    }

    #[test]
    fn binc_roundtrip_nested() {
        let v = Value::map([
            ("inputs", Value::Str("img_001.h5".into())),
            ("phil", Value::Str("params.phil".into())),
            ("pixels", Value::F32s(vec![0.5, -1.25, 3.75])),
            ("ids", Value::I32s(vec![1, -2, 3])),
            ("nested", Value::List(vec![Value::Null, Value::Bool(true), Value::Int(-9)])),
        ]);
        let enc = BincCodec.encode(&v).unwrap();
        assert_eq!(BincCodec.decode(&enc).unwrap(), v);
    }

    #[test]
    fn binc_rejects_truncated() {
        let enc = BincCodec.encode(&Value::Str("hello".into())).unwrap();
        assert!(BincCodec.decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn binc_rejects_trailing() {
        let mut enc = BincCodec.encode(&Value::Int(1)).unwrap();
        enc.push(0);
        assert!(BincCodec.decode(&enc).is_err());
    }

    #[test]
    fn method_byte_roundtrip() {
        for m in [Method::Raw, Method::Json, Method::Binc] {
            assert_eq!(Method::from_u8(m as u8).unwrap(), m);
        }
        assert!(Method::from_u8(99).is_err());
    }
}
