//! The dynamic value model passed to/from functions.
//!
//! Stands in for "arbitrary Python objects" (§4.5): primitives, strings,
//! bytes, numeric arrays (the science payloads' tensors), lists, maps.

use std::collections::BTreeMap;

use crate::serialize::facade::Buffer;

/// A dynamically-typed function input/output value.
///
/// Equality is structural by *content*: [`Value::Bytes`] and
/// [`Value::Blob`] compare equal when their bytes match, so zero-copy
/// decodes are interchangeable with owned ones.
#[derive(Clone, Debug)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    /// Opaque byte payloads — the raw fast path (owned).
    Bytes(Vec<u8>),
    /// Opaque byte payload as a zero-copy [`Buffer`] view: `unpack` of a
    /// Raw-method frame yields this variant borrowing the frame's
    /// allocation, so reading a raw payload at the worker allocates
    /// nothing (pinned in `tests/alloc_discipline.rs`).
    Blob(Buffer),
    /// Dense f32 tensor data (PJRT artifact inputs/outputs).
    F32s(Vec<f32>),
    /// Dense i32 tensor data.
    I32s(Vec<i32>),
    List(Vec<Value>),
    /// Ordered map = kwargs-style inputs (Listing 1's `data` dict).
    Map(BTreeMap<String, Value>),
}

impl Value {
    /// Rough in-memory size, used for payload-cap enforcement (§5.1).
    pub fn approx_size(&self) -> usize {
        match self {
            Value::Null | Value::Bool(_) => 1,
            Value::Int(_) | Value::Float(_) => 8,
            Value::Str(s) => s.len(),
            Value::Bytes(b) => b.len(),
            Value::Blob(b) => b.len(),
            Value::F32s(v) => v.len() * 4,
            Value::I32s(v) => v.len() * 4,
            Value::List(l) => l.iter().map(Value::approx_size).sum::<usize>() + 8,
            Value::Map(m) => {
                m.iter().map(|(k, v)| k.len() + v.approx_size()).sum::<usize>() + 8
            }
        }
    }

    /// Convenience constructor for map values.
    pub fn map(entries: impl IntoIterator<Item = (&'static str, Value)>) -> Value {
        Value::Map(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f32s(&self) -> Option<&[f32]> {
        match self {
            Value::F32s(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32s(&self) -> Option<&[i32]> {
        match self {
            Value::I32s(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self {
            Value::Bytes(b) => Some(b),
            Value::Blob(b) => Some(b.as_slice()),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Value::Null, Value::Null) => true,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Float(a), Value::Float(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::Bytes(a), Value::Bytes(b)) => a == b,
            (Value::Blob(a), Value::Blob(b)) => a.as_slice() == b.as_slice(),
            // Owned and zero-copy byte payloads are the same value.
            (Value::Bytes(a), Value::Blob(b)) | (Value::Blob(b), Value::Bytes(a)) => {
                a.as_slice() == b.as_slice()
            }
            (Value::F32s(a), Value::F32s(b)) => a == b,
            (Value::I32s(a), Value::I32s(b)) => a == b,
            (Value::List(a), Value::List(b)) => a == b,
            (Value::Map(a), Value::Map(b)) => a == b,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_sizes() {
        assert_eq!(Value::Bytes(vec![0; 100]).approx_size(), 100);
        assert_eq!(Value::F32s(vec![0.0; 10]).approx_size(), 40);
        assert!(Value::map([("k", Value::Int(1))]).approx_size() >= 9);
    }

    #[test]
    fn map_access() {
        let v = Value::map([("x", Value::Int(7)), ("name", Value::Str("a".into()))]);
        assert_eq!(v.get("x").and_then(Value::as_int), Some(7));
        assert_eq!(v.get("name").and_then(Value::as_str), Some("a"));
        assert!(v.get("missing").is_none());
        assert!(Value::Null.get("x").is_none());
    }

    #[test]
    fn float_coercion() {
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_float(), Some(2.5));
        assert_eq!(Value::Str("x".into()).as_float(), None);
    }

    #[test]
    fn blob_equals_bytes_by_content() {
        let blob = Value::Blob(Buffer::from_slice(&[1, 2, 3]));
        assert_eq!(blob, Value::Bytes(vec![1, 2, 3]));
        assert_eq!(Value::Bytes(vec![1, 2, 3]), blob);
        assert_ne!(blob, Value::Bytes(vec![1, 2, 4]));
        assert_eq!(blob, Value::Blob(Buffer::from_slice(&[1, 2, 3])));
        assert_eq!(blob.as_bytes(), Some(&[1u8, 2, 3][..]));
        assert_eq!(blob.approx_size(), 3);
    }
}
