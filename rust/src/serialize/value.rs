//! The dynamic value model passed to/from functions.
//!
//! Stands in for "arbitrary Python objects" (§4.5): primitives, strings,
//! bytes, numeric arrays (the science payloads' tensors), lists, maps.

use std::collections::BTreeMap;

/// A dynamically-typed function input/output value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    /// Opaque byte payloads — the raw fast path.
    Bytes(Vec<u8>),
    /// Dense f32 tensor data (PJRT artifact inputs/outputs).
    F32s(Vec<f32>),
    /// Dense i32 tensor data.
    I32s(Vec<i32>),
    List(Vec<Value>),
    /// Ordered map = kwargs-style inputs (Listing 1's `data` dict).
    Map(BTreeMap<String, Value>),
}

impl Value {
    /// Rough in-memory size, used for payload-cap enforcement (§5.1).
    pub fn approx_size(&self) -> usize {
        match self {
            Value::Null | Value::Bool(_) => 1,
            Value::Int(_) | Value::Float(_) => 8,
            Value::Str(s) => s.len(),
            Value::Bytes(b) => b.len(),
            Value::F32s(v) => v.len() * 4,
            Value::I32s(v) => v.len() * 4,
            Value::List(l) => l.iter().map(Value::approx_size).sum::<usize>() + 8,
            Value::Map(m) => {
                m.iter().map(|(k, v)| k.len() + v.approx_size()).sum::<usize>() + 8
            }
        }
    }

    /// Convenience constructor for map values.
    pub fn map(entries: impl IntoIterator<Item = (&'static str, Value)>) -> Value {
        Value::Map(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f32s(&self) -> Option<&[f32]> {
        match self {
            Value::F32s(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32s(&self) -> Option<&[i32]> {
        match self {
            Value::I32s(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self {
            Value::Bytes(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_sizes() {
        assert_eq!(Value::Bytes(vec![0; 100]).approx_size(), 100);
        assert_eq!(Value::F32s(vec![0.0; 10]).approx_size(), 40);
        assert!(Value::map([("k", Value::Int(1))]).approx_size() >= 9);
    }

    #[test]
    fn map_access() {
        let v = Value::map([("x", Value::Int(7)), ("name", Value::Str("a".into()))]);
        assert_eq!(v.get("x").and_then(Value::as_int), Some(7));
        assert_eq!(v.get("name").and_then(Value::as_str), Some("a"));
        assert!(v.get("missing").is_none());
        assert!(Value::Null.get("x").is_none());
    }

    #[test]
    fn float_coercion() {
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_float(), Some(2.5));
        assert_eq!(Value::Str("x".into()).as_float(), None);
    }
}
