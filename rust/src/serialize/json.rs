//! Minimal JSON writer/parser for the [`JsonCodec`] strategy (offline
//! build: serde_json is unavailable). Covers exactly the JSON-able subset
//! of [`Value`]; floats round-trip via Rust's shortest-representation
//! formatting.

use std::collections::BTreeMap;

use crate::common::error::{Error, Result};
use crate::serialize::value::Value;

pub fn to_string(v: &Value) -> String {
    let mut out = Vec::new();
    write_value(v, &mut out);
    String::from_utf8(out).expect("json writer emits utf-8")
}

/// Append UTF-8 JSON bytes directly to `out`. Allocation-free (numbers
/// format through `fmt::Write` into the same vec), so the facade's
/// reusable encode scratch stays the only buffer on the pack hot path.
pub(crate) fn write_value(v: &Value, out: &mut Vec<u8>) {
    use std::fmt::Write;
    match v {
        Value::Null => out.extend_from_slice(b"null"),
        Value::Bool(true) => out.extend_from_slice(b"true"),
        Value::Bool(false) => out.extend_from_slice(b"false"),
        Value::Int(i) => {
            let _ = write!(Utf8Vec(out), "{i}");
        }
        Value::Float(f) => {
            // Tag floats that print like ints so parsing restores the type.
            let start = out.len();
            let _ = write!(Utf8Vec(out), "{f}");
            let s = &out[start..];
            if !s.contains(&b'.') && !s.contains(&b'e') && !s.windows(3).any(|w| w == b"inf")
                && !s.windows(3).any(|w| w == b"NaN")
            {
                out.extend_from_slice(b".0");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::List(l) => {
            out.push(b'[');
            for (i, x) in l.iter().enumerate() {
                if i > 0 {
                    out.push(b',');
                }
                write_value(x, out);
            }
            out.push(b']');
        }
        Value::Map(m) => {
            out.push(b'{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(b',');
                }
                write_string(k, out);
                out.push(b':');
                write_value(x, out);
            }
            out.push(b'}');
        }
        // Not JSON-able; the codec filters these out before calling us.
        Value::Bytes(_) | Value::Blob(_) | Value::F32s(_) | Value::I32s(_) => {
            unreachable!("non-jsonable")
        }
    }
}

/// `fmt::Write` adapter appending to a byte vec (JSON is valid UTF-8 by
/// construction, so raw byte appends are safe).
struct Utf8Vec<'a>(&'a mut Vec<u8>);

impl std::fmt::Write for Utf8Vec<'_> {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        self.0.extend_from_slice(s.as_bytes());
        Ok(())
    }
}

fn write_string(s: &str, out: &mut Vec<u8>) {
    use std::fmt::Write;
    out.push(b'"');
    let mut rest = s;
    while let Some(i) = rest
        .bytes()
        .position(|b| matches!(b, b'"' | b'\\' | b'\n' | b'\r' | b'\t') || b < 0x20)
    {
        out.extend_from_slice(rest[..i].as_bytes());
        let c = rest.as_bytes()[i];
        match c {
            b'"' => out.extend_from_slice(b"\\\""),
            b'\\' => out.extend_from_slice(b"\\\\"),
            b'\n' => out.extend_from_slice(b"\\n"),
            b'\r' => out.extend_from_slice(b"\\r"),
            b'\t' => out.extend_from_slice(b"\\t"),
            c => {
                let _ = write!(Utf8Vec(out), "\\u{:04x}", c as u32);
            }
        }
        rest = &rest[i + 1..];
    }
    out.extend_from_slice(rest.as_bytes());
    out.push(b'"');
}

pub fn from_str(s: &str) -> Result<Value> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(Error::Serialization("json: trailing characters".into()));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::Serialization("json: unexpected end".into()))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Serialization(format!(
                "json: expected '{}' at {}",
                c as char, self.pos
            )))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(Error::Serialization(format!("json: bad literal at {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.literal("null", Value::Null),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => {
                self.eat(b'[')?;
                let mut l = Vec::new();
                self.skip_ws();
                if self.peek()? == b']' {
                    self.pos += 1;
                    return Ok(Value::List(l));
                }
                loop {
                    self.skip_ws();
                    l.push(self.value()?);
                    self.skip_ws();
                    match self.peek()? {
                        b',' => self.pos += 1,
                        b']' => {
                            self.pos += 1;
                            return Ok(Value::List(l));
                        }
                        _ => return Err(Error::Serialization("json: bad list".into())),
                    }
                }
            }
            b'{' => {
                self.eat(b'{')?;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek()? == b'}' {
                    self.pos += 1;
                    return Ok(Value::Map(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_ws();
                    m.insert(k, self.value()?);
                    self.skip_ws();
                    match self.peek()? {
                        b',' => self.pos += 1,
                        b'}' => {
                            self.pos += 1;
                            return Ok(Value::Map(m));
                        }
                        _ => return Err(Error::Serialization("json: bad map".into())),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(Error::Serialization("json: bad \\u".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| Error::Serialization("json: bad \\u".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Serialization("json: bad \\u".into()))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| Error::Serialization("json: bad codepoint".into()))?,
                            );
                        }
                        _ => return Err(Error::Serialization("json: bad escape".into())),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(Error::Serialization("json: bad utf8".into())),
                        };
                        if start + width > self.b.len() {
                            return Err(Error::Serialization("json: bad utf8".into()));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + width])
                            .map_err(|_| Error::Serialization("json: bad utf8".into()))?;
                        s.push_str(chunk);
                        self.pos = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| Error::Serialization("json: bad number".into()))?;
        if txt.is_empty() {
            return Err(Error::Serialization(format!("json: bad value at {start}")));
        }
        if txt.contains('.') || txt.contains('e') || txt.contains('E') {
            txt.parse::<f64>()
                .map(Value::Float)
                .map_err(|_| Error::Serialization(format!("json: bad float {txt}")))
        } else {
            txt.parse::<i64>()
                .map(Value::Int)
                .map_err(|_| Error::Serialization(format!("json: bad int {txt}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(v: Value) {
        let s = to_string(&v);
        assert_eq!(from_str(&s).unwrap(), v, "via {s}");
    }

    #[test]
    fn scalars_roundtrip() {
        rt(Value::Null);
        rt(Value::Bool(true));
        rt(Value::Bool(false));
        rt(Value::Int(0));
        rt(Value::Int(-12345678901234));
        rt(Value::Float(1.5));
        rt(Value::Float(-0.001));
        rt(Value::Float(3.0)); // int-looking float stays float
        rt(Value::Float(1e300));
    }

    #[test]
    fn strings_with_escapes() {
        rt(Value::Str("".into()));
        rt(Value::Str("hello \"world\"\n\t\\".into()));
        rt(Value::Str("unicode: π ≈ 3.14159 🚀".into()));
        rt(Value::Str("\u{1}\u{1f}".into()));
    }

    #[test]
    fn containers_roundtrip() {
        rt(Value::List(vec![]));
        rt(Value::List(vec![Value::Int(1), Value::Null, Value::Str("x".into())]));
        rt(Value::map([
            ("a", Value::Int(1)),
            ("b", Value::List(vec![Value::Bool(false)])),
            ("nested", Value::map([("deep", Value::Float(2.25))])),
        ]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str("").is_err());
        assert!(from_str("{").is_err());
        assert!(from_str("[1,]").is_err());
        assert!(from_str("1 2").is_err());
        assert!(from_str("\"unterminated").is_err());
        assert!(from_str("nul").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        assert_eq!(
            from_str(" { \"a\" : [ 1 , 2 ] } ").unwrap(),
            Value::map([("a", Value::List(vec![Value::Int(1), Value::Int(2)]))])
        );
    }
}
