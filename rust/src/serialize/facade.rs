//! The facade itself: try codecs fastest-first, pack into a tagged
//! buffer whose header carries the routing tag and method id (§4.5),
//! so only buffers are unpacked/deserialized at the destination.

use std::sync::Arc;

use crate::common::error::{Error, Result};
use crate::serialize::codec::{BincCodec, Codec, JsonCodec, Method, RawCodec};
use crate::serialize::value::Value;

/// Buffer header: magic, method, routing tag, body length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    pub method: Method,
    /// Routing tag used by forwarders/managers to steer buffers without
    /// deserializing the body.
    pub routing_tag: u32,
    pub body_len: u32,
}

const MAGIC: u8 = 0xFC; // "funcX"
const HEADER_LEN: usize = 1 + 1 + 4 + 4;

/// A packed, self-describing buffer as shipped through every queue.
#[derive(Clone, Debug, PartialEq)]
pub struct Buffer(pub Vec<u8>);

impl Buffer {
    pub fn empty() -> Buffer {
        Facade::default().pack(&Value::Null, 0).expect("null always packs")
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn body_len(&self) -> usize {
        self.0.len().saturating_sub(HEADER_LEN)
    }
}

/// Ordered chain of serialization strategies (fastest first).
pub struct Facade {
    codecs: Vec<Arc<dyn Codec>>,
}

impl Default for Facade {
    fn default() -> Self {
        Facade {
            codecs: vec![Arc::new(RawCodec), Arc::new(JsonCodec), Arc::new(BincCodec)],
        }
    }
}

impl Facade {
    /// Serialize `v`, trying each strategy in order (§4.5: "sorts the
    /// serialization libraries by speed and applies them in order
    /// successively until the object is successfully serialized").
    pub fn pack(&self, v: &Value, routing_tag: u32) -> Result<Buffer> {
        for codec in &self.codecs {
            if let Some(body) = codec.encode(v) {
                let mut out = Vec::with_capacity(HEADER_LEN + body.len());
                out.push(MAGIC);
                out.push(codec.method() as u8);
                out.extend_from_slice(&routing_tag.to_le_bytes());
                out.extend_from_slice(&(body.len() as u32).to_le_bytes());
                out.extend_from_slice(&body);
                return Ok(Buffer(out));
            }
        }
        Err(Error::Serialization("all serialization strategies failed".into()))
    }

    /// Read the header without touching the body (what forwarders do).
    pub fn peek(&self, buf: &Buffer) -> Result<Header> {
        let b = &buf.0;
        if b.len() < HEADER_LEN || b[0] != MAGIC {
            return Err(Error::Serialization("bad buffer magic/length".into()));
        }
        let method = Method::from_u8(b[1])?;
        let routing_tag = u32::from_le_bytes(b[2..6].try_into().unwrap());
        let body_len = u32::from_le_bytes(b[6..10].try_into().unwrap());
        if b.len() != HEADER_LEN + body_len as usize {
            return Err(Error::Serialization(format!(
                "length mismatch: header says {body_len}, have {}",
                b.len() - HEADER_LEN
            )));
        }
        Ok(Header { method, routing_tag, body_len })
    }

    /// Unpack a buffer at the destination.
    pub fn unpack(&self, buf: &Buffer) -> Result<(Header, Value)> {
        let header = self.peek(buf)?;
        let body = &buf.0[HEADER_LEN..];
        let codec = self
            .codecs
            .iter()
            .find(|c| c.method() == header.method)
            .ok_or_else(|| Error::Serialization("no codec for method".into()))?;
        Ok((header, codec.decode(body)?))
    }
}

/// The process-wide facade instance (perf: constructing a facade
/// allocates the codec chain; the free functions below are on the
/// per-task hot path, so they share one static instance).
fn global() -> &'static Facade {
    static FACADE: std::sync::OnceLock<Facade> = std::sync::OnceLock::new();
    FACADE.get_or_init(Facade::default)
}

/// Pack with the process-default facade.
pub fn pack(v: &Value, tag: u32) -> Result<Buffer> {
    global().pack(v, tag)
}

/// Unpack with the process-default facade.
pub fn unpack(buf: &Buffer) -> Result<Value> {
    global().unpack(buf).map(|(_, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_selects_fastest() {
        let f = Facade::default();
        // Bytes -> Raw
        let b = f.pack(&Value::Bytes(vec![9; 8]), 1).unwrap();
        assert_eq!(f.peek(&b).unwrap().method, Method::Raw);
        // JSON-able -> Json
        let b = f.pack(&Value::Int(5), 1).unwrap();
        assert_eq!(f.peek(&b).unwrap().method, Method::Json);
        // Tensor blob -> Binc (json refuses)
        let b = f.pack(&Value::F32s(vec![1.0, 2.0]), 1).unwrap();
        assert_eq!(f.peek(&b).unwrap().method, Method::Binc);
    }

    #[test]
    fn peek_does_not_need_body_decode() {
        let f = Facade::default();
        let b = f.pack(&Value::Str("task-route-me".into()), 0xDEAD).unwrap();
        assert_eq!(f.peek(&b).unwrap().routing_tag, 0xDEAD);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let f = Facade::default();
        let mut b = f.pack(&Value::Int(1), 0).unwrap();
        b.0[0] = 0x00;
        assert!(f.peek(&b).is_err());
    }

    #[test]
    fn corrupt_length_rejected() {
        let f = Facade::default();
        let mut b = f.pack(&Value::Int(1), 0).unwrap();
        b.0.truncate(b.0.len() - 1);
        assert!(f.peek(&b).is_err());
    }

    #[test]
    fn empty_buffer_is_null() {
        let v = unpack(&Buffer::empty()).unwrap();
        assert_eq!(v, Value::Null);
    }
}
