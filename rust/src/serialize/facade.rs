//! The facade itself: try codecs fastest-first, pack into a tagged
//! buffer whose header carries the routing tag and method id (§4.5),
//! so only buffers are unpacked/deserialized at the destination.
//!
//! # Shared buffers
//!
//! [`Buffer`] is a view (`offset`, `len`) into a reference-counted
//! `Arc<[u8]>` allocation. Cloning a buffer is an O(1) refcount bump —
//! never a copy of the bytes — so a packed payload can sit in the task
//! queue, the forwarder's in-flight ack cache, a link frame, and a
//! manager queue while the process holds exactly one allocation of the
//! body. Sub-views ([`Buffer::slice`]) share the same allocation, which
//! is how a `Task` decoded from a queue frame borrows its input payload
//! from the frame instead of copying it (see `docs/wire-format.md`).
//!
//! # Encode scratch
//!
//! [`Facade::pack`] assembles header + body in a thread-local scratch
//! `Vec<u8>` that is reused across calls, then makes the single exact-size
//! allocation for the shared `Arc<[u8]>`. One allocation and one memcpy
//! per pack, regardless of codec (the seed allocated a body vec *and* an
//! out vec per value, on every submit and every result).

use std::cell::RefCell;
use std::sync::{Arc, OnceLock};

use crate::common::error::{Error, Result};
use crate::serialize::codec::{BincCodec, Codec, JsonCodec, Method, RawCodec};
use crate::serialize::value::Value;

/// Buffer header: magic, method, routing tag, body length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    pub method: Method,
    /// Routing tag used by forwarders/managers to steer buffers without
    /// deserializing the body.
    pub routing_tag: u32,
    pub body_len: u32,
}

const MAGIC: u8 = 0xFC; // "funcX"
pub(crate) const HEADER_LEN: usize = 1 + 1 + 4 + 4;
/// Scratch capacity kept alive per thread between packs (see
/// [`Facade::pack`]); larger one-off frames are released after use.
const MAX_RETAINED_SCRATCH: usize = 64 * 1024;

/// A packed, self-describing byte buffer as shipped through every queue:
/// a cheaply-cloneable view into a shared, immutable allocation.
#[derive(Clone)]
pub struct Buffer {
    data: Arc<[u8]>,
    off: usize,
    len: usize,
}

impl Buffer {
    /// Wrap an owned byte vector (one allocation for the shared slice).
    pub fn from_vec(v: Vec<u8>) -> Buffer {
        let len = v.len();
        Buffer { data: Arc::from(v), off: 0, len }
    }

    /// Copy a slice into a fresh shared allocation.
    pub fn from_slice(s: &[u8]) -> Buffer {
        Buffer { data: Arc::from(s), off: 0, len: s.len() }
    }

    /// The cached empty (packed `Value::Null`) buffer. O(1): the frame is
    /// packed once per process and every caller clones the same
    /// allocation (the seed rebuilt a full `Facade` — codec chain and
    /// all — on every call).
    pub fn empty() -> Buffer {
        static EMPTY: OnceLock<Buffer> = OnceLock::new();
        EMPTY
            .get_or_init(|| global().pack(&Value::Null, 0).expect("null always packs"))
            .clone()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.off..self.off + self.len]
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Length of the packed body (everything after the header).
    pub fn body_len(&self) -> usize {
        self.len.saturating_sub(HEADER_LEN)
    }

    /// A sub-view sharing this buffer's allocation — O(1), no copy.
    /// Panics when the range exceeds the view (internal callers validate
    /// against a parsed header first).
    pub fn slice(&self, start: usize, len: usize) -> Buffer {
        assert!(start + len <= self.len, "slice {start}+{len} out of {}", self.len);
        Buffer { data: self.data.clone(), off: self.off + start, len }
    }

    /// Whether two buffers are views into the same allocation (the
    /// zero-copy invariant tests pin).
    pub fn same_allocation(&self, other: &Buffer) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Size of the backing allocation (≥ `len()` for sub-views). A task
    /// input deep-copied out of its queue frame would satisfy
    /// `alloc_len() == len()`; a borrowed view satisfies
    /// `alloc_len() > len()`.
    pub fn alloc_len(&self) -> usize {
        self.data.len()
    }

    /// Number of live handles on the backing allocation.
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.data)
    }
}

impl std::ops::Deref for Buffer {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl PartialEq for Buffer {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Buffer {}

impl std::fmt::Debug for Buffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Buffer({} bytes @{} of {})", self.len, self.off, self.data.len())
    }
}

impl From<Vec<u8>> for Buffer {
    fn from(v: Vec<u8>) -> Buffer {
        Buffer::from_vec(v)
    }
}

impl From<&[u8]> for Buffer {
    fn from(s: &[u8]) -> Buffer {
        Buffer::from_slice(s)
    }
}

impl<const N: usize> From<&[u8; N]> for Buffer {
    fn from(s: &[u8; N]) -> Buffer {
        Buffer::from_slice(s)
    }
}

/// Ordered chain of serialization strategies (fastest first).
pub struct Facade {
    codecs: Vec<Arc<dyn Codec>>,
}

impl Default for Facade {
    fn default() -> Self {
        Facade {
            codecs: vec![Arc::new(RawCodec), Arc::new(JsonCodec), Arc::new(BincCodec)],
        }
    }
}

thread_local! {
    /// Reusable encode scratch: header + body are assembled here, then
    /// copied once into the exact-size shared allocation.
    static SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

impl Facade {
    /// Serialize `v`, trying each strategy in order (§4.5: "sorts the
    /// serialization libraries by speed and applies them in order
    /// successively until the object is successfully serialized").
    pub fn pack(&self, v: &Value, routing_tag: u32) -> Result<Buffer> {
        self.pack_with_trailer(v, routing_tag, &[])
    }

    /// Pack `v` and append `trailer` raw after the packed frame. The
    /// header's `body_len` covers only `v`'s body, so [`Facade::peek_prefix`]
    /// recovers the frame boundary and the trailer can be sliced off as a
    /// zero-copy view — the framing `Task`/`TaskResult` use to carry
    /// their payload buffers without re-encoding them.
    pub fn pack_with_trailer(&self, v: &Value, routing_tag: u32, trailer: &[u8]) -> Result<Buffer> {
        SCRATCH.with(|cell| {
            // Re-entrant pack (a codec packing a nested buffer) falls back
            // to a local scratch; the hot path never recurses.
            match cell.try_borrow_mut() {
                Ok(mut scratch) => self.pack_into(v, routing_tag, trailer, &mut scratch),
                Err(_) => self.pack_into(v, routing_tag, trailer, &mut Vec::new()),
            }
        })
    }

    fn pack_into(
        &self,
        v: &Value,
        routing_tag: u32,
        trailer: &[u8],
        out: &mut Vec<u8>,
    ) -> Result<Buffer> {
        out.clear();
        out.push(MAGIC);
        out.push(0); // method byte patched below
        out.extend_from_slice(&routing_tag.to_le_bytes());
        out.extend_from_slice(&[0; 4]); // body_len patched below
        for codec in &self.codecs {
            if codec.encode_into(v, out) {
                let body_len = out.len() - HEADER_LEN;
                out[1] = codec.method() as u8;
                out[6..10].copy_from_slice(&(body_len as u32).to_le_bytes());
                out.extend_from_slice(trailer);
                let frame = Buffer::from_slice(out);
                // Don't let one oversized frame (payloads are capped at
                // ~10 MB by the service) pin that much scratch capacity
                // in every packing thread forever.
                if out.capacity() > MAX_RETAINED_SCRATCH {
                    out.truncate(0);
                    out.shrink_to(MAX_RETAINED_SCRATCH);
                }
                return Ok(frame);
            }
            out.truncate(HEADER_LEN);
        }
        Err(Error::Serialization("all serialization strategies failed".into()))
    }

    /// Read the header without touching the body (what forwarders do).
    /// Strict: the buffer must contain exactly one frame.
    pub fn peek(&self, buf: &Buffer) -> Result<Header> {
        let (header, end) = self.peek_prefix(buf)?;
        if buf.len() != end {
            return Err(Error::Serialization(format!(
                "length mismatch: header says {}, have {}",
                header.body_len,
                buf.len() - HEADER_LEN
            )));
        }
        Ok(header)
    }

    /// Read the header of a frame that may carry trailing bytes (the
    /// trailer framing). Returns the header and the frame end offset;
    /// hostile `body_len` values error out instead of panicking or
    /// driving allocations.
    pub fn peek_prefix(&self, buf: &Buffer) -> Result<(Header, usize)> {
        let b = buf.as_slice();
        if b.len() < HEADER_LEN || b[0] != MAGIC {
            return Err(Error::Serialization("bad buffer magic/length".into()));
        }
        let method = Method::from_u8(b[1])?;
        let routing_tag = u32::from_le_bytes(b[2..6].try_into().unwrap());
        let body_len = u32::from_le_bytes(b[6..10].try_into().unwrap());
        let end = HEADER_LEN
            .checked_add(body_len as usize)
            .filter(|end| *end <= b.len())
            .ok_or_else(|| {
                Error::Serialization(format!(
                    "length mismatch: header says {body_len}, have {}",
                    b.len() - HEADER_LEN
                ))
            })?;
        Ok((Header { method, routing_tag, body_len }, end))
    }

    /// Decode a body slice with the codec named in `header`. Borrows the
    /// body — callers hand in a sub-slice of the frame they already hold.
    pub fn decode_body(&self, header: Header, body: &[u8]) -> Result<Value> {
        let codec = self
            .codecs
            .iter()
            .find(|c| c.method() == header.method)
            .ok_or_else(|| Error::Serialization("no codec for method".into()))?;
        codec.decode(body)
    }

    /// Unpack a buffer at the destination. The body is decoded in place
    /// (borrowed from `buf`), never copied out first. Raw-method frames
    /// short-circuit to a [`Value::Blob`] *view* of the frame — reading
    /// a raw payload allocates nothing (the body isn't even copied into
    /// an owned vec; pinned in `tests/alloc_discipline.rs`).
    pub fn unpack(&self, buf: &Buffer) -> Result<(Header, Value)> {
        let header = self.peek(buf)?;
        if header.method == Method::Raw {
            return Ok((header, Value::Blob(buf.slice(HEADER_LEN, header.body_len as usize))));
        }
        let body = &buf.as_slice()[HEADER_LEN..];
        Ok((header, self.decode_body(header, body)?))
    }
}

/// The process-wide facade instance (perf: constructing a facade
/// allocates the codec chain; the free functions below are on the
/// per-task hot path, so they share one static instance).
pub(crate) fn global() -> &'static Facade {
    static FACADE: OnceLock<Facade> = OnceLock::new();
    FACADE.get_or_init(Facade::default)
}

/// Pack with the process-default facade.
pub fn pack(v: &Value, tag: u32) -> Result<Buffer> {
    global().pack(v, tag)
}

/// Unpack with the process-default facade.
pub fn unpack(buf: &Buffer) -> Result<Value> {
    global().unpack(buf).map(|(_, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_selects_fastest() {
        let f = Facade::default();
        // Bytes -> Raw
        let b = f.pack(&Value::Bytes(vec![9; 8]), 1).unwrap();
        assert_eq!(f.peek(&b).unwrap().method, Method::Raw);
        // JSON-able -> Json
        let b = f.pack(&Value::Int(5), 1).unwrap();
        assert_eq!(f.peek(&b).unwrap().method, Method::Json);
        // Tensor blob -> Binc (json refuses)
        let b = f.pack(&Value::F32s(vec![1.0, 2.0]), 1).unwrap();
        assert_eq!(f.peek(&b).unwrap().method, Method::Binc);
    }

    #[test]
    fn peek_does_not_need_body_decode() {
        let f = Facade::default();
        let b = f.pack(&Value::Str("task-route-me".into()), 0xDEAD).unwrap();
        assert_eq!(f.peek(&b).unwrap().routing_tag, 0xDEAD);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let f = Facade::default();
        let mut raw = f.pack(&Value::Int(1), 0).unwrap().to_vec();
        raw[0] = 0x00;
        assert!(f.peek(&Buffer::from_vec(raw)).is_err());
    }

    #[test]
    fn corrupt_length_rejected() {
        let f = Facade::default();
        let mut raw = f.pack(&Value::Int(1), 0).unwrap().to_vec();
        raw.truncate(raw.len() - 1);
        assert!(f.peek(&Buffer::from_vec(raw)).is_err());
    }

    #[test]
    fn empty_buffer_is_null() {
        let v = unpack(&Buffer::empty()).unwrap();
        assert_eq!(v, Value::Null);
        // Cached: every call shares one allocation.
        assert!(Buffer::empty().same_allocation(&Buffer::empty()));
    }

    #[test]
    fn clone_shares_allocation() {
        let b = pack(&Value::Bytes(vec![7; 1024]), 0).unwrap();
        let c = b.clone();
        assert!(b.same_allocation(&c));
        assert_eq!(b.ref_count(), 2);
        assert_eq!(b, c);
    }

    #[test]
    fn slice_is_a_view() {
        let b = Buffer::from_vec((0..32u8).collect());
        let s = b.slice(4, 8);
        assert_eq!(s.as_slice(), &(4..12u8).collect::<Vec<_>>()[..]);
        assert!(s.same_allocation(&b));
        assert_eq!(s.alloc_len(), 32);
        // Views of views compose.
        let ss = s.slice(2, 3);
        assert_eq!(ss.as_slice(), [6, 7, 8]);
        assert!(ss.same_allocation(&b));
    }

    #[test]
    fn trailer_frame_roundtrip() {
        let f = Facade::default();
        let trailer = [0xAA; 16];
        let b = f.pack_with_trailer(&Value::Int(9), 3, &trailer).unwrap();
        // Strict peek rejects the trailing bytes...
        assert!(f.peek(&b).is_err());
        // ...prefix peek recovers the boundary.
        let (h, end) = f.peek_prefix(&b).unwrap();
        assert_eq!(h.routing_tag, 3);
        assert_eq!(end, b.len() - trailer.len());
        assert_eq!(&b.as_slice()[end..], trailer);
        let meta = f.decode_body(h, &b.as_slice()[HEADER_LEN..end]).unwrap();
        assert_eq!(meta, Value::Int(9));
    }

    #[test]
    fn hostile_body_len_rejected() {
        // A header claiming a huge body must error, not panic or allocate.
        for claimed in [u32::MAX, u32::MAX - 9, 1 << 30, 11] {
            let mut raw = vec![MAGIC, Method::Raw as u8];
            raw.extend_from_slice(&0u32.to_le_bytes());
            raw.extend_from_slice(&claimed.to_le_bytes());
            raw.extend_from_slice(&[0; 10]); // actual body: 10 bytes
            let f = Facade::default();
            let b = Buffer::from_vec(raw);
            assert!(f.peek(&b).is_err(), "claimed {claimed}");
            assert!(f.peek_prefix(&b).is_err(), "claimed {claimed}");
            assert!(f.unpack(&b).is_err(), "claimed {claimed}");
        }
    }
}
