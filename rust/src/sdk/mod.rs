//! The funcX SDK (§3 "User interface") — the Rust mirror of Listing 1's
//! `FuncXClient`:
//!
//! ```text
//! fc = FuncXClient()
//! func_id = fc.register_function(process_stills)
//! task_id = fc.run(func_id, endpoint_id, data=input_data)
//! res = fc.get_result(task_id)
//! ```

use std::sync::Arc;
use std::time::Duration;

use crate::auth::Token;
use crate::batching::BatchRequest;
use crate::common::error::Result;
use crate::common::ids::{ContainerId, EndpointId, FunctionId, TaskId};
use crate::common::task::Payload;
use crate::datastore::DataRef;
use crate::metrics::{MetricsSnapshot, TaskTrace};
use crate::serialize::Value;
use crate::service::{FuncXService, ShardMap};

/// A user-facing client bound to one authenticated identity.
#[derive(Clone)]
pub struct FuncXClient {
    service: Arc<FuncXService>,
    token: Token,
}

impl FuncXClient {
    /// Construct a client from a service handle and a bearer token
    /// (the SDK's OAuth native-client flow equivalent).
    pub fn new(service: Arc<FuncXService>, token: Token) -> Self {
        FuncXClient { service, token }
    }

    /// Register a function; returns its UUID (Listing 1).
    pub fn register_function(&self, name: &str, payload: Payload) -> Result<FunctionId> {
        self.service.register_function(&self.token, name, payload, None)
    }

    /// Register a function that requires a container image (§4.2).
    pub fn register_function_with_container(
        &self,
        name: &str,
        payload: Payload,
        container: ContainerId,
    ) -> Result<FunctionId> {
        self.service.register_function(&self.token, name, payload, Some(container))
    }

    /// Register an endpoint; returns its UUID.
    pub fn register_endpoint(&self, name: &str, description: &str) -> Result<EndpointId> {
        self.service.register_endpoint(&self.token, name, description)
    }

    /// Invoke a function on an endpoint (Listing 1's `fc.run`).
    /// Asynchronous: returns the task id immediately.
    pub fn run(
        &self,
        function: FunctionId,
        endpoint: EndpointId,
        data: &Value,
    ) -> Result<TaskId> {
        Ok(self.service.submit(&self.token, function, endpoint, data)?.task)
    }

    /// Submit a batch of invocations in one call (§4.6).
    pub fn run_batch(
        &self,
        function: FunctionId,
        endpoint: EndpointId,
        inputs: &[Value],
    ) -> Result<Vec<TaskId>> {
        let mut batch = BatchRequest::new(function, endpoint);
        for v in inputs {
            batch.add(v)?;
        }
        Ok(self
            .service
            .submit_batch(&self.token, &batch)?
            .into_iter()
            .map(|r| r.task)
            .collect())
    }

    /// Invoke a function whose input is a prior task's [`DataRef`]
    /// (ref forwarding — the payload bytes never transit the service).
    pub fn run_by_ref(
        &self,
        function: FunctionId,
        endpoint: EndpointId,
        input: &DataRef,
    ) -> Result<TaskId> {
        Ok(self.service.submit_by_ref(&self.token, function, endpoint, input)?.task)
    }

    /// Non-blocking result fetch; `None` while still running.
    pub fn try_get_result(&self, task: TaskId) -> Result<Option<Value>> {
        self.service.get_result(task)
    }

    /// Blocking result fetch (Listing 1's `fc.get_result`).
    pub fn get_result(&self, task: TaskId, timeout: Duration) -> Result<Value> {
        self.service.wait_result(task, timeout)
    }

    /// Batch result retrieval (§4.6's matching batch interface).
    pub fn get_batch_results(
        &self,
        tasks: &[TaskId],
        timeout: Duration,
    ) -> Result<Vec<Value>> {
        let deadline = std::time::Instant::now() + timeout;
        tasks
            .iter()
            .map(|t| {
                let remaining = deadline
                    .saturating_duration_since(std::time::Instant::now())
                    .max(Duration::from_millis(1));
                self.service.wait_result(*t, remaining)
            })
            .collect()
    }

    /// The service plane's consistent-hash shard map (client shard map).
    ///
    /// `run`/`run_by_ref`, `try_get_result`, and `get_result` already
    /// route through this same map inside the service, so every hot-path
    /// call lands directly on the shard that owns the task's state — no
    /// cross-shard hop. The map is exposed so a distributed deployment
    /// can address the owning shard's frontend straight from the client
    /// (and so tests can pin assignment parity with the service plane).
    pub fn shard_map(&self) -> ShardMap {
        self.service.shard_map()
    }

    /// Which service shard owns `task`'s queue rows, result slot, and
    /// completion notify.
    pub fn shard_of_task(&self, task: TaskId) -> usize {
        self.service.shard_map().shard_for_task(task)
    }

    /// Which service shard owns `endpoint`'s dispatch queue.
    pub fn shard_of_endpoint(&self, endpoint: EndpointId) -> usize {
        self.service.shard_map().shard_for_endpoint(endpoint)
    }

    /// Assemble the cross-shard, cross-endpoint flight trace for one of
    /// this client's tasks (the introspection half of §4.4's task-state
    /// visibility). `None` if tracing is disabled service-side or the
    /// task's events have aged out of the bounded rings.
    pub fn trace(&self, task: TaskId) -> Option<TaskTrace> {
        self.service.trace(task)
    }

    /// One consistent point-in-time snapshot of the service's metrics
    /// registry (counters, gauges, stage histograms across every shard,
    /// store, and advertised endpoint).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.service.metrics_snapshot()
    }

    pub fn service(&self) -> &Arc<FuncXService> {
        &self.service
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{EndpointConfig, ServiceConfig};
    use crate::endpoint::{link, EndpointBuilder};

    fn stack() -> (
        FuncXClient,
        EndpointId,
        crate::service::ForwarderHandle,
        crate::endpoint::AgentHandle,
    ) {
        let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
        let (_u, tok) = svc.bootstrap_user("alice");
        let client = FuncXClient::new(svc.clone(), tok);
        let e = client.register_endpoint("laptop", "dev box").unwrap();
        let (fwd, agent) = link();
        let handle = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
            .heartbeat_period(0.05)
            .start(agent);
        let fh = svc.connect_endpoint(e, fwd).unwrap();
        (client, e, fh, handle)
    }

    #[test]
    fn listing1_flow() {
        let (client, e, fh, handle) = stack();
        let f = client.register_function("process_stills", Payload::Echo).unwrap();
        let input = Value::map([
            ("inputs", Value::Str("img_0001.h5".into())),
            ("phil", Value::Str("params.phil".into())),
        ]);
        let task = client.run(f, e, &input).unwrap();
        let res = client.get_result(task, Duration::from_secs(10)).unwrap();
        assert_eq!(res, input);
        fh.shutdown();
        handle.join();
    }

    #[test]
    fn client_shard_map_matches_service_plane() {
        let svc = Arc::new(FuncXService::new(ServiceConfig {
            service_shards: 4,
            ..Default::default()
        }));
        let (_u, tok) = svc.bootstrap_user("alice");
        let client = FuncXClient::new(svc.clone(), tok);
        assert_eq!(client.shard_map().shards(), 4);
        let t = TaskId::new();
        let e = EndpointId::new();
        assert_eq!(client.shard_of_task(t), svc.shard_map().shard_for_task(t));
        assert_eq!(client.shard_of_endpoint(e), svc.shard_map().shard_for_endpoint(e));
    }

    #[test]
    fn trace_and_metrics_surface_through_client() {
        let (client, e, fh, handle) = stack();
        let f = client.register_function("echo", Payload::Echo).unwrap();
        let task = client.run(f, e, &Value::Int(7)).unwrap();
        client.get_result(task, Duration::from_secs(10)).unwrap();
        let trace = client.trace(task).expect("tracing is on by default");
        assert!(trace.terminal().is_some(), "completed task's trace must close");
        let snap = client.metrics();
        assert!(snap.counter_total("funcx_tasks_submitted_total") >= 1);
        assert!(snap.counter_total("funcx_tasks_completed_total") >= 1);
        fh.shutdown();
        handle.join();
    }

    #[test]
    fn batch_flow() {
        let (client, e, fh, handle) = stack();
        let f = client.register_function("echo", Payload::Echo).unwrap();
        let inputs: Vec<Value> = (0..10).map(Value::Int).collect();
        let tasks = client.run_batch(f, e, &inputs).unwrap();
        let results = client.get_batch_results(&tasks, Duration::from_secs(20)).unwrap();
        assert_eq!(results, inputs);
        fh.shutdown();
        handle.join();
    }
}
