//! In-tree property-testing harness (the build is offline; proptest is
//! unavailable), used by the module-level invariant tests.
//!
//! [`check`] runs a property over `n` seeded cases; on failure it reports
//! the seed so the case replays deterministically:
//!
//! ```no_run
//! use funcx::testing::{check, Gen};
//! check("sort is idempotent", 200, |g| {
//!     let mut v = g.vec(0..64, |g| g.i64(-100, 100));
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use crate::common::rng::Rng;

/// A seeded case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.rng.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let n = self.rng.below(max_len + 1);
        (0..n).map(|_| (self.rng.next_u64() & 0xff) as u8).collect()
    }

    pub fn string(&mut self, max_len: usize) -> String {
        let n = self.rng.below(max_len + 1);
        (0..n)
            .map(|_| {
                let c = self.rng.below(52);
                (if c < 26 { b'a' + c as u8 } else { b'A' + (c - 26) as u8 }) as char
            })
            .collect()
    }

    pub fn vec<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(len.start, len.end.max(len.start + 1));
        (0..n).map(|_| f(self)).collect()
    }

    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        assert!(!v.is_empty());
        &v[self.rng.below(v.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded generations. Panics (with the seed) on
/// the first failing case. `FUNCX_PROP_SEED` replays a single case.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    if let Ok(seed) = std::env::var("FUNCX_PROP_SEED") {
        let seed: u64 = seed.parse().expect("FUNCX_PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        prop(&mut g);
        return;
    }
    // Deterministic seed stream per property name so CI is stable.
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (replay with FUNCX_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |g| {
            let x = g.usize(0, 10);
            assert!(x < 10);
        });
    }

    #[test]
    #[should_panic(expected = "replay with FUNCX_PROP_SEED=")]
    fn check_reports_seed_on_failure() {
        check("always-fails", 5, |_| panic!("boom"));
    }

    #[test]
    fn gen_vec_respects_bounds() {
        check("vec-bounds", 50, |g| {
            let v = g.vec(2..5, |g| g.bool());
            assert!((2..5).contains(&v.len()));
        });
    }

    #[test]
    fn gen_string_alpha() {
        check("string-alpha", 50, |g| {
            let s = g.string(16);
            assert!(s.chars().all(|c| c.is_ascii_alphabetic()));
        });
    }
}
