//! §5.1 — inter-endpoint data transfers (the Globus integration).
//!
//! funcX passes *references* to Globus-accessible files between
//! endpoints; the service stages data before/after function invocation
//! via the Globus transfer API. We reproduce the programmatic surface —
//! storage-endpoint registry, async third-party transfers with status
//! polling, Globus-Auth-scoped access — over a bandwidth/latency model
//! (GridFTP behaviour: per-transfer setup cost, striped wide-area
//! bandwidth shared across concurrent transfers per endpoint pair).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::common::error::{Error, Result};
use crate::common::ids::{TransferId, Uuid};
use crate::common::time::Time;

/// A registered storage endpoint (Globus Connect installation).
#[derive(Clone, Debug)]
pub struct StorageEndpoint {
    pub id: Uuid,
    pub name: String,
    /// Wide-area bandwidth to/from this endpoint, bytes/s.
    pub wan_bps: f64,
    /// Per-transfer setup latency (auth handshake + GridFTP control).
    pub setup_s: f64,
}

/// A file reference passed to/from functions (Listing 2's
/// `GlobusFile(endpoint, path)`).
#[derive(Clone, Debug, PartialEq)]
pub struct GlobusFile {
    pub endpoint: Uuid,
    pub path: String,
    pub size_bytes: u64,
}

/// Transfer task status.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransferStatus {
    Active { done_at: Time },
    Succeeded,
    Failed,
}

#[derive(Clone, Debug)]
struct TransferTask {
    #[allow(dead_code)]
    id: TransferId,
    status: TransferStatus,
    src: Uuid,
    dst: Uuid,
    bytes: u64,
}

#[derive(Default)]
struct TransferState {
    endpoints: HashMap<Uuid, StorageEndpoint>,
    tasks: HashMap<TransferId, TransferTask>,
}

/// The transfer service (Globus stand-in). Clone-shareable.
#[derive(Clone, Default)]
pub struct TransferService {
    state: Arc<Mutex<TransferState>>,
}

impl TransferService {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a storage endpoint (Globus Connect install).
    pub fn register_endpoint(&self, name: &str, wan_bps: f64, setup_s: f64) -> Uuid {
        let id = Uuid::new();
        self.state.lock().unwrap().endpoints.insert(
            id,
            StorageEndpoint { id, name: name.to_string(), wan_bps, setup_s },
        );
        id
    }

    pub fn endpoint(&self, id: Uuid) -> Result<StorageEndpoint> {
        self.state
            .lock()
            .unwrap()
            .endpoints
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("storage endpoint {id}")))
    }

    /// Estimated duration for a transfer between two endpoints: setup +
    /// size over the min of the two WAN links.
    pub fn estimate(&self, src: Uuid, dst: Uuid, bytes: u64) -> Result<f64> {
        let st = self.state.lock().unwrap();
        let s = st
            .endpoints
            .get(&src)
            .ok_or_else(|| Error::NotFound(format!("storage endpoint {src}")))?;
        let d = st
            .endpoints
            .get(&dst)
            .ok_or_else(|| Error::NotFound(format!("storage endpoint {dst}")))?;
        let bw = s.wan_bps.min(d.wan_bps);
        Ok(s.setup_s.max(d.setup_s) + bytes as f64 / bw)
    }

    /// Estimated duration for moving `file` to `dst` — the cost the
    /// data fabric's fetch ladder consults before routing a
    /// GlobusFile-sized [`crate::datastore::DataRef`] wide-area (§5.1).
    pub fn estimate_file(&self, file: &GlobusFile, dst: Uuid) -> Result<f64> {
        self.estimate(file.endpoint, dst, file.size_bytes)
    }

    /// Submit an async third-party transfer; data moves directly between
    /// the source and destination systems (GridFTP), not through funcX.
    pub fn submit(
        &self,
        file: &GlobusFile,
        dst: Uuid,
        dst_path: &str,
        now: Time,
    ) -> Result<TransferId> {
        if dst_path.is_empty() {
            return Err(Error::InvalidArgument("empty destination path".into()));
        }
        let duration = self.estimate(file.endpoint, dst, file.size_bytes)?;
        let id = TransferId::new();
        self.state.lock().unwrap().tasks.insert(
            id,
            TransferTask {
                id,
                status: TransferStatus::Active { done_at: now + duration },
                src: file.endpoint,
                dst,
                bytes: file.size_bytes,
            },
        );
        Ok(id)
    }

    /// Poll a transfer's status at `now` (marks completion lazily).
    pub fn status(&self, id: TransferId, now: Time) -> Result<TransferStatus> {
        let mut st = self.state.lock().unwrap();
        let t = st
            .tasks
            .get_mut(&id)
            .ok_or_else(|| Error::NotFound(format!("transfer {id}")))?;
        if let TransferStatus::Active { done_at } = t.status {
            if now >= done_at {
                t.status = TransferStatus::Succeeded;
            }
        }
        Ok(t.status)
    }

    /// Wait (virtually): the completion time of a submitted transfer.
    pub fn completion_time(&self, id: TransferId) -> Result<Time> {
        let st = self.state.lock().unwrap();
        match st.tasks.get(&id) {
            Some(TransferTask { status: TransferStatus::Active { done_at }, .. }) => {
                Ok(*done_at)
            }
            Some(_) => Ok(0.0),
            None => Err(Error::NotFound(format!("transfer {id}"))),
        }
    }

    /// Aggregate bytes currently in flight between an endpoint pair
    /// (capacity planning / tests).
    pub fn in_flight_bytes(&self, src: Uuid, dst: Uuid, now: Time) -> u64 {
        let st = self.state.lock().unwrap();
        st.tasks
            .values()
            .filter(|t| t.src == src && t.dst == dst)
            .filter(|t| matches!(t.status, TransferStatus::Active { done_at } if now < done_at))
            .map(|t| t.bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> (TransferService, Uuid, Uuid) {
        let ts = TransferService::new();
        // ALCF DTN: 10 Gb/s WAN; campus cluster: 1 Gb/s.
        let alcf = ts.register_endpoint("alcf#dtn", 1.25e9, 2.0);
        let campus = ts.register_endpoint("campus#cluster", 0.125e9, 2.0);
        (ts, alcf, campus)
    }

    #[test]
    fn estimate_uses_min_bandwidth() {
        let (ts, alcf, campus) = svc();
        // 1 GB over the 1 Gb/s link: 8 s + 2 s setup.
        let est = ts.estimate(alcf, campus, 1_000_000_000).unwrap();
        assert!((est - 10.0).abs() < 0.5, "estimate {est}");
    }

    #[test]
    fn estimate_file_matches_estimate() {
        let (ts, alcf, campus) = svc();
        let f = GlobusFile { endpoint: alcf, path: "/data/x".into(), size_bytes: 1_000_000_000 };
        assert_eq!(
            ts.estimate_file(&f, campus).unwrap(),
            ts.estimate(alcf, campus, 1_000_000_000).unwrap()
        );
    }

    #[test]
    fn transfer_lifecycle() {
        let (ts, alcf, campus) = svc();
        let f = GlobusFile { endpoint: alcf, path: "/data/run42.h5".into(), size_bytes: 125_000_000 };
        let id = ts.submit(&f, campus, "/scratch/run42.h5", 0.0).unwrap();
        assert!(matches!(ts.status(id, 0.1).unwrap(), TransferStatus::Active { .. }));
        // 125 MB over 1 Gb/s ~ 1 s + 2 s setup = 3 s.
        assert!(matches!(ts.status(id, 10.0).unwrap(), TransferStatus::Succeeded));
    }

    #[test]
    fn unknown_endpoints_rejected() {
        let (ts, alcf, _) = svc();
        let f = GlobusFile { endpoint: alcf, path: "/x".into(), size_bytes: 1 };
        assert!(ts.submit(&f, Uuid::new(), "/y", 0.0).is_err());
        assert!(ts.estimate(Uuid::new(), alcf, 1).is_err());
        assert!(ts.status(TransferId::new(), 0.0).is_err());
    }

    #[test]
    fn empty_dst_path_rejected() {
        let (ts, alcf, campus) = svc();
        let f = GlobusFile { endpoint: alcf, path: "/x".into(), size_bytes: 1 };
        assert!(ts.submit(&f, campus, "", 0.0).is_err());
    }

    #[test]
    fn in_flight_accounting() {
        let (ts, alcf, campus) = svc();
        let f = GlobusFile { endpoint: alcf, path: "/a".into(), size_bytes: 1_000_000 };
        ts.submit(&f, campus, "/a", 0.0).unwrap();
        ts.submit(&f, campus, "/b", 0.0).unwrap();
        assert_eq!(ts.in_flight_bytes(alcf, campus, 0.5), 2_000_000);
        assert_eq!(ts.in_flight_bytes(alcf, campus, 100.0), 0);
        assert_eq!(ts.in_flight_bytes(campus, alcf, 0.5), 0);
    }
}
