//! §4.6 — batching, at two levels:
//!
//! * **Internal batching**: managers request many tasks at once on
//!   behalf of their workers, amortising network round-trips. The
//!   [`Prefetcher`] computes the request size: idle workers plus a
//!   configurable prefetch depth (§6.2), or 1 when batching is disabled
//!   (the §7.5 ablation: 6.7 s vs 118 s for 10 000 no-ops).
//! * **User-facing batching**: [`BatchRequest`] groups many function
//!   inputs into one submission; the SDK exposes a matching batch
//!   retrieval call.

use crate::common::ids::{EndpointId, FunctionId};
use crate::serialize::{Buffer, Value};

/// Manager-side request-size policy (internal batching).
#[derive(Clone, Copy, Debug)]
pub struct Prefetcher {
    /// Whether internal batching is enabled (§7.5 ablation toggles this).
    pub enabled: bool,
    /// Extra tasks requested beyond idle capacity (§6.2 prefetch).
    pub prefetch: usize,
}

impl Prefetcher {
    pub fn new(enabled: bool, prefetch: usize) -> Self {
        Prefetcher { enabled, prefetch }
    }

    /// How many tasks the manager should request this round.
    /// With batching disabled managers fetch one at a time (the paper's
    /// baseline); enabled, they fetch idle + prefetch.
    pub fn request_size(&self, idle_workers: usize) -> usize {
        if !self.enabled {
            return 1;
        }
        idle_workers + self.prefetch
    }
}

/// A user-facing batch of invocations of one function on one endpoint.
#[derive(Clone, Debug)]
pub struct BatchRequest {
    pub function: FunctionId,
    pub endpoint: EndpointId,
    pub inputs: Vec<Buffer>,
}

impl BatchRequest {
    pub fn new(function: FunctionId, endpoint: EndpointId) -> Self {
        BatchRequest { function, endpoint, inputs: Vec::new() }
    }

    /// Add one invocation's input to the batch.
    pub fn add(&mut self, input: &Value) -> crate::Result<&mut Self> {
        self.inputs.push(crate::serialize::pack(input, 0)?);
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// Total serialized size (counted against the 10 MB service cap).
    pub fn total_bytes(&self) -> usize {
        self.inputs.iter().map(Buffer::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetcher_disabled_is_one_at_a_time() {
        let p = Prefetcher::new(false, 4);
        assert_eq!(p.request_size(0), 1);
        assert_eq!(p.request_size(64), 1);
    }

    #[test]
    fn prefetcher_enabled_requests_bulk() {
        let p = Prefetcher::new(true, 4);
        assert_eq!(p.request_size(0), 4);
        assert_eq!(p.request_size(64), 68);
    }

    #[test]
    fn batch_accumulates() {
        let mut b = BatchRequest::new(FunctionId::new(), EndpointId::new());
        assert!(b.is_empty());
        b.add(&Value::Int(1)).unwrap();
        b.add(&Value::Str("x".into())).unwrap();
        assert_eq!(b.len(), 2);
        assert!(b.total_bytes() > 0);
    }
}
