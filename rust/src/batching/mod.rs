//! §4.6 — batching, at two levels:
//!
//! * **Internal batching**: managers request many tasks at once on
//!   behalf of their workers, amortising network round-trips. The
//!   [`Prefetcher`] computes the request size: idle workers plus a
//!   configurable prefetch depth (§6.2), or 1 when batching is disabled
//!   (the §7.5 ablation: 6.7 s vs 118 s for 10 000 no-ops).
//! * **User-facing batching**: [`BatchRequest`] groups many function
//!   inputs into one submission; the SDK exposes a matching batch
//!   retrieval call.

use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use crate::common::ids::{EndpointId, FunctionId};
use crate::common::sync::Notify;
use crate::common::task::TaskResult;
use crate::serialize::{Buffer, Value};

/// Manager-side request-size policy (internal batching).
#[derive(Clone, Copy, Debug)]
pub struct Prefetcher {
    /// Whether internal batching is enabled (§7.5 ablation toggles this).
    pub enabled: bool,
    /// Extra tasks requested beyond idle capacity (§6.2 prefetch).
    pub prefetch: usize,
}

impl Prefetcher {
    pub fn new(enabled: bool, prefetch: usize) -> Self {
        Prefetcher { enabled, prefetch }
    }

    /// How many tasks the manager should request this round.
    /// With batching disabled managers fetch one at a time (the paper's
    /// baseline); enabled, they fetch idle + prefetch.
    pub fn request_size(&self, idle_workers: usize) -> usize {
        if !self.enabled {
            return 1;
        }
        idle_workers + self.prefetch
    }
}

/// Manager-side result buffer (internal batching on the *return* path).
///
/// Workers append completed results here instead of sending each one
/// over the manager→agent channel individually; the buffer flushes a
/// whole `Vec<TaskResult>` — one channel send and one [`Notify`] signal
/// per batch — when:
///
/// * `cap` results have accumulated (size flush, the high-load path), or
/// * the completing worker observes an idle manager queue (idle flush:
///   nothing else is coming soon, so don't sit on the tail), or
/// * the agent calls [`ResultBuffer::flush`] on its loop tick (straggler
///   flush, bounded by the agent's idle-wait timeout).
///
/// At 10k+ workers this collapses per-result channel traffic and wakeups
/// into per-batch ones — the return-path mirror of §4.6's task-fetch
/// batching.
pub struct ResultBuffer {
    buf: Mutex<Vec<TaskResult>>,
    cap: usize,
    tx: Sender<Vec<TaskResult>>,
    wake: Arc<Notify>,
}

impl ResultBuffer {
    pub fn new(cap: usize, tx: Sender<Vec<TaskResult>>, wake: Arc<Notify>) -> Self {
        ResultBuffer { buf: Mutex::new(Vec::new()), cap: cap.max(1), tx, wake }
    }

    /// Append one result; flushes when full or when `idle` says no more
    /// completions are imminent.
    pub fn push(&self, r: TaskResult, idle: bool) {
        let mut b = self.buf.lock().expect("result buffer poisoned");
        b.push(r);
        if b.len() >= self.cap || idle {
            let out = std::mem::take(&mut *b);
            drop(b);
            self.send(out);
        }
    }

    /// Drain whatever is buffered (agent straggler flush). Returns the
    /// number of results flushed.
    pub fn flush(&self) -> usize {
        let out = std::mem::take(&mut *self.buf.lock().expect("result buffer poisoned"));
        let n = out.len();
        if n > 0 {
            self.send(out);
        }
        n
    }

    fn send(&self, out: Vec<TaskResult>) {
        // A dropped receiver means the agent is gone; results are moot.
        let _ = self.tx.send(out);
        self.wake.notify();
    }
}

/// A user-facing batch of invocations of one function on one endpoint.
#[derive(Clone, Debug)]
pub struct BatchRequest {
    pub function: FunctionId,
    pub endpoint: EndpointId,
    pub inputs: Vec<Buffer>,
}

impl BatchRequest {
    pub fn new(function: FunctionId, endpoint: EndpointId) -> Self {
        BatchRequest { function, endpoint, inputs: Vec::new() }
    }

    /// Add one invocation's input to the batch.
    pub fn add(&mut self, input: &Value) -> crate::Result<&mut Self> {
        self.inputs.push(crate::serialize::pack(input, 0)?);
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// Total serialized size (counted against the 10 MB service cap).
    pub fn total_bytes(&self) -> usize {
        self.inputs.iter().map(Buffer::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetcher_disabled_is_one_at_a_time() {
        let p = Prefetcher::new(false, 4);
        assert_eq!(p.request_size(0), 1);
        assert_eq!(p.request_size(64), 1);
    }

    #[test]
    fn prefetcher_enabled_requests_bulk() {
        let p = Prefetcher::new(true, 4);
        assert_eq!(p.request_size(0), 4);
        assert_eq!(p.request_size(64), 68);
    }

    fn mk_result() -> TaskResult {
        TaskResult {
            task: crate::common::ids::TaskId::new(),
            state: crate::common::task::TaskState::Success,
            output: Buffer::empty(),
            exec_time_s: 0.0,
            cold_start: false,
        }
    }

    #[test]
    fn result_buffer_flushes_on_cap() {
        let (tx, rx) = std::sync::mpsc::channel();
        let wake = Arc::new(Notify::new());
        let rb = ResultBuffer::new(3, tx, wake.clone());
        let seen = wake.epoch();
        rb.push(mk_result(), false);
        rb.push(mk_result(), false);
        assert!(rx.try_recv().is_err(), "below cap, nothing sent");
        assert_eq!(wake.epoch(), seen, "no wakeup before a flush");
        rb.push(mk_result(), false);
        assert_eq!(rx.try_recv().unwrap().len(), 3, "cap flush sends the batch");
        assert_ne!(wake.epoch(), seen, "flush signals the latch");
    }

    #[test]
    fn result_buffer_flushes_on_idle() {
        let (tx, rx) = std::sync::mpsc::channel();
        let rb = ResultBuffer::new(64, tx, Arc::new(Notify::new()));
        rb.push(mk_result(), true);
        assert_eq!(rx.try_recv().unwrap().len(), 1, "idle push flushes immediately");
    }

    #[test]
    fn result_buffer_straggler_flush() {
        let (tx, rx) = std::sync::mpsc::channel();
        let rb = ResultBuffer::new(64, tx, Arc::new(Notify::new()));
        assert_eq!(rb.flush(), 0, "empty flush is a no-op send-wise");
        assert!(rx.try_recv().is_err());
        rb.push(mk_result(), false);
        rb.push(mk_result(), false);
        assert_eq!(rb.flush(), 2);
        assert_eq!(rx.try_recv().unwrap().len(), 2);
    }

    #[test]
    fn batch_accumulates() {
        let mut b = BatchRequest::new(FunctionId::new(), EndpointId::new());
        assert!(b.is_empty());
        b.add(&Value::Int(1)).unwrap();
        b.add(&Value::Str("x".into())).unwrap();
        assert_eq!(b.len(), 2);
        assert!(b.total_bytes() > 0);
    }
}
