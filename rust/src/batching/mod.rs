//! §4.6 — batching, at two levels:
//!
//! * **Internal batching**: managers request many tasks at once on
//!   behalf of their workers, amortising network round-trips. The
//!   [`Prefetcher`] computes the request size: idle workers plus a
//!   configurable prefetch depth (§6.2), or 1 when batching is disabled
//!   (the §7.5 ablation: 6.7 s vs 118 s for 10 000 no-ops).
//! * **User-facing batching**: [`BatchRequest`] groups many function
//!   inputs into one submission; the SDK exposes a matching batch
//!   retrieval call.

use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use crate::common::ids::{EndpointId, FunctionId};
use crate::common::sync::Notify;
use crate::common::task::TaskResult;
use crate::common::time::{Clock, Time};
use crate::serialize::{Buffer, Value};

/// Manager-side request-size policy (internal batching).
#[derive(Clone, Copy, Debug)]
pub struct Prefetcher {
    /// Whether internal batching is enabled (§7.5 ablation toggles this).
    pub enabled: bool,
    /// Extra tasks requested beyond idle capacity (§6.2 prefetch).
    pub prefetch: usize,
}

impl Prefetcher {
    pub fn new(enabled: bool, prefetch: usize) -> Self {
        Prefetcher { enabled, prefetch }
    }

    /// How many tasks the manager should request this round.
    /// With batching disabled managers fetch one at a time (the paper's
    /// baseline); enabled, they fetch idle + prefetch.
    pub fn request_size(&self, idle_workers: usize) -> usize {
        if !self.enabled {
            return 1;
        }
        idle_workers + self.prefetch
    }
}

/// Flush-latency budget for the adaptive threshold: a batch should
/// accumulate for at most about this long at the observed completion
/// rate before the size flush fires.
const TARGET_WINDOW_S: f64 = 0.02;
/// EWMA smoothing factor for the completion-gap estimate.
const EWMA_ALPHA: f64 = 0.2;
/// Upper bound on the adaptive flush threshold.
const MAX_ADAPTIVE_BATCH: usize = 1024;

/// The adaptive size threshold: how many results may buffer before a
/// size flush, given the EWMA of the gap between completions.
///
/// * `floor <= 1` disables buffering entirely (the config contract).
/// * Fast completions (small gap) ⇒ bigger batches, up to
///   [`MAX_ADAPTIVE_BATCH`]: at high rate the latency cost of waiting
///   for a large batch is tiny and the channel-traffic saving is big.
/// * Slow completions ⇒ the threshold decays to `floor` (the static
///   `result_batch` value), never below it.
pub fn adaptive_threshold(ewma_gap_s: f64, floor: usize) -> usize {
    if floor <= 1 {
        return 1;
    }
    if ewma_gap_s <= 0.0 {
        return MAX_ADAPTIVE_BATCH;
    }
    ((TARGET_WINDOW_S / ewma_gap_s) as usize).clamp(floor, MAX_ADAPTIVE_BATCH)
}

/// Manager-side result buffer (internal batching on the *return* path).
///
/// Workers append completed results here instead of sending each one
/// over the manager→agent channel individually; the buffer flushes a
/// whole `Vec<TaskResult>` — one channel send and one [`Notify`] signal
/// per batch — when:
///
/// * the **adaptive threshold** results have accumulated (size flush):
///   an EWMA of the completion rate sizes batches to roughly
///   [`TARGET_WINDOW_S`] of accumulation, with the configured
///   `result_batch` as the floor and [`MAX_ADAPTIVE_BATCH`] as the
///   ceiling — fast endpoints batch big automatically, slow ones fall
///   back to the static value; or
/// * the completing worker observes an idle manager queue (idle flush:
///   nothing else is coming soon, so don't sit on the tail), or
/// * the agent calls [`ResultBuffer::flush`] on its loop tick (straggler
///   flush, bounded by the agent's idle-wait timeout).
///
/// At 10k+ workers this collapses per-result channel traffic and wakeups
/// into per-batch ones — the return-path mirror of §4.6's task-fetch
/// batching — while adapting the latency/traffic trade per endpoint.
pub struct ResultBuffer {
    inner: Mutex<Inner>,
    /// The static `result_batch` value: the adaptive threshold's floor.
    floor: usize,
    tx: Sender<Vec<TaskResult>>,
    wake: Arc<Notify>,
    /// Completion gaps are measured on the injected clock (the same
    /// [`Clock`] the rest of the endpoint runs on), so simulated /
    /// virtual time drives the adaptive threshold deterministically.
    clock: Arc<dyn Clock>,
}

struct Inner {
    buf: Vec<TaskResult>,
    /// EWMA of the gap between consecutive completions, seconds.
    ewma_gap_s: f64,
    last_push: Option<Time>,
}

impl ResultBuffer {
    pub fn new(
        floor: usize,
        tx: Sender<Vec<TaskResult>>,
        wake: Arc<Notify>,
        clock: Arc<dyn Clock>,
    ) -> Self {
        let floor = floor.max(1);
        ResultBuffer {
            inner: Mutex::new(Inner {
                buf: Vec::new(),
                // Seed the gap estimate so the threshold *starts at the
                // floor* (static behaviour) and adapts from there.
                ewma_gap_s: TARGET_WINDOW_S / floor as f64,
                last_push: None,
            }),
            floor,
            tx,
            wake,
            clock,
        }
    }

    /// Append one result; flushes when the adaptive threshold is reached
    /// or when `idle` says no more completions are imminent. A result
    /// travelling by reference (`output_ref` set) bypasses the adaptive
    /// buffer and flushes immediately: its frame is a ~100-byte ref, so
    /// there is no wire traffic to amortise, while the consumer may be
    /// blocked waiting to chain a follow-on task on exactly this ref —
    /// buffering it would trade nothing for tail latency.
    pub fn push(&self, r: TaskResult, idle: bool) {
        let now = self.clock.now();
        let flush_now = idle || r.returns_by_ref();
        let mut g = self.inner.lock().expect("result buffer poisoned");
        if let Some(last) = g.last_push {
            let gap = (now - last).max(0.0);
            g.ewma_gap_s = EWMA_ALPHA * gap + (1.0 - EWMA_ALPHA) * g.ewma_gap_s;
        }
        g.last_push = Some(now);
        g.buf.push(r);
        if g.buf.len() >= adaptive_threshold(g.ewma_gap_s, self.floor) || flush_now {
            let out = std::mem::take(&mut g.buf);
            drop(g);
            self.send(out);
        }
    }

    /// The size threshold the next push will flush at (telemetry/tests).
    pub fn current_threshold(&self) -> usize {
        let g = self.inner.lock().expect("result buffer poisoned");
        adaptive_threshold(g.ewma_gap_s, self.floor)
    }

    /// Drain whatever is buffered (agent straggler flush). Returns the
    /// number of results flushed.
    pub fn flush(&self) -> usize {
        let out =
            std::mem::take(&mut self.inner.lock().expect("result buffer poisoned").buf);
        let n = out.len();
        if n > 0 {
            self.send(out);
        }
        n
    }

    fn send(&self, out: Vec<TaskResult>) {
        // A dropped receiver means the agent is gone; results are moot.
        let _ = self.tx.send(out);
        self.wake.notify();
    }
}

/// A user-facing batch of invocations of one function on one endpoint.
#[derive(Clone, Debug)]
pub struct BatchRequest {
    pub function: FunctionId,
    pub endpoint: EndpointId,
    pub inputs: Vec<Buffer>,
}

impl BatchRequest {
    pub fn new(function: FunctionId, endpoint: EndpointId) -> Self {
        BatchRequest { function, endpoint, inputs: Vec::new() }
    }

    /// Add one invocation's input to the batch.
    pub fn add(&mut self, input: &Value) -> crate::Result<&mut Self> {
        self.inputs.push(crate::serialize::pack(input, 0)?);
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// Total serialized size (counted against the 10 MB service cap).
    pub fn total_bytes(&self) -> usize {
        self.inputs.iter().map(Buffer::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetcher_disabled_is_one_at_a_time() {
        let p = Prefetcher::new(false, 4);
        assert_eq!(p.request_size(0), 1);
        assert_eq!(p.request_size(64), 1);
    }

    #[test]
    fn prefetcher_enabled_requests_bulk() {
        let p = Prefetcher::new(true, 4);
        assert_eq!(p.request_size(0), 4);
        assert_eq!(p.request_size(64), 68);
    }

    fn mk_result() -> TaskResult {
        TaskResult {
            task: crate::common::ids::TaskId::new(),
            state: crate::common::task::TaskState::Success,
            output: Buffer::empty(),
            output_ref: None,
            exec_time_s: 0.0,
            cold_start: false,
        }
    }

    #[test]
    fn result_buffer_flushes_at_floor_when_completions_are_slow() {
        // Deterministic: gaps are driven on a virtual clock.
        let vc = crate::common::time::VirtualClock::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let wake = Arc::new(Notify::new());
        let rb = ResultBuffer::new(3, tx, wake.clone(), Arc::new(vc.clone()));
        let seen = wake.epoch();
        // Gaps longer than the target window keep the threshold at the
        // static floor — this is the pre-adaptive behaviour.
        rb.push(mk_result(), false);
        vc.advance_to(0.05);
        rb.push(mk_result(), false);
        assert!(rx.try_recv().is_err(), "below the floor, nothing sent");
        assert_eq!(wake.epoch(), seen, "no wakeup before a flush");
        assert_eq!(rb.current_threshold(), 3, "slow completions pin the floor");
        vc.advance_to(0.10);
        rb.push(mk_result(), false);
        assert_eq!(rx.try_recv().unwrap().len(), 3, "floor flush sends the batch");
        assert_ne!(wake.epoch(), seen, "flush signals the latch");
    }

    #[test]
    fn result_buffer_adapts_threshold_up_under_load() {
        // Deterministic: a zero-gap burst on a virtual clock drives the
        // EWMA gap down and the threshold up — no size flush at all.
        let vc = crate::common::time::VirtualClock::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let rb = ResultBuffer::new(4, tx, Arc::new(Notify::new()), Arc::new(vc));
        let n = 200;
        for _ in 0..n {
            rb.push(mk_result(), false);
        }
        assert!(rb.current_threshold() > 4, "threshold must grow above the floor");
        let mut sends = 0;
        let mut results = 0;
        while let Ok(batch) = rx.try_recv() {
            sends += 1;
            results += batch.len();
        }
        // Static batching would have sent n/4 = 50 batches.
        assert_eq!(sends, 0, "zero-gap burst must defer entirely to the straggler flush");
        // Nothing lost: the remainder drains on the straggler flush.
        results += rb.flush();
        assert_eq!(results, n);
    }

    #[test]
    fn adaptive_threshold_formula() {
        // floor 1 = buffering disabled, whatever the rate.
        assert_eq!(adaptive_threshold(0.0, 1), 1);
        assert_eq!(adaptive_threshold(1e-9, 1), 1);
        // Slow completions (gap >> window) sit at the floor.
        assert_eq!(adaptive_threshold(1.0, 8), 8);
        assert_eq!(adaptive_threshold(TARGET_WINDOW_S, 8), 8);
        // Fast completions scale up to the cap (±1 for float rounding).
        let t = adaptive_threshold(TARGET_WINDOW_S / 100.0, 8);
        assert!((99..=101).contains(&t), "expected ~100, got {t}");
        assert_eq!(adaptive_threshold(1e-12, 8), MAX_ADAPTIVE_BATCH);
        // Degenerate gap (unknown) maxes out rather than thrashing.
        assert_eq!(adaptive_threshold(0.0, 8), MAX_ADAPTIVE_BATCH);
        // Never below the floor, never above the cap.
        for gap in [1e-9, 1e-6, 1e-3, 1.0, 100.0] {
            let t = adaptive_threshold(gap, 16);
            assert!((16..=MAX_ADAPTIVE_BATCH).contains(&t));
        }
    }

    #[test]
    fn by_ref_results_bypass_the_buffer() {
        let (tx, rx) = std::sync::mpsc::channel();
        let clock = Arc::new(crate::common::time::WallClock::new());
        let rb = ResultBuffer::new(64, tx, Arc::new(Notify::new()), clock);
        rb.push(mk_result(), false);
        assert!(rx.try_recv().is_err(), "inline result buffers below the floor");
        let mut r = mk_result();
        r.output_ref = Some(crate::datastore::DataRef {
            owner: EndpointId::new(),
            epoch: 1,
            key: "task-result:x".into(),
            size: 1 << 20,
            checksum: 7,
            replicas: Vec::new(),
        });
        rb.push(r, false);
        // The ref flushes immediately and carries the buffered inline
        // sibling out with it.
        assert_eq!(rx.try_recv().unwrap().len(), 2, "ref result must flush the buffer");
    }

    #[test]
    fn result_buffer_flushes_on_idle() {
        let (tx, rx) = std::sync::mpsc::channel();
        let clock = Arc::new(crate::common::time::WallClock::new());
        let rb = ResultBuffer::new(64, tx, Arc::new(Notify::new()), clock);
        rb.push(mk_result(), true);
        assert_eq!(rx.try_recv().unwrap().len(), 1, "idle push flushes immediately");
    }

    #[test]
    fn result_buffer_straggler_flush() {
        let (tx, rx) = std::sync::mpsc::channel();
        let clock = Arc::new(crate::common::time::WallClock::new());
        let rb = ResultBuffer::new(64, tx, Arc::new(Notify::new()), clock);
        assert_eq!(rb.flush(), 0, "empty flush is a no-op send-wise");
        assert!(rx.try_recv().is_err());
        rb.push(mk_result(), false);
        rb.push(mk_result(), false);
        assert_eq!(rb.flush(), 2);
        assert_eq!(rx.try_recv().unwrap().len(), 2);
    }

    #[test]
    fn batch_accumulates() {
        let mut b = BatchRequest::new(FunctionId::new(), EndpointId::new());
        assert!(b.is_empty());
        b.add(&Value::Int(1)).unwrap();
        b.add(&Value::Str("x".into())).unwrap();
        assert_eq!(b.len(), 2);
        assert!(b.total_bytes() > 0);
    }
}
