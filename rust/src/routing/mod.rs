//! §6.2 — warming-aware function routing at the funcX agent.
//!
//! The agent routes each task to a manager based on the container types
//! the managers advertise:
//!
//! 1. If managers have a *warm* container of the required type with idle
//!    capacity, pick the one with the **most available container
//!    workers** (load balance).
//! 2. Otherwise pick a manager with capacity **at random** (the paper's
//!    fallback), cold-starting there.
//!
//! The module also provides the randomized baseline the paper compares
//! against (Figs. 6–7) plus round-robin and bin-packing alternatives
//! (§6.2 "other scheduling policies ... could also be used"), all behind
//! the [`Scheduler`] trait so the live engine and simulator share them.

use std::collections::HashMap;

use crate::common::ids::{ContainerId, ManagerId};
use crate::common::rng::Rng;

/// What a manager advertises to the agent (§6.2 "Each manager advertises
/// its deployed container types and its available resources").
#[derive(Clone, Debug)]
pub struct ManagerView {
    pub id: ManagerId,
    /// Deployed (warm, busy or idle) containers by type.
    pub deployed: HashMap<ContainerId, usize>,
    /// Warm *idle* containers by type (subset of `deployed`).
    pub warm_idle: HashMap<ContainerId, usize>,
    /// Slots not currently executing (warm idle + empty).
    pub available_slots: usize,
    /// Total worker slots on the node.
    pub total_slots: usize,
    /// Tasks already queued at the manager beyond running ones
    /// (prefetched; §6.2). Routing counts these against availability.
    pub queued: usize,
}

impl ManagerView {
    /// Effective free capacity after queued-but-unstarted tasks.
    pub fn effective_capacity(&self) -> usize {
        self.available_slots.saturating_sub(self.queued)
    }

    fn has_capacity(&self, prefetch: usize) -> bool {
        // A manager may accept up to `prefetch` tasks beyond its current
        // availability (§6.2 prefetching).
        self.available_slots + prefetch > self.queued
    }
}

/// A routing decision for one task.
pub trait Scheduler: Send {
    /// Route a task needing `container` to one of `managers`.
    /// `None` when no manager can accept work.
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId>;

    fn name(&self) -> &'static str;

    /// Whether managers should warm-match queued tasks to idle warm
    /// containers (§6.2: "warming-aware routing involves coordination
    /// between managers and funcX agent"). The non-warming-aware
    /// baseline serves its queue FIFO regardless of container types.
    fn warm_matching(&self) -> bool {
        false
    }
}

/// The paper's warming-aware scheduler (§6.2).
pub struct WarmingAware {
    /// Extra tasks a manager may queue beyond availability.
    pub prefetch: usize,
}

impl Default for WarmingAware {
    fn default() -> Self {
        WarmingAware { prefetch: 0 }
    }
}

impl Scheduler for WarmingAware {
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        if let Some(c) = container {
            // Tier 1: a warm *idle* container of the type exists — route
            // there for an immediate warm start, tie-broken by most
            // available workers (the paper's load-balance rule).
            let tier1 = managers
                .iter()
                .filter(|m| m.warm_idle.get(&c).copied().unwrap_or(0) > 0)
                .filter(|m| m.has_capacity(self.prefetch))
                .max_by_key(|m| {
                    (
                        m.warm_idle.get(&c).copied().unwrap_or(0),
                        m.effective_capacity(),
                        std::cmp::Reverse(m.queued),
                    )
                });
            if let Some(m) = tier1 {
                return Some(m.id);
            }
            // Tier 2: containers of the type are deployed but busy —
            // queue behind them (prefetch), preferring the manager with
            // the most of them (reinforces manager/type affinity so
            // queues stay aligned with warm sets).
            let salt = |m: &ManagerView| {
                let h = (c.0 .0 as u64) ^ ((c.0 .0 >> 64) as u64) ^ (m.id.0 .0 as u64);
                h.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            };
            let tier2 = managers
                .iter()
                .filter(|m| m.deployed.get(&c).copied().unwrap_or(0) > 0)
                .filter(|m| m.has_capacity(self.prefetch))
                .max_by_key(|m| {
                    (
                        m.deployed.get(&c).copied().unwrap_or(0),
                        m.effective_capacity(),
                        // Type-salted stable tie-break: equal-looking
                        // managers resolve the same way for the same
                        // type, so types specialise onto managers and
                        // queues stay aligned with warm sets.
                        salt(m),
                    )
                });
            if let Some(m) = tier2 {
                return Some(m.id);
            }
            // Tier 3: no container of the type anywhere — place the
            // type's *first* container on a type-consistent manager
            // (hash + linear probe over capacity) so subsequent tasks of
            // the type concentrate instead of scattering. This plays the
            // role of the paper's random fallback while keeping the
            // choice stable per type.
            if !managers.is_empty() {
                let h = (c.0 .0 as u64) ^ ((c.0 .0 >> 64) as u64);
                let start = (h % managers.len() as u64) as usize;
                for i in 0..managers.len() {
                    let m = &managers[(start + i) % managers.len()];
                    if m.has_capacity(self.prefetch) {
                        return Some(m.id);
                    }
                }
            }
            return None;
        }
        // Container-less tasks: random among managers with capacity
        // (paper: "the funcX agent chooses one manager at random").
        random_with_capacity(managers, self.prefetch, rng)
    }

    fn name(&self) -> &'static str {
        "warming-aware"
    }

    fn warm_matching(&self) -> bool {
        true
    }
}

/// The non-warming-aware baseline (Figs. 6–7): uniformly random among
/// managers with capacity, ignoring container warmth.
#[derive(Default)]
pub struct Randomized {
    pub prefetch: usize,
}

impl Scheduler for Randomized {
    fn route(
        &mut self,
        _container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        random_with_capacity(managers, self.prefetch, rng)
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Round-robin over managers with capacity (§6.2 lists it as an
/// alternative policy).
#[derive(Default)]
pub struct RoundRobin {
    cursor: usize,
    pub prefetch: usize,
}

impl Scheduler for RoundRobin {
    fn route(
        &mut self,
        _container: Option<ContainerId>,
        managers: &[ManagerView],
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        if managers.is_empty() {
            return None;
        }
        for i in 0..managers.len() {
            let m = &managers[(self.cursor + i) % managers.len()];
            if m.has_capacity(self.prefetch) {
                self.cursor = (self.cursor + i + 1) % managers.len();
                return Some(m.id);
            }
        }
        None
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Bin-packing: fill the *least*-available manager that still has
/// capacity, concentrating load so idle nodes can be released (§6.2
/// alternative; pairs with the elastic strategy's scale-down).
#[derive(Default)]
pub struct BinPacking {
    pub prefetch: usize,
}

impl Scheduler for BinPacking {
    fn route(
        &mut self,
        _container: Option<ContainerId>,
        managers: &[ManagerView],
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        managers
            .iter()
            .filter(|m| m.has_capacity(self.prefetch))
            .min_by_key(|m| (m.effective_capacity(), m.id.0 .0))
            .map(|m| m.id)
    }

    fn name(&self) -> &'static str {
        "bin-packing"
    }
}

/// Kubernetes-endpoint routing (§6.2): on a K8s deployment each manager
/// pod is bound to ONE container image, so "the agent simply needs to
/// route tasks to corresponding managers" — pick among the managers
/// pinned to the task's type (most available first); container-less
/// tasks cannot run on a pinned pod.
pub struct KubernetesRouting {
    pub prefetch: usize,
}

impl KubernetesRouting {
    pub fn new(prefetch: usize) -> Self {
        KubernetesRouting { prefetch }
    }
}

impl Scheduler for KubernetesRouting {
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        let c = container?;
        managers
            .iter()
            // A pod serves exactly one image: its deployed census is
            // {c: n} (or empty before the first task lands).
            .filter(|m| {
                m.deployed.keys().all(|k| *k == c)
                    && (m.deployed.contains_key(&c) || m.deployed.is_empty())
            })
            .filter(|m| m.has_capacity(self.prefetch))
            .max_by_key(|m| (m.deployed.contains_key(&c), m.effective_capacity()))
            .map(|m| m.id)
    }

    fn name(&self) -> &'static str {
        "kubernetes"
    }

    fn warm_matching(&self) -> bool {
        true
    }
}

fn random_with_capacity(
    managers: &[ManagerView],
    prefetch: usize,
    rng: &mut Rng,
) -> Option<ManagerId> {
    // Random-start first-fit: O(1) with plentiful capacity, O(n) worst
    // case, no allocation, one RNG draw (this runs once per routed task —
    // the agent hot path). Start position is uniform, so load spreads
    // evenly even though the scan is deterministic from there.
    if managers.is_empty() {
        return None;
    }
    let start = rng.below(managers.len());
    for i in 0..managers.len() {
        let m = &managers[(start + i) % managers.len()];
        if m.has_capacity(prefetch) {
            return Some(m.id);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(bits: u128, warm: &[(u128, usize)], avail: usize, total: usize) -> ManagerView {
        ManagerView {
            id: ManagerId::from_bits(bits),
            deployed: warm
                .iter()
                .map(|(c, n)| (ContainerId::from_bits(*c), *n))
                .collect(),
            warm_idle: warm
                .iter()
                .map(|(c, n)| (ContainerId::from_bits(*c), *n))
                .collect(),
            available_slots: avail,
            total_slots: total,
            queued: 0,
        }
    }

    #[test]
    fn warming_aware_prefers_warm_manager() {
        let managers = vec![
            mgr(1, &[], 10, 10),
            mgr(2, &[(7, 1)], 5, 10), // only manager with warm type-7
        ];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            assert_eq!(
                s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
                Some(ManagerId::from_bits(2))
            );
        }
    }

    #[test]
    fn warming_aware_ties_broken_by_availability() {
        // Both have warm type-7; pick the one with more available workers.
        let managers = vec![mgr(1, &[(7, 1)], 2, 10), mgr(2, &[(7, 1)], 8, 10)];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(1);
        assert_eq!(
            s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
            Some(ManagerId::from_bits(2))
        );
    }

    #[test]
    fn warming_aware_fallback_is_type_consistent() {
        // No warm containers anywhere: the fallback picks a manager with
        // capacity, *stable per container type* so a type's containers
        // concentrate rather than scatter.
        let managers = vec![mgr(1, &[], 5, 10), mgr(2, &[], 5, 10)];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(2);
        let c = ContainerId::from_bits(7);
        let first = s.route(Some(c), &managers, &mut rng).unwrap();
        for _ in 0..50 {
            assert_eq!(s.route(Some(c), &managers, &mut rng), Some(first));
        }
        // Many distinct types spread across managers.
        let mut seen = std::collections::HashSet::new();
        for t in 0..64u128 {
            seen.insert(
                s.route(Some(ContainerId::from_bits(t + 100)), &managers, &mut rng).unwrap(),
            );
        }
        assert_eq!(seen.len(), 2, "distinct types should spread over managers");
        // Container-less tasks still route randomly among capacity.
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(s.route(None, &managers, &mut rng).unwrap());
        }
        assert_eq!(seen.len(), 2);
    }

    #[test]
    fn no_capacity_returns_none() {
        let managers = vec![mgr(1, &[], 0, 10)];
        let mut rng = Rng::new(3);
        assert!(WarmingAware::default()
            .route(Some(ContainerId::from_bits(7)), &managers, &mut rng)
            .is_none());
        assert!(Randomized::default().route(None, &managers, &mut rng).is_none());
        assert!(RoundRobin::default().route(None, &managers, &mut rng).is_none());
        assert!(BinPacking::default().route(None, &managers, &mut rng).is_none());
    }

    #[test]
    fn warm_but_full_manager_not_chosen() {
        // Manager 2 has the warm container but zero capacity.
        let managers = vec![mgr(1, &[], 5, 10), mgr(2, &[(7, 1)], 0, 10)];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(4);
        assert_eq!(
            s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
            Some(ManagerId::from_bits(1))
        );
    }

    #[test]
    fn prefetch_extends_capacity() {
        let mut m = mgr(1, &[(7, 1)], 1, 10);
        m.queued = 1; // availability exhausted by queued task
        let managers = vec![m];
        let mut rng = Rng::new(5);
        // Without prefetch, no capacity.
        assert!(WarmingAware { prefetch: 0 }
            .route(Some(ContainerId::from_bits(7)), &managers, &mut rng)
            .is_none());
        // With prefetch, the manager can queue ahead.
        assert!(WarmingAware { prefetch: 2 }
            .route(Some(ContainerId::from_bits(7)), &managers, &mut rng)
            .is_some());
    }

    #[test]
    fn round_robin_cycles() {
        let managers = vec![mgr(1, &[], 5, 5), mgr(2, &[], 5, 5), mgr(3, &[], 5, 5)];
        let mut s = RoundRobin::default();
        let mut rng = Rng::new(6);
        let picks: Vec<_> =
            (0..6).map(|_| s.route(None, &managers, &mut rng).unwrap().0 .0).collect();
        assert_eq!(picks[0..3], picks[3..6], "cycle repeats");
        let unique: std::collections::HashSet<_> = picks[0..3].iter().collect();
        assert_eq!(unique.len(), 3);
    }

    #[test]
    fn kubernetes_routes_to_pinned_pods() {
        // Pod 1 pinned to image 7, pod 2 pinned to image 9, pod 3 fresh.
        let managers = vec![
            mgr(1, &[(7, 4)], 2, 4),
            mgr(2, &[(9, 4)], 4, 4),
            mgr(3, &[], 4, 4),
        ];
        let mut s = KubernetesRouting::new(0);
        let mut rng = Rng::new(1);
        assert_eq!(
            s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
            Some(ManagerId::from_bits(1))
        );
        assert_eq!(
            s.route(Some(ContainerId::from_bits(9)), &managers, &mut rng),
            Some(ManagerId::from_bits(2))
        );
        // Unknown image: only the fresh pod is eligible.
        assert_eq!(
            s.route(Some(ContainerId::from_bits(5)), &managers, &mut rng),
            Some(ManagerId::from_bits(3))
        );
        // Container-less tasks can't run on pinned pods.
        assert_eq!(s.route(None, &managers, &mut rng), None);
    }

    #[test]
    fn kubernetes_respects_capacity() {
        let managers = vec![mgr(1, &[(7, 4)], 0, 4)];
        let mut s = KubernetesRouting::new(0);
        let mut rng = Rng::new(2);
        assert_eq!(s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng), None);
    }

    #[test]
    fn bin_packing_concentrates() {
        let managers = vec![mgr(1, &[], 9, 10), mgr(2, &[], 2, 10)];
        let mut s = BinPacking::default();
        let mut rng = Rng::new(7);
        // Least-available eligible manager is 2.
        assert_eq!(s.route(None, &managers, &mut rng), Some(ManagerId::from_bits(2)));
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::check;

    fn arb_managers(g: &mut crate::testing::Gen) -> Vec<ManagerView> {
        let n = g.usize(1, 12);
        (0..n)
            .map(|i| {
                let total = g.usize(1, 16);
                let avail = g.usize(0, total + 1);
                let mut warm = HashMap::new();
                for c in 0..g.usize(0, 4) {
                    warm.insert(
                        ContainerId::from_bits(c as u128 + 1),
                        g.usize(0, avail.max(1) + 1),
                    );
                }
                ManagerView {
                    id: ManagerId::from_bits(i as u128 + 1),
                    deployed: warm.clone(),
                    warm_idle: warm,
                    available_slots: avail,
                    total_slots: total,
                    queued: 0,
                }
            })
            .collect()
    }

    #[test]
    fn never_routes_to_full_manager() {
        // Invariant: every scheduler only picks managers with capacity.
        check("route-capacity", 300, |g| {
            let managers = arb_managers(g);
            let container = if g.bool() {
                Some(ContainerId::from_bits(g.usize(1, 5) as u128))
            } else {
                None
            };
            let mut rng = crate::common::rng::Rng::new(g.u64());
            let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
                Box::new(WarmingAware::default()),
                Box::new(Randomized::default()),
                Box::new(RoundRobin::default()),
                Box::new(BinPacking::default()),
            ];
            for s in schedulers.iter_mut() {
                if let Some(picked) = s.route(container, &managers, &mut rng) {
                    let m = managers.iter().find(|m| m.id == picked).unwrap();
                    assert!(
                        m.available_slots > 0,
                        "{} routed to a full manager",
                        s.name()
                    );
                }
            }
        });
    }

    #[test]
    fn warming_aware_never_cold_when_warm_exists() {
        // THE §6.2 invariant: if any manager has a warm idle container of
        // the required type AND capacity, warming-aware must pick such a
        // manager.
        check("route-warm-first", 300, |g| {
            let managers = arb_managers(g);
            let c = ContainerId::from_bits(g.usize(1, 5) as u128);
            let warm_exists = managers
                .iter()
                .any(|m| m.deployed.get(&c).copied().unwrap_or(0) > 0 && m.available_slots > 0);
            let mut rng = crate::common::rng::Rng::new(g.u64());
            let mut s = WarmingAware::default();
            if let Some(picked) = s.route(Some(c), &managers, &mut rng) {
                if warm_exists {
                    let m = managers.iter().find(|m| m.id == picked).unwrap();
                    assert!(
                        m.deployed.get(&c).copied().unwrap_or(0) > 0,
                        "warm manager existed but routing went cold"
                    );
                }
            } else {
                assert!(
                    managers.iter().all(|m| m.available_slots == 0),
                    "returned None despite available capacity"
                );
            }
        });
    }
}
