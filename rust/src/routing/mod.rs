//! §6.2 — warming-aware function routing at the funcX agent.
//!
//! The agent routes each task to a manager based on the container types
//! the managers advertise:
//!
//! 1. If managers have a *warm* container of the required type with idle
//!    capacity, pick the one with the **most available container
//!    workers** (load balance).
//! 2. Otherwise pick a manager with capacity **at random** (the paper's
//!    fallback), cold-starting there.
//!
//! The module also provides the randomized baseline the paper compares
//! against (Figs. 6–7) plus round-robin and bin-packing alternatives
//! (§6.2 "other scheduling policies ... could also be used"), all behind
//! the [`Scheduler`] trait so the live engine and simulator share them.
//!
//! # Indexed routing ([`RoutingTable`])
//!
//! A naive implementation scans every [`ManagerView`] per routed task —
//! O(M) on the agent's per-task hot path, which FDN (arXiv:2102.02330)
//! identifies as the scaling limiter for large manager fleets. The
//! [`RoutingTable`] maintains the same information incrementally:
//!
//! * per container type, a `BTreeSet` ordered by the warming-aware
//!   tier-1 key `(warm_idle, effective capacity, fewest queued, id)` and
//!   a second set ordered by the tier-2 key `(deployed, effective
//!   capacity, type-salt, id)`, each holding only managers that
//!   currently pass the capacity filter — so the best candidate is
//!   `set.last()`, O(log M);
//! * a capacity count updated O(1) per slot change, so "no capacity
//!   anywhere" answers without a scan.
//!
//! Every view mutation goes through [`RoutingTable::update`] /
//! [`RoutingTable::upsert`], which de-index and re-index just the
//! touched manager (O(T·log M) for a manager hosting T container
//! types). [`Scheduler::route_indexed`] defaults to the O(M) scan over
//! the table's views, so alternative policies keep working unchanged;
//! [`WarmingAware`] overrides it with the O(log M) lookups and — by
//! construction of the keys — makes **identical decisions** to its scan
//! path (a property test pins this).
//!
//! Routing here is *within* an endpoint (task → manager). One layer up,
//! the service plane routes tasks and endpoints onto forwarder shards
//! with [`crate::service::ShardMap`]'s consistent-hash ring; the
//! locality hints these schedulers consume ride on the task regardless
//! of which shard brokered it, because store advertisements are shared
//! across shards (see `docs/architecture.md`).

use std::cmp::Reverse;
use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::common::ids::{ContainerId, EndpointId, ManagerId};
use crate::common::rng::Rng;

/// What a manager advertises to the agent (§6.2 "Each manager advertises
/// its deployed container types and its available resources").
#[derive(Clone, Debug, PartialEq)]
pub struct ManagerView {
    pub id: ManagerId,
    /// Deployed (warm, busy or idle) containers by type.
    pub deployed: HashMap<ContainerId, usize>,
    /// Warm *idle* containers by type (subset of `deployed`).
    pub warm_idle: HashMap<ContainerId, usize>,
    /// Slots not currently executing (warm idle + empty).
    pub available_slots: usize,
    /// Total worker slots on the node.
    pub total_slots: usize,
    /// Tasks already queued at the manager beyond running ones
    /// (prefetched; §6.2). Routing counts these against availability.
    pub queued: usize,
    /// Endpoint whose data-fabric store is local to this manager's node
    /// (`None` = unadvertised). [`LocalityAware`] prefers managers whose
    /// endpoint owns a task's by-ref input, so the frame resolves from
    /// the local store instead of a cross-endpoint fetch (the FDN
    /// "data-aware delivery" signal).
    pub endpoint: Option<EndpointId>,
    /// The manager's estimated cold-start cost in seconds (measured
    /// EWMA from its pool when available, else the profile model's
    /// mean; 0.0 = unknown). Tier-3 placement — where every candidate
    /// cold-starts — prefers cheaper starters.
    pub cold_start_est_s: f64,
}

/// Max replica endpoints carried as routing hints (keeps `RouteHints`
/// `Copy` for the per-task hot path; refs rarely list more).
pub const MAX_REPLICA_HINTS: usize = 3;

/// Data-locality hints for one routing decision, derived from the task
/// being routed: who owns its by-ref input frame, and which endpoints
/// hold replicas of it (§5 replication) — a replica holder is exactly
/// as data-local as the owner, since the worker's fabric resolve is a
/// local hit at either.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RouteHints {
    /// Endpoint owning the task's [`crate::datastore::DataRef`] input,
    /// if the task dispatches by reference.
    pub data_owner: Option<EndpointId>,
    /// Endpoints holding replicas of the input frame (first
    /// [`MAX_REPLICA_HINTS`] of the ref's replica set, owner excluded).
    pub data_replicas: [Option<EndpointId>; MAX_REPLICA_HINTS],
}

impl RouteHints {
    /// Hints for a task (the agent's per-task call site).
    pub fn for_task(task: &crate::common::task::Task) -> Self {
        let mut h = RouteHints {
            data_owner: task.input_ref.as_ref().map(|r| r.owner),
            data_replicas: [None; MAX_REPLICA_HINTS],
        };
        if let Some(r) = &task.input_ref {
            for (slot, rep) in h.data_replicas.iter_mut().zip(r.replicas.iter()) {
                *slot = Some(*rep);
            }
        }
        h
    }

    /// Every endpoint where the task's input frame already lives
    /// (owner first, then replica holders in preference order).
    pub fn locals(&self) -> impl Iterator<Item = EndpointId> + '_ {
        self.data_owner.into_iter().chain(self.data_replicas.iter().filter_map(|r| *r))
    }

    /// Whether a manager advertising `ep` would resolve the task's
    /// input from its node-local store.
    pub fn is_local(&self, ep: Option<EndpointId>) -> bool {
        match ep {
            Some(e) => self.locals().any(|l| l == e),
            None => false,
        }
    }
}

impl ManagerView {
    /// Effective free capacity after queued-but-unstarted tasks.
    pub fn effective_capacity(&self) -> usize {
        self.available_slots.saturating_sub(self.queued)
    }

    fn has_capacity(&self, prefetch: usize) -> bool {
        // A manager may accept up to `prefetch` tasks beyond its current
        // availability (§6.2 prefetching).
        self.available_slots + prefetch > self.queued
    }
}

/// A routing decision for one task.
pub trait Scheduler: Send {
    /// Route a task needing `container` to one of `managers`.
    /// `None` when no manager can accept work.
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId>;

    fn name(&self) -> &'static str;

    /// Whether managers should warm-match queued tasks to idle warm
    /// containers (§6.2: "warming-aware routing involves coordination
    /// between managers and funcX agent"). The non-warming-aware
    /// baseline serves its queue FIFO regardless of container types.
    fn warm_matching(&self) -> bool {
        false
    }

    /// Extra tasks a manager may queue beyond availability (§6.2
    /// prefetch). The [`RoutingTable`] must be built with the same value
    /// so its capacity filter matches the policy's.
    fn prefetch(&self) -> usize {
        0
    }

    /// Route using an incrementally-maintained [`RoutingTable`]. The
    /// default is the O(M) scan over the table's views, so every policy
    /// works unchanged; policies with an indexed implementation
    /// ([`WarmingAware`]) override this with O(log M) lookups.
    fn route_indexed(
        &mut self,
        container: Option<ContainerId>,
        table: &RoutingTable,
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        self.route(container, table.views(), rng)
    }

    /// Route with data-locality hints. Policies that ignore locality
    /// (everything except [`LocalityAware`]) delegate to [`Scheduler::route`],
    /// so existing schedulers behave identically under the hinted call
    /// sites.
    fn route_hinted(
        &mut self,
        container: Option<ContainerId>,
        hints: RouteHints,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        let _ = hints;
        self.route(container, managers, rng)
    }

    /// Hinted routing over a [`RoutingTable`] (the agent's per-task hot
    /// path). Defaults to [`Scheduler::route_indexed`], ignoring hints.
    fn route_hinted_indexed(
        &mut self,
        container: Option<ContainerId>,
        hints: RouteHints,
        table: &RoutingTable,
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        let _ = hints;
        self.route_indexed(container, table, rng)
    }
}

/// The paper's warming-aware scheduler (§6.2).
pub struct WarmingAware {
    /// Extra tasks a manager may queue beyond availability.
    pub prefetch: usize,
}

impl Default for WarmingAware {
    fn default() -> Self {
        WarmingAware { prefetch: 0 }
    }
}

/// Type-salted stable tie-break (see tier 2 below): equal-looking
/// managers resolve the same way for the same type, so types specialise
/// onto managers and queues stay aligned with warm sets. Shared with the
/// [`RoutingTable`]'s tier-2 index keys so indexed routing agrees.
fn type_salt(c: ContainerId, m: ManagerId) -> u64 {
    let h = (c.0 .0 as u64) ^ ((c.0 .0 >> 64) as u64) ^ (m.0 .0 as u64);
    h.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Tier 3: no container of the type anywhere — place the type's *first*
/// container on a type-consistent manager (hash + linear probe over
/// capacity) so subsequent tasks of the type concentrate instead of
/// scattering. Plays the role of the paper's random fallback while
/// keeping the choice stable per type. O(1) expected while capacity is
/// plentiful (the common case); shared by the scan and indexed paths.
fn hash_probe(c: ContainerId, managers: &[ManagerView], prefetch: usize) -> Option<ManagerId> {
    if managers.is_empty() {
        return None;
    }
    let h = (c.0 .0 as u64) ^ ((c.0 .0 >> 64) as u64);
    let start = (h % managers.len() as u64) as usize;
    // Every candidate here cold-starts the type, so managers advertising
    // a cheaper (measured) start cost win; quantizing to whole
    // milliseconds keeps the ordering stable against estimate jitter,
    // and probe order breaks ties so placement stays type-consistent.
    // With no estimates advertised (all 0.0) this degenerates to the
    // plain first-fit probe.
    let mut best: Option<(u64, ManagerId)> = None;
    for i in 0..managers.len() {
        let m = &managers[(start + i) % managers.len()];
        if !m.has_capacity(prefetch) {
            continue;
        }
        let est_ms = (m.cold_start_est_s.max(0.0) * 1000.0).round() as u64;
        match &best {
            Some((b, _)) if est_ms >= *b => {}
            _ => best = Some((est_ms, m.id)),
        }
    }
    best.map(|(_, id)| id)
}

impl Scheduler for WarmingAware {
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        if let Some(c) = container {
            // Tier 1: a warm *idle* container of the type exists — route
            // there for an immediate warm start, tie-broken by most
            // available workers (the paper's load-balance rule). The id
            // is the final key component so the maximum is unique and
            // the indexed path picks the identical manager.
            let tier1 = managers
                .iter()
                .filter(|m| m.warm_idle.get(&c).copied().unwrap_or(0) > 0)
                .filter(|m| m.has_capacity(self.prefetch))
                .max_by_key(|m| {
                    (
                        m.warm_idle.get(&c).copied().unwrap_or(0),
                        m.effective_capacity(),
                        Reverse(m.queued),
                        m.id,
                    )
                });
            if let Some(m) = tier1 {
                return Some(m.id);
            }
            // Tier 2: containers of the type are deployed but busy —
            // queue behind them (prefetch), preferring the manager with
            // the most of them (reinforces manager/type affinity so
            // queues stay aligned with warm sets).
            let tier2 = managers
                .iter()
                .filter(|m| m.deployed.get(&c).copied().unwrap_or(0) > 0)
                .filter(|m| m.has_capacity(self.prefetch))
                .max_by_key(|m| {
                    (
                        m.deployed.get(&c).copied().unwrap_or(0),
                        m.effective_capacity(),
                        type_salt(c, m.id),
                        m.id,
                    )
                });
            if let Some(m) = tier2 {
                return Some(m.id);
            }
            return hash_probe(c, managers, self.prefetch);
        }
        // Container-less tasks: random among managers with capacity
        // (paper: "the funcX agent chooses one manager at random").
        random_with_capacity(managers, self.prefetch, rng)
    }

    fn name(&self) -> &'static str {
        "warming-aware"
    }

    fn warm_matching(&self) -> bool {
        true
    }

    fn prefetch(&self) -> usize {
        self.prefetch
    }

    /// O(log M) amortized: tier 1/2 answers come straight off the
    /// table's per-type ordered indexes; the fallbacks are O(1) expected
    /// while capacity is plentiful. Decisions are identical to
    /// [`WarmingAware::route`] (pinned by `proptests::indexed_matches_scan`).
    fn route_indexed(
        &mut self,
        container: Option<ContainerId>,
        table: &RoutingTable,
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        debug_assert_eq!(
            table.prefetch(),
            self.prefetch,
            "routing table built with a different prefetch than the policy"
        );
        if let Some(c) = container {
            // The scan path consumes no RNG for container tasks, so this
            // path must not either (shared-RNG streams stay identical).
            if !table.any_capacity() {
                return None;
            }
            if let Some(m) = table.best_warm(c) {
                return Some(m);
            }
            if let Some(m) = table.best_deployed(c) {
                return Some(m);
            }
            return hash_probe(c, table.views(), self.prefetch);
        }
        // Container-less: delegate to the exact scan routine (same single
        // RNG draw even when nothing has capacity), keeping the RNG
        // stream — not just the decision — identical to `route`.
        random_with_capacity(table.views(), self.prefetch, rng)
    }
}

/// Telemetry for [`LocalityAware`]: where hinted tasks actually landed.
#[derive(Default)]
pub struct LocalityStats {
    /// Hinted tasks routed to a manager on the ref owner's endpoint.
    pub local_routes: AtomicU64,
    /// Hinted tasks that had to route off the owner endpoint.
    pub remote_routes: AtomicU64,
}

impl LocalityStats {
    /// Export both counters into a metrics snapshot.
    pub fn fill(&self, b: &mut crate::metrics::SnapshotBuilder, dims: &[(&str, &str)]) {
        b.counter(
            "funcx_route_local_total",
            dims,
            self.local_routes.load(Ordering::Relaxed),
        );
        b.counter(
            "funcx_route_remote_total",
            dims,
            self.remote_routes.load(Ordering::Relaxed),
        );
    }
}

/// Locality-aware routing (§5 + FDN "data-aware delivery"): wraps
/// [`WarmingAware`] and, for tasks carrying a by-ref input, prefers
/// managers on the ref owner's endpoint *within* each warming tier — a
/// warm container elsewhere still beats a cold start next to the data
/// (cold starts cost seconds, a peer fetch costs milliseconds), but
/// whenever the warming tiers tie, the task lands where its bytes
/// already live and the worker's fabric resolve is a local hit.
///
/// Unhinted tasks (inline inputs) route exactly as [`WarmingAware`].
/// The indexed path rides the [`RoutingTable`]'s per-endpoint owner
/// indexes, staying O(log M) per decision, and makes decisions
/// identical to the scan (pinned by
/// `proptests::locality_indexed_matches_scan`).
pub struct LocalityAware {
    pub inner: WarmingAware,
    pub stats: Arc<LocalityStats>,
}

impl LocalityAware {
    pub fn new(prefetch: usize) -> Self {
        LocalityAware {
            inner: WarmingAware { prefetch },
            stats: Arc::new(LocalityStats::default()),
        }
    }

    fn note(&self, hints: &RouteHints, picked_ep: Option<EndpointId>) {
        if hints.is_local(picked_ep) {
            self.stats.local_routes.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.remote_routes.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The reference scan (O(M)): same tiers as [`WarmingAware::route`],
    /// with a data-local pass *inside* each tier before the global one.
    /// "Local" is the hint's whole local set — the ref owner and every
    /// replica holder rank equally; the tier key breaks ties among them.
    /// Consumes RNG exactly like the inner scan (none for container
    /// tasks; one draw for the container-less random fallback).
    fn route_scan(
        &self,
        container: Option<ContainerId>,
        hints: &RouteHints,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        let prefetch = self.inner.prefetch;
        if let Some(c) = container {
            // Tier 1: warm idle container of the type — data-local
            // candidates win the tier; the keys match the scan within
            // each pass, so indexed lookups reproduce this exactly.
            for local_only in [true, false] {
                let pick = managers
                    .iter()
                    .filter(|m| m.warm_idle.get(&c).copied().unwrap_or(0) > 0)
                    .filter(|m| m.has_capacity(prefetch))
                    .filter(|m| !local_only || hints.is_local(m.endpoint))
                    .max_by_key(|m| {
                        (
                            m.warm_idle.get(&c).copied().unwrap_or(0),
                            m.effective_capacity(),
                            Reverse(m.queued),
                            m.id,
                        )
                    });
                if let Some(m) = pick {
                    return Some(m.id);
                }
            }
            // Tier 2: type deployed but busy — same locality-first order.
            for local_only in [true, false] {
                let pick = managers
                    .iter()
                    .filter(|m| m.deployed.get(&c).copied().unwrap_or(0) > 0)
                    .filter(|m| m.has_capacity(prefetch))
                    .filter(|m| !local_only || hints.is_local(m.endpoint))
                    .max_by_key(|m| {
                        (
                            m.deployed.get(&c).copied().unwrap_or(0),
                            m.effective_capacity(),
                            type_salt(c, m.id),
                            m.id,
                        )
                    });
                if let Some(m) = pick {
                    return Some(m.id);
                }
            }
            // Tier 3: the type is nowhere — every placement cold-starts,
            // so data gravity decides: any data-local manager with
            // capacity (most capacity first), then the type-consistent
            // probe.
            if let Some(m) = managers
                .iter()
                .filter(|m| m.has_capacity(prefetch))
                .filter(|m| hints.is_local(m.endpoint))
                .max_by_key(|m| (m.effective_capacity(), m.id))
            {
                return Some(m.id);
            }
            return hash_probe(c, managers, prefetch);
        }
        // Container-less: data-local manager with the most capacity,
        // else the inner policy's random fallback (one RNG draw).
        if let Some(m) = managers
            .iter()
            .filter(|m| m.has_capacity(prefetch))
            .filter(|m| hints.is_local(m.endpoint))
            .max_by_key(|m| (m.effective_capacity(), m.id))
        {
            return Some(m.id);
        }
        random_with_capacity(managers, prefetch, rng)
    }
}

/// Max over the hint's local endpoints (owner + replica holders) of
/// each per-endpoint index's best candidate, compared under the tier's
/// own ordering key recomputed from the view: the indexed analogue of
/// the scan's `hints.is_local` pass, still O(R log M) with R bounded by
/// [`MAX_REPLICA_HINTS`] + 1. A single-endpoint hint degenerates to the
/// plain owner-index lookup.
fn best_over_locals<K: Ord>(
    table: &RoutingTable,
    hints: &RouteHints,
    mut pick: impl FnMut(EndpointId) -> Option<ManagerId>,
    mut key: impl FnMut(&ManagerView) -> K,
) -> Option<ManagerId> {
    let mut best: Option<(K, ManagerId)> = None;
    for ep in hints.locals() {
        let Some(id) = pick(ep) else { continue };
        let Some(v) = table.view(id) else { continue };
        let k = key(v);
        if best.as_ref().map_or(true, |(bk, _)| k > *bk) {
            best = Some((k, id));
        }
    }
    best.map(|(_, id)| id)
}

impl Scheduler for LocalityAware {
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        self.inner.route(container, managers, rng)
    }

    fn name(&self) -> &'static str {
        "locality-aware"
    }

    fn warm_matching(&self) -> bool {
        true
    }

    fn prefetch(&self) -> usize {
        self.inner.prefetch
    }

    fn route_indexed(
        &mut self,
        container: Option<ContainerId>,
        table: &RoutingTable,
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        self.inner.route_indexed(container, table, rng)
    }

    fn route_hinted(
        &mut self,
        container: Option<ContainerId>,
        hints: RouteHints,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        if hints.data_owner.is_none() {
            return self.inner.route(container, managers, rng);
        }
        let picked = self.route_scan(container, &hints, managers, rng);
        if let Some(id) = picked {
            let ep = managers.iter().find(|m| m.id == id).and_then(|m| m.endpoint);
            self.note(&hints, ep);
        }
        picked
    }

    /// O(log M): tier answers come off the table's per-endpoint owner
    /// indexes first, then the global ones — identical decisions to
    /// [`LocalityAware::route_scan`] (proptest-pinned).
    fn route_hinted_indexed(
        &mut self,
        container: Option<ContainerId>,
        hints: RouteHints,
        table: &RoutingTable,
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        if hints.data_owner.is_none() {
            return self.inner.route_indexed(container, table, rng);
        }
        debug_assert_eq!(
            table.prefetch(),
            self.inner.prefetch,
            "routing table built with a different prefetch than the policy"
        );
        let prefetch = self.inner.prefetch;
        let picked = if let Some(c) = container {
            if !table.any_capacity() {
                None
            } else if let Some(m) = best_over_locals(
                table,
                &hints,
                |ep| table.best_warm_local(ep, c),
                |v| {
                    (
                        v.warm_idle.get(&c).copied().unwrap_or(0),
                        v.effective_capacity(),
                        Reverse(v.queued),
                        v.id,
                    )
                },
            ) {
                Some(m)
            } else if let Some(m) = table.best_warm(c) {
                Some(m)
            } else if let Some(m) = best_over_locals(
                table,
                &hints,
                |ep| table.best_deployed_local(ep, c),
                |v| {
                    (
                        v.deployed.get(&c).copied().unwrap_or(0),
                        v.effective_capacity(),
                        type_salt(c, v.id),
                        v.id,
                    )
                },
            ) {
                Some(m)
            } else if let Some(m) = table.best_deployed(c) {
                Some(m)
            } else if let Some(m) = best_over_locals(
                table,
                &hints,
                |ep| table.max_capacity_local(ep),
                |v| (v.effective_capacity(), v.id),
            ) {
                Some(m)
            } else {
                hash_probe(c, table.views(), prefetch)
            }
        } else if let Some(m) = best_over_locals(
            table,
            &hints,
            |ep| table.max_capacity_local(ep),
            |v| (v.effective_capacity(), v.id),
        ) {
            Some(m)
        } else {
            random_with_capacity(table.views(), prefetch, rng)
        };
        if let Some(id) = picked {
            self.note(&hints, table.view(id).and_then(|v| v.endpoint));
        }
        picked
    }
}

/// The non-warming-aware baseline (Figs. 6–7): uniformly random among
/// managers with capacity, ignoring container warmth.
#[derive(Default)]
pub struct Randomized {
    pub prefetch: usize,
}

impl Scheduler for Randomized {
    fn route(
        &mut self,
        _container: Option<ContainerId>,
        managers: &[ManagerView],
        rng: &mut Rng,
    ) -> Option<ManagerId> {
        random_with_capacity(managers, self.prefetch, rng)
    }

    fn name(&self) -> &'static str {
        "random"
    }

    fn prefetch(&self) -> usize {
        self.prefetch
    }
}

/// Round-robin over managers with capacity (§6.2 lists it as an
/// alternative policy).
#[derive(Default)]
pub struct RoundRobin {
    cursor: usize,
    pub prefetch: usize,
}

impl Scheduler for RoundRobin {
    fn route(
        &mut self,
        _container: Option<ContainerId>,
        managers: &[ManagerView],
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        if managers.is_empty() {
            return None;
        }
        for i in 0..managers.len() {
            let m = &managers[(self.cursor + i) % managers.len()];
            if m.has_capacity(self.prefetch) {
                self.cursor = (self.cursor + i + 1) % managers.len();
                return Some(m.id);
            }
        }
        None
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn prefetch(&self) -> usize {
        self.prefetch
    }
}

/// Bin-packing: fill the *least*-available manager that still has
/// capacity, concentrating load so idle nodes can be released (§6.2
/// alternative; pairs with the elastic strategy's scale-down).
#[derive(Default)]
pub struct BinPacking {
    pub prefetch: usize,
}

impl Scheduler for BinPacking {
    fn route(
        &mut self,
        _container: Option<ContainerId>,
        managers: &[ManagerView],
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        managers
            .iter()
            .filter(|m| m.has_capacity(self.prefetch))
            .min_by_key(|m| (m.effective_capacity(), m.id))
            .map(|m| m.id)
    }

    fn name(&self) -> &'static str {
        "bin-packing"
    }

    fn prefetch(&self) -> usize {
        self.prefetch
    }

    /// O(log M): the least-loaded eligible manager is the first entry of
    /// the table's capacity-ordered index — the same (effective
    /// capacity, id) key the scan minimises, so decisions are identical
    /// (pinned by `proptests::binpacking_indexed_matches_scan`).
    fn route_indexed(
        &mut self,
        _container: Option<ContainerId>,
        table: &RoutingTable,
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        debug_assert_eq!(
            table.prefetch(),
            self.prefetch,
            "routing table built with a different prefetch than the policy"
        );
        table.min_capacity()
    }
}

/// Kubernetes-endpoint routing (§6.2): on a K8s deployment each manager
/// pod is bound to ONE container image, so "the agent simply needs to
/// route tasks to corresponding managers" — pick among the managers
/// pinned to the task's type (most available first); container-less
/// tasks cannot run on a pinned pod.
pub struct KubernetesRouting {
    pub prefetch: usize,
}

impl KubernetesRouting {
    pub fn new(prefetch: usize) -> Self {
        KubernetesRouting { prefetch }
    }
}

impl Scheduler for KubernetesRouting {
    fn route(
        &mut self,
        container: Option<ContainerId>,
        managers: &[ManagerView],
        _rng: &mut Rng,
    ) -> Option<ManagerId> {
        let c = container?;
        managers
            .iter()
            // A pod serves exactly one image: its deployed census is
            // {c: n} (or empty before the first task lands).
            .filter(|m| {
                m.deployed.keys().all(|k| *k == c)
                    && (m.deployed.contains_key(&c) || m.deployed.is_empty())
            })
            .filter(|m| m.has_capacity(self.prefetch))
            .max_by_key(|m| (m.deployed.contains_key(&c), m.effective_capacity()))
            .map(|m| m.id)
    }

    fn name(&self) -> &'static str {
        "kubernetes"
    }

    fn warm_matching(&self) -> bool {
        true
    }

    fn prefetch(&self) -> usize {
        self.prefetch
    }
}

fn random_with_capacity(
    managers: &[ManagerView],
    prefetch: usize,
    rng: &mut Rng,
) -> Option<ManagerId> {
    // Random-start first-fit: O(1) with plentiful capacity, O(n) worst
    // case, no allocation, one RNG draw (this runs once per routed task —
    // the agent hot path). Start position is uniform, so load spreads
    // evenly even though the scan is deterministic from there.
    if managers.is_empty() {
        return None;
    }
    let start = rng.below(managers.len());
    for i in 0..managers.len() {
        let m = &managers[(start + i) % managers.len()];
        if m.has_capacity(prefetch) {
            return Some(m.id);
        }
    }
    None
}

// ---- the routing table -----------------------------------------------------

/// Tier-1 ordering: (warm idle of the type, effective capacity, fewest
/// queued, id). The id makes the maximum unique, so `set.last()` equals
/// the scan's `max_by_key`.
type WarmKey = (usize, usize, Reverse<usize>, ManagerId);
/// Tier-2 ordering: (deployed of the type, effective capacity,
/// type-salt, id).
type DeployedKey = (usize, usize, u64, ManagerId);

/// The index entries a view contributes, or `None` if it fails the
/// capacity filter (ineligible managers are simply absent from every
/// index, which is exactly the scan's `has_capacity` filter).
#[allow(clippy::type_complexity)]
fn index_entries(
    v: &ManagerView,
    prefetch: usize,
) -> Option<(Vec<(ContainerId, WarmKey)>, Vec<(ContainerId, DeployedKey)>)> {
    if !v.has_capacity(prefetch) {
        return None;
    }
    let eff = v.effective_capacity();
    let warm = v
        .warm_idle
        .iter()
        .filter(|(_, n)| **n > 0)
        .map(|(c, n)| (*c, (*n, eff, Reverse(v.queued), v.id)))
        .collect();
    let deployed = v
        .deployed
        .iter()
        .filter(|(_, n)| **n > 0)
        .map(|(c, n)| (*c, (*n, eff, type_salt(*c, v.id), v.id)))
        .collect();
    Some((warm, deployed))
}

/// Incrementally-maintained routing state: the managers' views plus the
/// per-type ordered indexes and a capacity count that make
/// [`WarmingAware`] routing O(log M) amortized instead of an O(M) scan
/// per task (module docs, "Indexed routing"). Owned by whoever drives
/// dispatch — the live agent and the simulated endpoint both keep one —
/// and mutated *only* through [`RoutingTable::upsert`] /
/// [`RoutingTable::update`] / [`RoutingTable::remove`] so the indexes
/// never drift from the views.
pub struct RoutingTable {
    prefetch: usize,
    views: Vec<ManagerView>,
    index_of: HashMap<ManagerId, usize>,
    warm_index: HashMap<ContainerId, BTreeSet<WarmKey>>,
    deployed_index: HashMap<ContainerId, BTreeSet<DeployedKey>>,
    /// Eligible managers ordered by (effective capacity, id) — the
    /// bin-packing fill order; `first()` is the least-loaded manager
    /// still passing the capacity filter.
    capacity_index: BTreeSet<(usize, ManagerId)>,
    /// Owner indexes: the same three orderings restricted to managers
    /// advertising a given endpoint, so [`LocalityAware`] answers
    /// "best candidate *on the ref owner's endpoint*" in O(log M)
    /// without scanning. Managers with `endpoint: None` appear only in
    /// the global indexes.
    warm_local: HashMap<(EndpointId, ContainerId), BTreeSet<WarmKey>>,
    deployed_local: HashMap<(EndpointId, ContainerId), BTreeSet<DeployedKey>>,
    capacity_local: HashMap<EndpointId, BTreeSet<(usize, ManagerId)>>,
    /// Managers currently passing the capacity filter.
    with_capacity: usize,
}

/// Remove one key from a keyed index set, dropping the set when it
/// empties (ineligible entries are simply absent from every index).
fn index_remove<K: Eq + std::hash::Hash, V: Ord>(map: &mut HashMap<K, BTreeSet<V>>, k: K, v: &V) {
    let now_empty = match map.get_mut(&k) {
        Some(set) => {
            let removed = set.remove(v);
            debug_assert!(removed, "routing index out of sync");
            set.is_empty()
        }
        None => false,
    };
    if now_empty {
        map.remove(&k);
    }
}

impl RoutingTable {
    /// An empty table. `prefetch` must match the routing policy's (the
    /// capacity filter depends on it).
    pub fn new(prefetch: usize) -> Self {
        RoutingTable {
            prefetch,
            views: Vec::new(),
            index_of: HashMap::new(),
            warm_index: HashMap::new(),
            deployed_index: HashMap::new(),
            capacity_index: BTreeSet::new(),
            warm_local: HashMap::new(),
            deployed_local: HashMap::new(),
            capacity_local: HashMap::new(),
            with_capacity: 0,
        }
    }

    /// Bulk-build from a set of views (benches, tests).
    pub fn with_views(prefetch: usize, views: Vec<ManagerView>) -> Self {
        let mut t = Self::new(prefetch);
        for v in views {
            t.upsert(v);
        }
        t
    }

    pub fn prefetch(&self) -> usize {
        self.prefetch
    }

    pub fn len(&self) -> usize {
        self.views.len()
    }

    pub fn is_empty(&self) -> bool {
        self.views.is_empty()
    }

    /// The views, for scan-based policies and the probe fallbacks.
    pub fn views(&self) -> &[ManagerView] {
        &self.views
    }

    pub fn view(&self, id: ManagerId) -> Option<&ManagerView> {
        self.index_of.get(&id).map(|&i| &self.views[i])
    }

    /// Any manager with capacity at all? O(1).
    pub fn any_capacity(&self) -> bool {
        self.with_capacity > 0
    }

    /// Insert a new view or replace an existing manager's view wholesale.
    pub fn upsert(&mut self, view: ManagerView) {
        match self.index_of.get(&view.id).copied() {
            Some(i) => {
                self.deindex(i);
                self.views[i] = view;
                self.reindex(i);
            }
            None => {
                let i = self.views.len();
                self.index_of.insert(view.id, i);
                self.views.push(view);
                self.reindex(i);
            }
        }
    }

    /// Upsert that skips the reindex when the view is unchanged — the
    /// live agent refreshes every manager's view once per dispatch pass,
    /// and steady-state managers don't churn the indexes.
    pub fn sync(&mut self, view: ManagerView) {
        if let Some(&i) = self.index_of.get(&view.id) {
            if self.views[i] == view {
                return;
            }
        }
        self.upsert(view);
    }

    /// Remove a manager (node released / lost).
    pub fn remove(&mut self, id: ManagerId) -> Option<ManagerView> {
        let i = self.index_of.get(&id).copied()?;
        self.deindex(i);
        let removed = self.views.swap_remove(i);
        self.index_of.remove(&id);
        if i < self.views.len() {
            // Index keys don't encode positions, so only the slot map of
            // the swapped-in tail view needs fixing.
            self.index_of.insert(self.views[i].id, i);
        }
        Some(removed)
    }

    /// Apply a point mutation to one manager's view (slot acquired or
    /// released, task queued, container deployed/evicted), keeping the
    /// indexes consistent. O(T·log M) for a manager hosting T types.
    pub fn update(&mut self, id: ManagerId, f: impl FnOnce(&mut ManagerView)) {
        if let Some(&i) = self.index_of.get(&id) {
            self.deindex(i);
            f(&mut self.views[i]);
            self.reindex(i);
        } else {
            debug_assert!(false, "update of unknown manager {id}");
        }
    }

    /// Best tier-1 candidate for `c`: the eligible manager maximising
    /// (warm idle, effective capacity, fewest queued, id). O(log M).
    pub fn best_warm(&self, c: ContainerId) -> Option<ManagerId> {
        self.warm_index.get(&c).and_then(|s| s.iter().next_back()).map(|k| k.3)
    }

    /// Best tier-2 candidate for `c`: the eligible manager maximising
    /// (deployed, effective capacity, type-salt, id). O(log M).
    pub fn best_deployed(&self, c: ContainerId) -> Option<ManagerId> {
        self.deployed_index.get(&c).and_then(|s| s.iter().next_back()).map(|k| k.3)
    }

    /// The eligible manager minimising (effective capacity, id) — the
    /// bin-packing pick. O(log M).
    pub fn min_capacity(&self) -> Option<ManagerId> {
        self.capacity_index.iter().next().map(|k| k.1)
    }

    /// Best tier-1 candidate for `c` *on endpoint `ep`* — same ordering
    /// as [`RoutingTable::best_warm`], restricted to the owner. O(log M).
    pub fn best_warm_local(&self, ep: EndpointId, c: ContainerId) -> Option<ManagerId> {
        self.warm_local.get(&(ep, c)).and_then(|s| s.iter().next_back()).map(|k| k.3)
    }

    /// Best tier-2 candidate for `c` on endpoint `ep`. O(log M).
    pub fn best_deployed_local(&self, ep: EndpointId, c: ContainerId) -> Option<ManagerId> {
        self.deployed_local.get(&(ep, c)).and_then(|s| s.iter().next_back()).map(|k| k.3)
    }

    /// The eligible manager on endpoint `ep` maximising (effective
    /// capacity, id) — the locality fallback pick. O(log M).
    pub fn max_capacity_local(&self, ep: EndpointId) -> Option<ManagerId> {
        self.capacity_local.get(&ep).and_then(|s| s.iter().next_back()).map(|k| k.1)
    }

    fn deindex(&mut self, i: usize) {
        if let Some((warm, deployed)) = index_entries(&self.views[i], self.prefetch) {
            self.with_capacity -= 1;
            let cap_key = (self.views[i].effective_capacity(), self.views[i].id);
            let removed = self.capacity_index.remove(&cap_key);
            debug_assert!(removed, "capacity index out of sync");
            let ep = self.views[i].endpoint;
            if let Some(ep) = ep {
                index_remove(&mut self.capacity_local, ep, &cap_key);
            }
            for (c, key) in warm {
                index_remove(&mut self.warm_index, c, &key);
                if let Some(ep) = ep {
                    index_remove(&mut self.warm_local, (ep, c), &key);
                }
            }
            for (c, key) in deployed {
                index_remove(&mut self.deployed_index, c, &key);
                if let Some(ep) = ep {
                    index_remove(&mut self.deployed_local, (ep, c), &key);
                }
            }
        }
    }

    fn reindex(&mut self, i: usize) {
        if let Some((warm, deployed)) = index_entries(&self.views[i], self.prefetch) {
            self.with_capacity += 1;
            let cap_key = (self.views[i].effective_capacity(), self.views[i].id);
            self.capacity_index.insert(cap_key);
            let ep = self.views[i].endpoint;
            if let Some(ep) = ep {
                self.capacity_local.entry(ep).or_default().insert(cap_key);
            }
            for (c, key) in warm {
                self.warm_index.entry(c).or_default().insert(key);
                if let Some(ep) = ep {
                    self.warm_local.entry((ep, c)).or_default().insert(key);
                }
            }
            for (c, key) in deployed {
                self.deployed_index.entry(c).or_default().insert(key);
                if let Some(ep) = ep {
                    self.deployed_local.entry((ep, c)).or_default().insert(key);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(bits: u128, warm: &[(u128, usize)], avail: usize, total: usize) -> ManagerView {
        ManagerView {
            id: ManagerId::from_bits(bits),
            deployed: warm
                .iter()
                .map(|(c, n)| (ContainerId::from_bits(*c), *n))
                .collect(),
            warm_idle: warm
                .iter()
                .map(|(c, n)| (ContainerId::from_bits(*c), *n))
                .collect(),
            available_slots: avail,
            total_slots: total,
            queued: 0,
            cold_start_est_s: 0.0,
            endpoint: None,
        }
    }

    fn on_ep(mut v: ManagerView, ep: u128) -> ManagerView {
        v.endpoint = Some(EndpointId::from_bits(ep));
        v
    }

    #[test]
    fn warming_aware_prefers_warm_manager() {
        let managers = vec![
            mgr(1, &[], 10, 10),
            mgr(2, &[(7, 1)], 5, 10), // only manager with warm type-7
        ];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            assert_eq!(
                s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
                Some(ManagerId::from_bits(2))
            );
        }
    }

    #[test]
    fn warming_aware_ties_broken_by_availability() {
        // Both have warm type-7; pick the one with more available workers.
        let managers = vec![mgr(1, &[(7, 1)], 2, 10), mgr(2, &[(7, 1)], 8, 10)];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(1);
        assert_eq!(
            s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
            Some(ManagerId::from_bits(2))
        );
    }

    #[test]
    fn warming_aware_fallback_is_type_consistent() {
        // No warm containers anywhere: the fallback picks a manager with
        // capacity, *stable per container type* so a type's containers
        // concentrate rather than scatter.
        let managers = vec![mgr(1, &[], 5, 10), mgr(2, &[], 5, 10)];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(2);
        let c = ContainerId::from_bits(7);
        let first = s.route(Some(c), &managers, &mut rng).unwrap();
        for _ in 0..50 {
            assert_eq!(s.route(Some(c), &managers, &mut rng), Some(first));
        }
        // Many distinct types spread across managers.
        let mut seen = std::collections::HashSet::new();
        for t in 0..64u128 {
            seen.insert(
                s.route(Some(ContainerId::from_bits(t + 100)), &managers, &mut rng).unwrap(),
            );
        }
        assert_eq!(seen.len(), 2, "distinct types should spread over managers");
        // Container-less tasks still route randomly among capacity.
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(s.route(None, &managers, &mut rng).unwrap());
        }
        assert_eq!(seen.len(), 2);
    }

    #[test]
    fn no_capacity_returns_none() {
        let managers = vec![mgr(1, &[], 0, 10)];
        let mut rng = Rng::new(3);
        assert!(WarmingAware::default()
            .route(Some(ContainerId::from_bits(7)), &managers, &mut rng)
            .is_none());
        assert!(Randomized::default().route(None, &managers, &mut rng).is_none());
        assert!(RoundRobin::default().route(None, &managers, &mut rng).is_none());
        assert!(BinPacking::default().route(None, &managers, &mut rng).is_none());
    }

    #[test]
    fn warm_but_full_manager_not_chosen() {
        // Manager 2 has the warm container but zero capacity.
        let managers = vec![mgr(1, &[], 5, 10), mgr(2, &[(7, 1)], 0, 10)];
        let mut s = WarmingAware::default();
        let mut rng = Rng::new(4);
        assert_eq!(
            s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
            Some(ManagerId::from_bits(1))
        );
    }

    #[test]
    fn prefetch_extends_capacity() {
        let mut m = mgr(1, &[(7, 1)], 1, 10);
        m.queued = 1; // availability exhausted by queued task
        let managers = vec![m];
        let mut rng = Rng::new(5);
        // Without prefetch, no capacity.
        assert!(WarmingAware { prefetch: 0 }
            .route(Some(ContainerId::from_bits(7)), &managers, &mut rng)
            .is_none());
        // With prefetch, the manager can queue ahead.
        assert!(WarmingAware { prefetch: 2 }
            .route(Some(ContainerId::from_bits(7)), &managers, &mut rng)
            .is_some());
    }

    #[test]
    fn round_robin_cycles() {
        let managers = vec![mgr(1, &[], 5, 5), mgr(2, &[], 5, 5), mgr(3, &[], 5, 5)];
        let mut s = RoundRobin::default();
        let mut rng = Rng::new(6);
        let picks: Vec<_> =
            (0..6).map(|_| s.route(None, &managers, &mut rng).unwrap().0 .0).collect();
        assert_eq!(picks[0..3], picks[3..6], "cycle repeats");
        let unique: std::collections::HashSet<_> = picks[0..3].iter().collect();
        assert_eq!(unique.len(), 3);
    }

    #[test]
    fn kubernetes_routes_to_pinned_pods() {
        // Pod 1 pinned to image 7, pod 2 pinned to image 9, pod 3 fresh.
        let managers = vec![
            mgr(1, &[(7, 4)], 2, 4),
            mgr(2, &[(9, 4)], 4, 4),
            mgr(3, &[], 4, 4),
        ];
        let mut s = KubernetesRouting::new(0);
        let mut rng = Rng::new(1);
        assert_eq!(
            s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng),
            Some(ManagerId::from_bits(1))
        );
        assert_eq!(
            s.route(Some(ContainerId::from_bits(9)), &managers, &mut rng),
            Some(ManagerId::from_bits(2))
        );
        // Unknown image: only the fresh pod is eligible.
        assert_eq!(
            s.route(Some(ContainerId::from_bits(5)), &managers, &mut rng),
            Some(ManagerId::from_bits(3))
        );
        // Container-less tasks can't run on pinned pods.
        assert_eq!(s.route(None, &managers, &mut rng), None);
    }

    #[test]
    fn kubernetes_respects_capacity() {
        let managers = vec![mgr(1, &[(7, 4)], 0, 4)];
        let mut s = KubernetesRouting::new(0);
        let mut rng = Rng::new(2);
        assert_eq!(s.route(Some(ContainerId::from_bits(7)), &managers, &mut rng), None);
    }

    #[test]
    fn bin_packing_concentrates() {
        let managers = vec![mgr(1, &[], 9, 10), mgr(2, &[], 2, 10)];
        let mut s = BinPacking::default();
        let mut rng = Rng::new(7);
        // Least-available eligible manager is 2.
        assert_eq!(s.route(None, &managers, &mut rng), Some(ManagerId::from_bits(2)));
    }

    #[test]
    fn locality_prefers_owner_endpoint_within_a_tier() {
        let owner = EndpointId::from_bits(9);
        let hints = RouteHints { data_owner: Some(owner), ..Default::default() };
        // Both managers have warm type-7 and capacity; manager 1 is on
        // the owner endpoint, manager 2 (more capacity) is not: the
        // warming tiers tie, so locality decides.
        let managers =
            vec![on_ep(mgr(1, &[(7, 1)], 2, 10), 9), on_ep(mgr(2, &[(7, 1)], 8, 10), 5)];
        let table = RoutingTable::with_views(0, managers.clone());
        let mut s = LocalityAware::new(0);
        let mut rng = Rng::new(1);
        let c = Some(ContainerId::from_bits(7));
        assert_eq!(s.route_hinted(c, hints, &managers, &mut rng), Some(ManagerId::from_bits(1)));
        assert_eq!(
            s.route_hinted_indexed(c, hints, &table, &mut rng),
            Some(ManagerId::from_bits(1))
        );
        // Plain WarmingAware would pick manager 2 (more capacity).
        let mut wa = WarmingAware::default();
        assert_eq!(wa.route(c, &managers, &mut rng), Some(ManagerId::from_bits(2)));
        // Without a hint LocalityAware decides exactly like its inner.
        assert_eq!(
            s.route_hinted(c, RouteHints::default(), &managers, &mut rng),
            Some(ManagerId::from_bits(2))
        );
        assert_eq!(s.stats.local_routes.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn locality_never_trades_warmth_for_distance() {
        let owner = EndpointId::from_bits(9);
        let hints = RouteHints { data_owner: Some(owner), ..Default::default() };
        // Only the remote manager has the warm container: warmth wins
        // the tier, locality does not override it.
        let managers = vec![on_ep(mgr(1, &[], 5, 10), 9), on_ep(mgr(2, &[(7, 1)], 5, 10), 5)];
        let table = RoutingTable::with_views(0, managers.clone());
        let mut s = LocalityAware::new(0);
        let mut rng = Rng::new(2);
        let c = Some(ContainerId::from_bits(7));
        assert_eq!(s.route_hinted(c, hints, &managers, &mut rng), Some(ManagerId::from_bits(2)));
        assert_eq!(
            s.route_hinted_indexed(c, hints, &table, &mut rng),
            Some(ManagerId::from_bits(2))
        );
        assert_eq!(s.stats.remote_routes.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn locality_routes_containerless_tasks_to_the_data() {
        let owner = EndpointId::from_bits(9);
        let hints = RouteHints { data_owner: Some(owner), ..Default::default() };
        let managers = vec![
            on_ep(mgr(1, &[], 3, 10), 9),
            on_ep(mgr(2, &[], 9, 10), 5),
            on_ep(mgr(3, &[], 5, 10), 9),
        ];
        let table = RoutingTable::with_views(0, managers.clone());
        let mut s = LocalityAware::new(0);
        let mut rng = Rng::new(3);
        // Most capacity among the owner's managers: 3, not the globally
        // freest manager 2.
        assert_eq!(
            s.route_hinted(None, hints, &managers, &mut rng),
            Some(ManagerId::from_bits(3))
        );
        assert_eq!(
            s.route_hinted_indexed(None, hints, &table, &mut rng),
            Some(ManagerId::from_bits(3))
        );
        // Owner endpoint saturated: falls back off-endpoint rather than
        // stalling the task.
        let drained = vec![
            on_ep(mgr(1, &[], 0, 10), 9),
            on_ep(mgr(2, &[], 9, 10), 5),
            on_ep(mgr(3, &[], 0, 10), 9),
        ];
        assert_eq!(
            s.route_hinted(None, hints, &drained, &mut rng),
            Some(ManagerId::from_bits(2))
        );
        assert_eq!(s.stats.local_routes.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.remote_routes.load(Ordering::Relaxed), 1);
    }

    /// Replica holders count as data-local (§5 replication): with the
    /// owner's endpoint saturated, a manager on a replica holder beats
    /// the globally freest manager — and the pick is noted as a local
    /// route, on both the scan and the indexed path.
    #[test]
    fn locality_treats_replica_holders_as_local() {
        let owner = EndpointId::from_bits(9);
        let replica = EndpointId::from_bits(4);
        let hints = RouteHints {
            data_owner: Some(owner),
            data_replicas: [Some(replica), None, None],
        };
        let managers = vec![
            on_ep(mgr(1, &[], 0, 10), 9), // owner endpoint, drained
            on_ep(mgr(2, &[], 9, 10), 5), // freest, but data-remote
            on_ep(mgr(3, &[], 5, 10), 4), // replica holder
        ];
        let table = RoutingTable::with_views(0, managers.clone());
        let mut s = LocalityAware::new(0);
        let mut rng = Rng::new(4);
        assert_eq!(
            s.route_hinted(None, hints, &managers, &mut rng),
            Some(ManagerId::from_bits(3))
        );
        assert_eq!(
            s.route_hinted_indexed(None, hints, &table, &mut rng),
            Some(ManagerId::from_bits(3))
        );
        assert_eq!(s.stats.local_routes.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.remote_routes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn table_tier1_picks_best_warm() {
        let table = RoutingTable::with_views(
            0,
            vec![
                mgr(1, &[], 10, 10),
                mgr(2, &[(7, 1)], 5, 10),
                mgr(3, &[(7, 2)], 3, 10),
            ],
        );
        // Most warm-idle of type 7 wins (manager 3), despite less capacity.
        assert_eq!(
            table.best_warm(ContainerId::from_bits(7)),
            Some(ManagerId::from_bits(3))
        );
        assert_eq!(table.best_warm(ContainerId::from_bits(9)), None);
        assert!(table.any_capacity());
    }

    #[test]
    fn table_update_moves_candidates() {
        let mut table =
            RoutingTable::with_views(0, vec![mgr(1, &[(7, 1)], 5, 10), mgr(2, &[(7, 1)], 8, 10)]);
        let c = ContainerId::from_bits(7);
        // More capacity wins the warm tie.
        assert_eq!(table.best_warm(c), Some(ManagerId::from_bits(2)));
        // Drain manager 2's warm container: candidate flips to 1.
        table.update(ManagerId::from_bits(2), |v| {
            v.warm_idle.insert(c, 0);
        });
        assert_eq!(table.best_warm(c), Some(ManagerId::from_bits(1)));
        // Manager 2 still has the type deployed, so tier-2 prefers it
        // (more capacity).
        assert_eq!(table.best_deployed(c), Some(ManagerId::from_bits(2)));
        // Exhaust manager 1's capacity: it must drop out of every index.
        table.update(ManagerId::from_bits(1), |v| {
            v.available_slots = 0;
        });
        assert_eq!(table.best_warm(c), None);
        assert_eq!(table.view(ManagerId::from_bits(1)).unwrap().available_slots, 0);
    }

    #[test]
    fn table_remove_and_capacity_count() {
        let mut table =
            RoutingTable::with_views(0, vec![mgr(1, &[(7, 1)], 5, 10), mgr(2, &[], 0, 10)]);
        assert_eq!(table.len(), 2);
        assert!(table.any_capacity());
        assert!(table.remove(ManagerId::from_bits(1)).is_some());
        assert_eq!(table.len(), 1);
        assert!(!table.any_capacity(), "only the full manager remains");
        assert_eq!(table.best_warm(ContainerId::from_bits(7)), None);
        assert!(table.remove(ManagerId::from_bits(1)).is_none());
    }

    #[test]
    fn table_min_capacity_tracks_binpacking_order() {
        let mut table = RoutingTable::with_views(
            0,
            vec![mgr(1, &[], 9, 10), mgr(2, &[], 2, 10), mgr(3, &[], 0, 10)],
        );
        // Least-loaded eligible manager (3 has no capacity).
        assert_eq!(table.min_capacity(), Some(ManagerId::from_bits(2)));
        let mut s = BinPacking::default();
        let mut rng = Rng::new(1);
        assert_eq!(s.route_indexed(None, &table, &mut rng), Some(ManagerId::from_bits(2)));
        // Fill 2 completely: the pick moves to 1.
        table.update(ManagerId::from_bits(2), |v| v.available_slots = 0);
        assert_eq!(table.min_capacity(), Some(ManagerId::from_bits(1)));
        // Drain everyone: no pick.
        table.update(ManagerId::from_bits(1), |v| v.available_slots = 0);
        assert_eq!(table.min_capacity(), None);
        assert_eq!(s.route_indexed(None, &table, &mut rng), None);
    }

    #[test]
    fn route_indexed_agrees_on_fixtures() {
        let managers = vec![
            mgr(1, &[], 10, 10),
            mgr(2, &[(7, 1)], 5, 10),
            mgr(3, &[(9, 2)], 0, 10),
        ];
        let table = RoutingTable::with_views(0, managers.clone());
        let mut s = WarmingAware::default();
        for t in [5u128, 7, 9, 40] {
            let c = Some(ContainerId::from_bits(t));
            let mut r1 = Rng::new(11);
            let mut r2 = Rng::new(11);
            assert_eq!(
                s.route(c, &managers, &mut r1),
                s.route_indexed(c, &table, &mut r2),
                "scan and indexed disagree for type {t}"
            );
        }
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::check;

    fn arb_managers(g: &mut crate::testing::Gen) -> Vec<ManagerView> {
        let n = g.usize(1, 12);
        (0..n)
            .map(|i| {
                let total = g.usize(1, 16);
                let avail = g.usize(0, total + 1);
                let mut warm = HashMap::new();
                for c in 0..g.usize(0, 4) {
                    warm.insert(
                        ContainerId::from_bits(c as u128 + 1),
                        g.usize(0, avail.max(1) + 1),
                    );
                }
                ManagerView {
                    id: ManagerId::from_bits(i as u128 + 1),
                    deployed: warm.clone(),
                    warm_idle: warm,
                    available_slots: avail,
                    total_slots: total,
                    queued: 0,
                    endpoint: None,
                    cold_start_est_s: 0.0,
                }
            })
            .collect()
    }

    #[test]
    fn never_routes_to_full_manager() {
        // Invariant: every scheduler only picks managers with capacity.
        check("route-capacity", 300, |g| {
            let managers = arb_managers(g);
            let container = if g.bool() {
                Some(ContainerId::from_bits(g.usize(1, 5) as u128))
            } else {
                None
            };
            let mut rng = crate::common::rng::Rng::new(g.u64());
            let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
                Box::new(WarmingAware::default()),
                Box::new(Randomized::default()),
                Box::new(RoundRobin::default()),
                Box::new(BinPacking::default()),
            ];
            for s in schedulers.iter_mut() {
                if let Some(picked) = s.route(container, &managers, &mut rng) {
                    let m = managers.iter().find(|m| m.id == picked).unwrap();
                    assert!(
                        m.available_slots > 0,
                        "{} routed to a full manager",
                        s.name()
                    );
                }
            }
        });
    }

    /// Richer generator for the table-equivalence property: deployed ⊇
    /// warm-idle, non-zero queued, varying capacity.
    fn arb_managers_full(g: &mut crate::testing::Gen) -> Vec<ManagerView> {
        let n = g.usize(1, 14);
        (0..n)
            .map(|i| {
                let total = g.usize(1, 16);
                let avail = g.usize(0, total + 1);
                let queued = g.usize(0, 4);
                let mut deployed = HashMap::new();
                let mut warm = HashMap::new();
                for c in 1..=g.usize(0, 4) {
                    let dep = g.usize(0, 5);
                    let idle = g.usize(0, dep + 1);
                    if dep > 0 {
                        deployed.insert(ContainerId::from_bits(c as u128), dep);
                    }
                    if idle > 0 {
                        warm.insert(ContainerId::from_bits(c as u128), idle);
                    }
                }
                // A few managers leave their endpoint unadvertised, so
                // the locality property also covers the None case.
                let endpoint = if g.usize(0, 5) == 0 {
                    None
                } else {
                    Some(EndpointId::from_bits(g.usize(1, 4) as u128))
                };
                ManagerView {
                    id: ManagerId::from_bits(i as u128 + 1),
                    deployed,
                    warm_idle: warm,
                    available_slots: avail,
                    total_slots: total,
                    queued,
                    endpoint,
                    cold_start_est_s: 0.0,
                }
            })
            .collect()
    }

    fn apply_op(v: &mut ManagerView, op: usize, c: ContainerId) {
        match op {
            0 => v.queued += 1,
            1 => v.queued = v.queued.saturating_sub(1),
            2 => v.available_slots = (v.available_slots + 1).min(v.total_slots),
            3 => v.available_slots = v.available_slots.saturating_sub(1),
            4 => {
                *v.deployed.entry(c).or_insert(0) += 1;
                *v.warm_idle.entry(c).or_insert(0) += 1;
            }
            _ => {
                if let Some(n) = v.warm_idle.get_mut(&c) {
                    *n = n.saturating_sub(1);
                }
            }
        }
    }

    /// Route a probe sequence through both paths and assert equal
    /// decisions (helper for `indexed_matches_scan`). One long-lived RNG
    /// per path across the whole sequence, so a path that consumes a
    /// different number of draws (stream divergence) also fails.
    fn compare_paths(
        s: &mut WarmingAware,
        managers: &[ManagerView],
        table: &RoutingTable,
        seed: u64,
    ) {
        let mut r1 = crate::common::rng::Rng::new(seed);
        let mut r2 = crate::common::rng::Rng::new(seed);
        for round in 0..3 {
            for t in 0..6u128 {
                let c = if t == 0 { None } else { Some(ContainerId::from_bits(t)) };
                assert_eq!(
                    s.route(c, managers, &mut r1),
                    s.route_indexed(c, table, &mut r2),
                    "scan vs indexed diverged for container {c:?} (round {round})"
                );
            }
        }
    }

    /// THE indexed-routing invariant: `route_indexed` makes the same
    /// decision as the O(M) scan, including after arbitrary incremental
    /// updates and removals through the table.
    #[test]
    fn indexed_matches_scan() {
        check("route-indexed-eq", 300, |g| {
            let mut managers = arb_managers_full(g);
            let prefetch = g.usize(0, 3);
            let mut table = RoutingTable::with_views(prefetch, managers.clone());
            let mut s = WarmingAware { prefetch };
            compare_paths(&mut s, &managers, &table, g.u64());

            // Incremental updates (and occasional removals) must keep
            // the indexes exact.
            for _ in 0..g.usize(1, 25) {
                if managers.is_empty() {
                    break;
                }
                let i = g.usize(0, managers.len());
                let id = managers[i].id;
                if g.usize(0, 10) == 0 {
                    // swap_remove on both sides keeps view order aligned.
                    managers.swap_remove(i);
                    table.remove(id);
                } else {
                    let op = g.usize(0, 6);
                    let c = ContainerId::from_bits(g.usize(1, 5) as u128);
                    apply_op(&mut managers[i], op, c);
                    table.update(id, |v| apply_op(v, op, c));
                }
            }
            compare_paths(&mut s, &managers, &table, g.u64());
        });
    }

    /// The locality analogue of `indexed_matches_scan`: for every hint
    /// shape (no owner, an owner with managers, an owner nobody
    /// advertises), `LocalityAware::route_hinted_indexed` must decide
    /// exactly like the O(M) scan — including after arbitrary
    /// incremental updates and removals through the table.
    #[test]
    fn locality_indexed_matches_scan() {
        check("locality-indexed-eq", 300, |g| {
            let mut managers = arb_managers_full(g);
            let prefetch = g.usize(0, 3);
            let mut table = RoutingTable::with_views(prefetch, managers.clone());
            let mut s = LocalityAware::new(prefetch);
            let compare = |s: &mut LocalityAware,
                           managers: &[ManagerView],
                           table: &RoutingTable,
                           seed: u64| {
                let mut r1 = crate::common::rng::Rng::new(seed);
                let mut r2 = crate::common::rng::Rng::new(seed);
                // Owner 0 = no hint; owners 1..=3 exist in the pool;
                // owner 7 is advertised by nobody.
                for owner in [0u128, 1, 2, 3, 7] {
                    let hints = RouteHints {
                        data_owner: (owner > 0).then(|| EndpointId::from_bits(owner)),
                        // Endpoint 2 doubles as a replica holder, 9 is
                        // advertised by nobody: the indexed path must
                        // agree with the scan on multi-local hints too.
                        data_replicas: [
                            (owner > 0).then(|| EndpointId::from_bits(2)),
                            (owner > 0).then(|| EndpointId::from_bits(9)),
                            None,
                        ],
                    };
                    for t in 0..6u128 {
                        let c = if t == 0 { None } else { Some(ContainerId::from_bits(t)) };
                        assert_eq!(
                            s.route_hinted(c, hints, managers, &mut r1),
                            s.route_hinted_indexed(c, hints, table, &mut r2),
                            "locality scan vs indexed diverged for container {c:?} owner {owner}"
                        );
                    }
                }
            };
            compare(&mut s, &managers, &table, g.u64());
            for _ in 0..g.usize(1, 25) {
                if managers.is_empty() {
                    break;
                }
                let i = g.usize(0, managers.len());
                let id = managers[i].id;
                if g.usize(0, 10) == 0 {
                    managers.swap_remove(i);
                    table.remove(id);
                } else {
                    let op = g.usize(0, 6);
                    let c = ContainerId::from_bits(g.usize(1, 5) as u128);
                    apply_op(&mut managers[i], op, c);
                    table.update(id, |v| apply_op(v, op, c));
                }
            }
            compare(&mut s, &managers, &table, g.u64());
        });
    }

    /// The bin-packing analogue of `indexed_matches_scan`: the
    /// capacity-ordered index must reproduce the O(M) scan's decision,
    /// including after arbitrary incremental updates and removals.
    #[test]
    fn binpacking_indexed_matches_scan() {
        check("binpack-indexed-eq", 300, |g| {
            let mut managers = arb_managers_full(g);
            let prefetch = g.usize(0, 3);
            let mut table = RoutingTable::with_views(prefetch, managers.clone());
            let mut s = BinPacking { prefetch };
            let mut rng = crate::common::rng::Rng::new(g.u64());
            let compare = |s: &mut BinPacking,
                           managers: &[ManagerView],
                           table: &RoutingTable,
                           rng: &mut crate::common::rng::Rng| {
                assert_eq!(
                    s.route(None, managers, rng),
                    s.route_indexed(None, table, rng),
                    "bin-packing scan vs indexed diverged"
                );
            };
            compare(&mut s, &managers, &table, &mut rng);
            for _ in 0..g.usize(1, 25) {
                if managers.is_empty() {
                    break;
                }
                let i = g.usize(0, managers.len());
                let id = managers[i].id;
                if g.usize(0, 10) == 0 {
                    managers.swap_remove(i);
                    table.remove(id);
                } else {
                    let op = g.usize(0, 6);
                    let c = ContainerId::from_bits(g.usize(1, 5) as u128);
                    apply_op(&mut managers[i], op, c);
                    table.update(id, |v| apply_op(v, op, c));
                }
                compare(&mut s, &managers, &table, &mut rng);
            }
        });
    }

    #[test]
    fn warming_aware_never_cold_when_warm_exists() {
        // THE §6.2 invariant: if any manager has a warm idle container of
        // the required type AND capacity, warming-aware must pick such a
        // manager.
        check("route-warm-first", 300, |g| {
            let managers = arb_managers(g);
            let c = ContainerId::from_bits(g.usize(1, 5) as u128);
            let warm_exists = managers
                .iter()
                .any(|m| m.deployed.get(&c).copied().unwrap_or(0) > 0 && m.available_slots > 0);
            let mut rng = crate::common::rng::Rng::new(g.u64());
            let mut s = WarmingAware::default();
            if let Some(picked) = s.route(Some(c), &managers, &mut rng) {
                if warm_exists {
                    let m = managers.iter().find(|m| m.id == picked).unwrap();
                    assert!(
                        m.deployed.get(&c).copied().unwrap_or(0) > 0,
                        "warm manager existed but routing went cold"
                    );
                }
            } else {
                assert!(
                    managers.iter().all(|m| m.available_slots == 0),
                    "returned None despite available capacity"
                );
            }
        });
    }
}
