//! Workload generators for the evaluation harnesses (§7) and example
//! applications (§8): microbenchmarks, the uniform-random container mix
//! of Figs. 6–7, the MapReduce shuffle model of Table 1, and the Colmena
//! communication-stage model of Table 2.

use crate::common::ids::ContainerId;
use crate::common::rng::Rng;
use crate::data::{CommPattern, Transport, TransportModel};
use crate::sim::SimTask;

/// §7.2's three calibration functions.
pub fn noops(n: usize) -> Vec<SimTask> {
    vec![SimTask::noop(); n]
}

pub fn sleeps(n: usize, secs: f64) -> Vec<SimTask> {
    vec![SimTask::sleep(secs); n]
}

pub fn stresses(n: usize, secs: f64) -> Vec<SimTask> {
    vec![SimTask::sleep(secs); n] // CPU-bound == occupied worker in the sim
}

/// Figs. 6–7: `n` requests, each uniformly one of `types` container
/// types, all with the same duration.
pub fn uniform_container_mix(
    n: usize,
    types: &[ContainerId],
    duration_s: f64,
    rng: &mut Rng,
) -> Vec<SimTask> {
    (0..n)
        .map(|_| SimTask::with_container(*rng.choose(types).expect("types nonempty"), duration_s))
        .collect()
}

/// Ten container types as used in the §7.4 routing experiment.
pub fn ten_container_types() -> Vec<ContainerId> {
    (1..=10).map(ContainerId::from_bits).collect()
}

// ---------------------------------------------------------------------------
// MapReduce (Table 1)
// ---------------------------------------------------------------------------

/// Parameters of a MapReduce run (Table 1: 30 GB Wikipedia text,
/// 300 map + 300 reduce tasks, 90 000 chunks).
#[derive(Clone, Copy, Debug)]
pub struct MapReduceSpec {
    pub input_bytes: u64,
    pub maps: usize,
    pub reduces: usize,
    /// Fraction of input that is shuffled map→reduce (WordCount ≈ 0.1,
    /// Sort = 1.0 — "WordCount shuffles just one tenth of the data").
    pub shuffle_fraction: f64,
    /// CPU seconds per map task.
    pub map_cpu_s: f64,
    /// CPU seconds per reduce task.
    pub reduce_cpu_s: f64,
    /// Read-op multiplier for key-grouped reduce fetches (WordCount's
    /// reducers issue many small per-key reads; Sort streams ranges).
    pub read_op_multiplier: f64,
}

impl MapReduceSpec {
    pub fn wordcount_paper() -> Self {
        MapReduceSpec {
            input_bytes: 30 * 1024 * 1024 * 1024,
            maps: 300,
            reduces: 300,
            shuffle_fraction: 0.1,
            map_cpu_s: 1500.0,
            reduce_cpu_s: 200.0,
            read_op_multiplier: 3.0,
        }
    }

    pub fn sort_paper() -> Self {
        MapReduceSpec {
            input_bytes: 30 * 1024 * 1024 * 1024,
            maps: 300,
            reduces: 300,
            shuffle_fraction: 1.0,
            map_cpu_s: 100.0,
            reduce_cpu_s: 70.0,
            read_op_multiplier: 1.0,
        }
    }
}

/// Phase timings of a MapReduce run (Table 1's rows; per-task averages).
#[derive(Clone, Copy, Debug)]
pub struct MapReducePhases {
    pub input_read_s: f64,
    pub map_process_s: f64,
    pub intermediate_write_s: f64,
    pub intermediate_read_s: f64,
    pub reduce_process_s: f64,
    pub output_write_s: f64,
}

impl MapReducePhases {
    pub fn total(&self) -> f64 {
        self.input_read_s
            + self.map_process_s
            + self.intermediate_write_s
            + self.intermediate_read_s
            + self.reduce_process_s
            + self.output_write_s
    }
}

/// Per-chunk metadata/broker op costs at 300-way concurrency, seconds.
/// Calibrated so the Table-1 cells land in the paper's range: the broker
/// (Redis) and the Lustre MDS serialize per-chunk operations; MPI/ZMQ
/// exchange directly.
fn shuffle_op_cost(transport: Transport, read: bool) -> f64 {
    match (transport, read) {
        (Transport::Mpi, false) => 0.1e-3,
        (Transport::Mpi, true) => 0.15e-3,
        (Transport::ZeroMq, false) => 0.3e-3,
        (Transport::ZeroMq, true) => 0.5e-3,
        (Transport::InMemoryStore, false) => 8e-3,
        (Transport::InMemoryStore, true) => 10e-3,
        (Transport::SharedFs, false) => 20e-3,
        (Transport::SharedFs, true) => 35e-3,
    }
}

/// Model the per-task average phase times for a MapReduce app whose
/// shuffle uses `transport` (Table 1's comparison), with `parallel`
/// concurrently-running tasks per wave.
pub fn mapreduce_phases(
    spec: &MapReduceSpec,
    transport: Transport,
    parallel: usize,
) -> MapReducePhases {
    let model = TransportModel::theta(transport);
    // Input/output always live on the shared FS (the dataset's home).
    let fs = TransportModel::theta(Transport::SharedFs);
    let par = parallel.max(1) as f64;
    let op_scale = par / 300.0; // op costs calibrated at 300-way concurrency

    let chunk_in = spec.input_bytes as f64 / spec.maps as f64;
    let shuffle_per_task = spec.input_bytes as f64 * spec.shuffle_fraction / spec.maps as f64;

    // Streaming bandwidth per task when `par` tasks share the fabric.
    let shared_bw = |m: &TransportModel| (m.fabric_bps / par).min(m.beta_bps);

    let iw = spec.reduces as f64 * shuffle_op_cost(transport, false) * op_scale
        + shuffle_per_task / shared_bw(&model);
    // Reads are contended harder on the FS (uncoordinated seeks on OSTs).
    let read_contention = if transport == Transport::SharedFs { 2.0 } else { 1.0 };
    let ir = spec.maps as f64
        * shuffle_op_cost(transport, true)
        * spec.read_op_multiplier
        * op_scale
        + shuffle_per_task * read_contention / shared_bw(&model);

    MapReducePhases {
        input_read_s: fs.meta_s + chunk_in / shared_bw(&fs),
        map_process_s: spec.map_cpu_s,
        intermediate_write_s: iw,
        intermediate_read_s: ir,
        reduce_process_s: spec.reduce_cpu_s,
        output_write_s: fs.meta_s + shuffle_per_task / shared_bw(&fs),
    }
}

// ---------------------------------------------------------------------------
// Colmena (Table 2)
// ---------------------------------------------------------------------------

/// Table 2's four communication stages for one Colmena task.
#[derive(Clone, Copy, Debug)]
pub struct ColmenaStages {
    pub input_write_s: f64,
    pub input_read_s: f64,
    pub result_write_s: f64,
    pub result_read_s: f64,
}

/// Model Colmena's per-task communication stages (1 MB in / 1 MB out,
/// 1000 tasks; §7.3.2) for a given transport.
///
/// Four effective bandwidths per transport (client-write, worker-read,
/// contended result-write shared by all workers, hot result-read),
/// calibrated to the regime Table 2 measures: a Python client writing
/// through a broker vs Lustre, and every worker returning results at
/// once (the paper's 244.72 ms sharedFS result write is pure contention).
pub fn colmena_stages(transport: Transport, task_bytes: usize, workers: usize) -> ColmenaStages {
    let b = task_bytes as f64;
    let w = workers.max(1) as f64;
    // (client_write_bps, worker_read_bps, shared_result_bps, hot_read_bps)
    let (cw, wr, sw, hr) = match transport {
        Transport::InMemoryStore => (150e6, 1.4e9, 5.5e9, 9.0e9),
        Transport::SharedFs => (31e6, 92e6, 0.42e9, 300e6),
        Transport::Mpi => (2.0e9, 4.0e9, 8.0e9, 8.0e9),
        Transport::ZeroMq => (1.0e9, 3.0e9, 7.0e9, 7.0e9),
    };
    ColmenaStages {
        input_write_s: b / cw,
        input_read_s: b / wr,
        result_write_s: b / (sw / w),
        result_read_s: b / hr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_generators() {
        assert_eq!(noops(10).len(), 10);
        assert_eq!(sleeps(5, 1.0)[0].duration_s, 1.0);
        assert_eq!(stresses(5, 60.0)[0].duration_s, 60.0);
    }

    #[test]
    fn uniform_mix_covers_types() {
        let types = ten_container_types();
        let mut rng = Rng::new(1);
        let tasks = uniform_container_mix(3000, &types, 0.0, &mut rng);
        assert_eq!(tasks.len(), 3000);
        let mut seen = std::collections::HashSet::new();
        for t in &tasks {
            seen.insert(t.container.unwrap());
        }
        assert_eq!(seen.len(), 10, "3000 uniform draws must hit all 10 types");
    }

    #[test]
    fn table1_shape_redis_beats_sharedfs_on_shuffle() {
        // Table 1: Redis speeds the shuffle phases up to ~3x.
        for spec in [MapReduceSpec::wordcount_paper(), MapReduceSpec::sort_paper()] {
            let redis = mapreduce_phases(&spec, Transport::InMemoryStore, 300);
            let fs = mapreduce_phases(&spec, Transport::SharedFs, 300);
            assert!(
                fs.intermediate_write_s > redis.intermediate_write_s,
                "write: fs {} vs redis {}",
                fs.intermediate_write_s,
                redis.intermediate_write_s
            );
            assert!(
                fs.intermediate_read_s > redis.intermediate_read_s * 1.5,
                "read: fs {} vs redis {}",
                fs.intermediate_read_s,
                redis.intermediate_read_s
            );
        }
    }

    #[test]
    fn table1_sort_benefits_more_than_wordcount() {
        // §7.3.1: Sort (heavy shuffle) gains more from Redis than
        // WordCount (10% shuffle) — 55.7% vs 18.2% total improvement.
        let improvement = |spec: MapReduceSpec| {
            let redis = mapreduce_phases(&spec, Transport::InMemoryStore, 300).total();
            let fs = mapreduce_phases(&spec, Transport::SharedFs, 300).total();
            (fs - redis) / fs
        };
        let wc = improvement(MapReduceSpec::wordcount_paper());
        let sort = improvement(MapReduceSpec::sort_paper());
        assert!(sort > wc, "sort improvement {sort} must exceed wordcount {wc}");
    }

    #[test]
    fn table2_shape() {
        // Table 2: Redis beats sharedFS on every stage; result write is
        // the worst sharedFS stage.
        let redis = colmena_stages(Transport::InMemoryStore, 1 << 20, 100);
        let fs = colmena_stages(Transport::SharedFs, 1 << 20, 100);
        // Cells near the paper's values (ms): 7.15/32.31, 0.70/11.36,
        // 18.04/244.72, 0.11/3.50.
        assert!((redis.input_write_s - 7.15e-3).abs() < 3e-3);
        assert!((fs.result_write_s - 244.72e-3).abs() < 60e-3);
        assert!(fs.input_write_s > redis.input_write_s);
        assert!(fs.input_read_s > redis.input_read_s);
        assert!(fs.result_write_s > redis.result_write_s);
        assert!(fs.result_read_s > redis.result_read_s);
        assert!(
            fs.result_write_s > fs.input_write_s,
            "contended result write must dominate"
        );
    }
}
