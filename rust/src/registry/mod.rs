//! The persistent registry of users, functions, endpoints, and container
//! images (§3, §4.1 — the AWS RDS database stand-in).
//!
//! Functions are registered with a name, serialized body, optional
//! container image and sharing list; endpoints with descriptive metadata.
//! Every entity gets a UUID used for subsequent management/invocation.
//!
//! # Striping
//!
//! Internally the registry is split into [`N_STRIPES`] lock stripes
//! keyed by an id hash, so the per-submit lookups (function, endpoint)
//! issued concurrently by every service shard don't serialize behind one
//! `RwLock`. The registry itself is a single shared object handed to all
//! service shards — that sharing IS the cross-shard advertisement
//! replication: a store advertised via any shard's forwarder is
//! immediately visible to replica placement, locality routing, and
//! decommission drains running on every other shard.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::common::error::{Error, Result};
use crate::common::ids::{ContainerId, EndpointId, FunctionId, UserId, Uuid};
use crate::common::task::Payload;
use crate::containers::ContainerTech;
use crate::datastore::TieredStore;

/// Lock stripes. A small power of two: plenty for the handful of
/// service shards contending, cheap to scan for aggregate reads.
const N_STRIPES: usize = 8;

/// A registered function (§3 "Function registration").
#[derive(Clone, Debug)]
pub struct FunctionRecord {
    pub id: FunctionId,
    pub name: String,
    pub owner: UserId,
    /// Serialized function body. For built-in payloads this encodes the
    /// payload kind; for real funcX it would be the pickled Python.
    pub payload: Payload,
    /// Container image required for execution (§4.2), if any.
    pub container: Option<ContainerId>,
    /// Registration epoch (bookkeeping only).
    pub registered_at: f64,
}

/// Endpoint connection status as seen by the service.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EndpointStatus {
    /// Registered but no agent connected.
    Offline,
    /// Agent connected and heartbeating.
    Online,
    /// Heartbeats missed; tasks are queued, not dispatched (§4.1).
    Lost,
}

/// A registered endpoint (§3 "Endpoints").
#[derive(Clone, Debug)]
pub struct EndpointRecord {
    pub id: EndpointId,
    pub name: String,
    pub description: String,
    pub owner: UserId,
    pub status: EndpointStatus,
}

/// A registered container image (§4.2).
#[derive(Clone, Debug)]
pub struct ContainerRecord {
    pub id: ContainerId,
    pub name: String,
    /// Image technology: Docker for cloud, Singularity/Shifter for HPC.
    pub tech: ContainerTech,
}

#[derive(Default)]
struct RegistryState {
    functions: HashMap<FunctionId, FunctionRecord>,
    endpoints: HashMap<EndpointId, EndpointRecord>,
    containers: HashMap<ContainerId, ContainerRecord>,
    /// Endpoint payload stores advertised on connect (§5 peer
    /// auto-discovery): the service fabrics peer with these to resolve
    /// `rref`s, and reconnecting forwarders re-peer from here.
    stores: HashMap<EndpointId, Arc<TieredStore>>,
}

/// The registry service (RDS stand-in). Clone-shareable.
#[derive(Clone)]
pub struct Registry {
    stripes: Arc<Vec<RwLock<RegistryState>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            stripes: Arc::new((0..N_STRIPES).map(|_| RwLock::default()).collect()),
        }
    }
}

/// The stripe an id hashes to (mixed fold of the 128-bit id).
fn stripe_of(u: Uuid) -> usize {
    let x = (u.0 as u64) ^ ((u.0 >> 64) as u64);
    (x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % N_STRIPES
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn read(&self, u: Uuid) -> std::sync::RwLockReadGuard<'_, RegistryState> {
        self.stripes[stripe_of(u)].read().unwrap()
    }

    fn write(&self, u: Uuid) -> std::sync::RwLockWriteGuard<'_, RegistryState> {
        self.stripes[stripe_of(u)].write().unwrap()
    }

    // ---- functions -------------------------------------------------------

    pub fn register_function(
        &self,
        name: &str,
        owner: UserId,
        payload: Payload,
        container: Option<ContainerId>,
    ) -> FunctionId {
        let id = FunctionId::new();
        self.write(id.0).functions.insert(
            id,
            FunctionRecord {
                id,
                name: name.to_string(),
                owner,
                payload,
                container,
                registered_at: 0.0,
            },
        );
        id
    }

    pub fn function(&self, id: FunctionId) -> Result<FunctionRecord> {
        self.read(id.0)
            .functions
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("function {id}")))
    }

    /// Users may update functions they own (§3).
    pub fn update_function(
        &self,
        id: FunctionId,
        by: UserId,
        payload: Payload,
    ) -> Result<()> {
        let mut st = self.write(id.0);
        let f = st
            .functions
            .get_mut(&id)
            .ok_or_else(|| Error::NotFound(format!("function {id}")))?;
        if f.owner != by {
            return Err(Error::Forbidden(format!("{by} does not own function {id}")));
        }
        f.payload = payload;
        Ok(())
    }

    pub fn function_count(&self) -> usize {
        self.stripes.iter().map(|s| s.read().unwrap().functions.len()).sum()
    }

    // ---- endpoints -------------------------------------------------------

    pub fn register_endpoint(
        &self,
        name: &str,
        description: &str,
        owner: UserId,
    ) -> EndpointId {
        let id = EndpointId::new();
        self.write(id.0).endpoints.insert(
            id,
            EndpointRecord {
                id,
                name: name.to_string(),
                description: description.to_string(),
                owner,
                status: EndpointStatus::Offline,
            },
        );
        id
    }

    pub fn endpoint(&self, id: EndpointId) -> Result<EndpointRecord> {
        self.read(id.0)
            .endpoints
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("endpoint {id}")))
    }

    pub fn set_endpoint_status(&self, id: EndpointId, status: EndpointStatus) -> Result<()> {
        let mut st = self.write(id.0);
        let e = st
            .endpoints
            .get_mut(&id)
            .ok_or_else(|| Error::NotFound(format!("endpoint {id}")))?;
        e.status = status;
        Ok(())
    }

    pub fn endpoints(&self) -> Vec<EndpointRecord> {
        self.stripes
            .iter()
            .flat_map(|s| s.read().unwrap().endpoints.values().cloned().collect::<Vec<_>>())
            .collect()
    }

    /// Record the endpoint's advertised payload store (arrives over the
    /// agent link on connect; every service shard's fabric auto-peers
    /// with it so by-ref results resolve without manual wiring).
    pub fn advertise_store(&self, id: EndpointId, store: Arc<TieredStore>) {
        self.write(id.0).stores.insert(id, store);
    }

    /// The endpoint's last advertised store, if any.
    pub fn advertised_store(&self, id: EndpointId) -> Option<Arc<TieredStore>> {
        self.read(id.0).stores.get(&id).cloned()
    }

    /// Drop an endpoint's store advertisement (decommission: the
    /// registry's `Arc` pins the store — its spiller thread and spool —
    /// for as long as the advertisement stands, so operators retiring
    /// an endpoint for good should withdraw it). Returns whether one
    /// was recorded. Live `DataFabric` peers that already cloned the
    /// `Arc` keep resolving in-flight refs until they disconnect.
    pub fn withdraw_store(&self, id: EndpointId) -> bool {
        self.write(id.0).stores.remove(&id).is_some()
    }

    /// Every endpoint with a standing store advertisement — the
    /// candidate pool for frame replication and decommission re-homing,
    /// aggregated across stripes (advertisements made via any service
    /// shard are visible here).
    pub fn advertised_stores(&self) -> Vec<(EndpointId, Arc<TieredStore>)> {
        self.stripes
            .iter()
            .flat_map(|s| {
                s.read()
                    .unwrap()
                    .stores
                    .iter()
                    .map(|(id, st)| (*id, st.clone()))
                    .collect::<Vec<_>>()
            })
            .collect()
    }

    // ---- containers ------------------------------------------------------

    pub fn register_container(&self, name: &str, tech: ContainerTech) -> ContainerId {
        let id = ContainerId::new();
        self.write(id.0)
            .containers
            .insert(id, ContainerRecord { id, name: name.to_string(), tech });
        id
    }

    pub fn container(&self, id: ContainerId) -> Result<ContainerRecord> {
        self.read(id.0)
            .containers
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("container {id}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn function_register_lookup_update() {
        let r = Registry::new();
        let owner = UserId::new();
        let other = UserId::new();
        let f = r.register_function("process_stills", owner, Payload::Noop, None);
        assert_eq!(r.function(f).unwrap().name, "process_stills");
        assert_eq!(r.function_count(), 1);

        // owner may update
        r.update_function(f, owner, Payload::Sleep(1.0)).unwrap();
        assert_eq!(r.function(f).unwrap().payload, Payload::Sleep(1.0));
        // non-owner may not
        assert!(matches!(
            r.update_function(f, other, Payload::Noop),
            Err(Error::Forbidden(_))
        ));
        // unknown function
        assert!(r.function(FunctionId::new()).is_err());
    }

    #[test]
    fn endpoint_lifecycle() {
        let r = Registry::new();
        let owner = UserId::new();
        let e = r.register_endpoint("theta-knl", "ALCF Theta", owner);
        assert_eq!(r.endpoint(e).unwrap().status, EndpointStatus::Offline);
        r.set_endpoint_status(e, EndpointStatus::Online).unwrap();
        assert_eq!(r.endpoint(e).unwrap().status, EndpointStatus::Online);
        assert_eq!(r.endpoints().len(), 1);
        assert!(r.set_endpoint_status(EndpointId::new(), EndpointStatus::Online).is_err());
    }

    #[test]
    fn store_advertisement_roundtrips() {
        use crate::datastore::TieredConfig;
        let r = Registry::new();
        let e = r.register_endpoint("theta-knl", "ALCF Theta", UserId::new());
        assert!(r.advertised_store(e).is_none());
        let store = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
        r.advertise_store(e, store.clone());
        let got = r.advertised_store(e).expect("store advertised");
        assert_eq!(got.owner(), e);
        assert_eq!(got.epoch(), store.epoch());
        // Re-advertising (reconnect with a fresh store) replaces it.
        let fresh = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
        r.advertise_store(e, fresh.clone());
        assert_eq!(r.advertised_store(e).unwrap().epoch(), fresh.epoch());
        // Decommission: withdrawing releases the registry's pin.
        assert!(r.withdraw_store(e));
        assert!(!r.withdraw_store(e));
        assert!(r.advertised_store(e).is_none());
    }

    #[test]
    fn container_registry() {
        let r = Registry::new();
        let c = r.register_container("dials-env", ContainerTech::Singularity);
        assert_eq!(r.container(c).unwrap().tech, ContainerTech::Singularity);
        assert!(r.container(ContainerId::new()).is_err());
    }

    /// Aggregate reads see every stripe: records registered under ids
    /// that hash to different stripes all come back.
    #[test]
    fn aggregates_span_stripes() {
        use crate::datastore::TieredConfig;
        let r = Registry::new();
        let owner = UserId::new();
        let eps: Vec<_> =
            (0..64).map(|i| r.register_endpoint(&format!("ep{i}"), "", owner)).collect();
        for _ in 0..64 {
            r.register_function("f", owner, Payload::Noop, None);
        }
        assert_eq!(r.endpoints().len(), 64);
        assert_eq!(r.function_count(), 64);
        for e in &eps[..8] {
            let store = Arc::new(TieredStore::new(*e, TieredConfig::default()).unwrap());
            r.advertise_store(*e, store);
        }
        assert_eq!(r.advertised_stores().len(), 8);
    }
}
