//! §4.3 — the manager: represents one node's collective worker capacity.
//!
//! A manager partitions its node into worker slots, deploys/retains
//! containers ([`WarmPool`]), advertises warm types + availability to the
//! agent, and feeds tasks to blocking workers. Cold container starts cost
//! real time, sampled from the Table-3 model for the endpoint's
//! (system, tech) profile, scaled by `cold_start_scale` so tests and
//! examples can run the same code path quickly.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::batching::ResultBuffer;
use crate::common::error::Error;
use crate::common::ids::{ContainerId, EndpointId, ManagerId};
use crate::common::rng::Rng;
use crate::common::sync::Notify;
use crate::common::task::{Task, TaskResult, TaskState};
use crate::common::time::{Clock, Time};
use crate::containers::{StartCostModel, WarmPool};
use crate::datastore::DataFabric;
use crate::metrics::{FlightRecorder, LatencyBreakdown, TraceCtx, TraceKind};
use crate::routing::ManagerView;
use crate::runtime::{BatchItem, WorkerExecutor};
use crate::serialize::{unpack, Buffer, Value};

/// Mints the executor-backend pool key for each manager: backend worker
/// processes are keyed by `(pool_id, slot)`, so two managers sharing one
/// [`WorkerExecutor`] never collide on slot indices.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

struct Shared {
    /// Executor-backend key of this manager's pool.
    pool_id: u64,
    /// Tasks are shared handles: the queue holds the same allocation the
    /// forwarder cached and the link carried — no per-hop record clone.
    queue: Mutex<VecDeque<Arc<Task>>>,
    cv: Condvar,
    pool: Mutex<WarmPool>,
    /// Completed results, buffered and flushed in batches (§4.6 on the
    /// return path) instead of one channel send per result.
    results: ResultBuffer,
    /// Transient acquire failures that parked a worker on the condvar
    /// (oversubscribed pool); a healthy manager keeps this near zero.
    acquire_retries: AtomicU64,
    shutdown: AtomicBool,
}

/// A live manager with `workers` blocking worker threads.
pub struct Manager {
    pub id: ManagerId,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Endpoint whose data-fabric store is local to this manager
    /// (advertised in [`ManagerView`] for locality-aware routing).
    endpoint: Option<EndpointId>,
    /// Backend handle kept for out-of-band slot lifecycle (prewarm and
    /// reap run on the agent's tick thread, not in a worker).
    executor: Arc<dyn WorkerExecutor>,
    /// Cold-start estimate advertised before any start has been
    /// observed: the Table-3 model mean, scaled like the charged cost.
    cold_start_fallback_s: f64,
}

/// Everything a worker needs, bundled to keep spawn() readable.
#[derive(Clone)]
pub struct ManagerCtx {
    /// Worker backend the manager runs tasks through: in-process
    /// ([`crate::runtime::PayloadExecutor`], modeled start costs) or
    /// forked worker children ([`crate::runtime::ProcessExecutor`],
    /// measured start costs).
    pub executor: Arc<dyn WorkerExecutor>,
    /// Receives *batches* of results (size/idle/straggler-flushed by the
    /// manager's [`ResultBuffer`]).
    pub results: Sender<Vec<TaskResult>>,
    /// Signalled after each result-batch send so the agent's event loop
    /// wakes on completions instead of polling its result channel.
    pub wake: Arc<Notify>,
    /// Floor of the adaptive result-flush threshold
    /// ([`crate::common::config::EndpointConfig::result_batch`]; 1
    /// disables buffering).
    pub result_batch: usize,
    /// Data-fabric handle workers resolve [`crate::datastore::DataRef`]
    /// inputs through (§5 pass-by-reference); `None` means by-ref tasks
    /// fail cleanly at this endpoint.
    pub fabric: Option<Arc<DataFabric>>,
    /// The fabric's owning endpoint, advertised in [`ManagerView`] so
    /// [`crate::routing::LocalityAware`] can route tasks toward the
    /// store that holds their by-ref input.
    pub endpoint: Option<EndpointId>,
    /// Successful outputs above this size are `put()` into the fabric
    /// and returned as a `DataRef` (`"rref"`); inline below it. With no
    /// fabric attached, results always return inline.
    pub max_result_bytes: usize,
    pub clock: Arc<dyn Clock>,
    pub latency: Arc<LatencyBreakdown>,
    /// Flight recorder sink for worker-side trace events
    /// ([`TraceKind::WorkerStarted`] / [`TraceKind::WorkerFinished`] and
    /// typed failure terminals). A disabled recorder (capacity 0) makes
    /// every record a no-op.
    pub recorder: Arc<FlightRecorder>,
    pub start_model: StartCostModel,
    /// Multiplier on sampled cold-start times (1.0 = Table-3 realism;
    /// examples/tests use ~0.001 to keep wall-clock short).
    pub cold_start_scale: f64,
    /// How many queued same-container-type tasks one worker may claim
    /// for a single slot and flush to the backend as one pipelined
    /// batch, completing results out of order as replies land
    /// ([`crate::common::config::EndpointConfig::worker_pipeline_depth`]).
    /// 1 disables batching (strict one-task-per-dispatch).
    pub pipeline_depth: usize,
}

impl Manager {
    pub fn spawn(workers: usize, idle_timeout_s: f64, ctx: ManagerCtx, seed: u64) -> Self {
        Self::spawn_oversubscribed(workers, workers, idle_timeout_s, ctx, seed)
    }

    /// Like [`Manager::spawn`] but with container `slots` decoupled from
    /// worker threads. With `slots < workers`, transient acquire
    /// failures are the norm, not the exception — the configuration
    /// that exercises the bounded condvar park in `worker_loop`.
    pub fn spawn_oversubscribed(
        workers: usize,
        slots: usize,
        idle_timeout_s: f64,
        ctx: ManagerCtx,
        seed: u64,
    ) -> Self {
        let id = ManagerId::new();
        let endpoint = ctx.endpoint;
        let executor = ctx.executor.clone();
        let cold_start_fallback_s = ctx.start_model.mean() * ctx.cold_start_scale;
        let shared = Arc::new(Shared {
            pool_id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            pool: Mutex::new(WarmPool::new(slots, idle_timeout_s)),
            results: ResultBuffer::new(
                ctx.result_batch,
                ctx.results.clone(),
                ctx.wake.clone(),
                ctx.clock.clone(),
            ),
            acquire_retries: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                let ctx = ctx.clone();
                let mut rng = Rng::new(seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15));
                std::thread::Builder::new()
                    .name(format!("funcx-worker-{w}"))
                    .spawn(move || worker_loop(shared, ctx, &mut rng))
                    .expect("spawn worker")
            })
            .collect();
        Manager { id, shared, workers: handles, endpoint, executor, cold_start_fallback_s }
    }

    /// Enqueue routed tasks (the agent's dispatch; §6.2). Takes shared
    /// handles: enqueueing is O(1) per task regardless of payload size.
    pub fn enqueue(&self, tasks: Vec<Arc<Task>>) {
        let mut q = self.shared.queue.lock().unwrap();
        q.extend(tasks);
        drop(q);
        self.shared.cv.notify_all();
    }

    /// Straggler flush of the result buffer (the agent calls this on its
    /// loop tick so buffered results never wait longer than its idle
    /// bound). Returns how many results were flushed.
    pub fn flush_results(&self) -> usize {
        self.shared.results.flush()
    }

    /// Advertised view for the routing scheduler.
    pub fn view(&self) -> ManagerView {
        let pool = self.shared.pool.lock().unwrap();
        let queued = self.shared.queue.lock().unwrap().len();
        let fallback = self.cold_start_fallback_s;
        ManagerView {
            id: self.id,
            deployed: pool.deployed_census(),
            warm_idle: pool.warm_census(),
            available_slots: pool.available_slots(),
            total_slots: pool.capacity(),
            queued,
            endpoint: self.endpoint,
            cold_start_est_s: pool.start_cost_estimate().unwrap_or(fallback),
        }
    }

    /// Idle = no busy slots and nothing queued (strategy scale-in input).
    pub fn is_idle(&self) -> bool {
        let pool = self.shared.pool.lock().unwrap();
        pool.busy_slots().is_empty() && self.shared.queue.lock().unwrap().is_empty()
    }

    /// Reap idle containers past their timeout (§6.1); agent calls this
    /// on its strategy tick. Backend workers behind reaped slots are
    /// stopped.
    pub fn reap_idle(&self, now: Time) -> usize {
        let reaped = self.shared.pool.lock().unwrap().reap_idle_slots(now);
        for (slot, _) in &reaped {
            self.executor.stop_slot(self.shared.pool_id, *slot);
        }
        reaped.len()
    }

    /// Apply a predictive warm plan (the agent's EWMA pool sizing, see
    /// `docs/containers.md`): warm empty slots up to each type's floor —
    /// starting backend workers eagerly, off the task critical path —
    /// then reap warm-idle slots above the floors that have been idle
    /// longer than `grace_s`. Returns `(warmed, reaped)` slot counts.
    pub fn apply_warm_plan(
        &self,
        floors: &HashMap<ContainerId, usize>,
        grace_s: f64,
        now: Time,
    ) -> (usize, usize) {
        let mut warmed = 0usize;
        for (&ctype, &floor) in floors {
            loop {
                let slot = {
                    let mut pool = self.shared.pool.lock().unwrap();
                    // Deployed (busy + idle) counts toward the floor: a
                    // busy slot is warm again the moment its task ends.
                    let have = pool.deployed_census().get(&ctype).copied().unwrap_or(0);
                    if have >= floor {
                        break;
                    }
                    match pool.warm_slot(ctype, now) {
                        Some(s) => s,
                        None => break, // no empty slot left
                    }
                };
                // Start the backend outside the pool lock: a real
                // process spawn takes milliseconds, and workers must
                // keep acquiring while it forks.
                match self.executor.start_slot(self.shared.pool_id, slot) {
                    Ok(Some(measured)) => {
                        self.shared.pool.lock().unwrap().note_start_cost(measured);
                    }
                    Ok(None) => {}
                    Err(_) => {
                        // The slot never hosted a container; undo the
                        // warm marking and stop trying this tick.
                        self.shared.pool.lock().unwrap().vacate(slot);
                        break;
                    }
                }
                warmed += 1;
            }
        }
        if warmed > 0 {
            // Prewarmed slots can satisfy parked acquires.
            self.shared.cv.notify_all();
        }
        let mut pool = self.shared.pool.lock().unwrap();
        let reaped = pool.reap_excess(floors, grace_s, now);
        drop(pool);
        for (slot, _) in &reaped {
            self.executor.stop_slot(self.shared.pool_id, *slot);
        }
        (warmed, reaped.len())
    }

    pub fn cold_starts(&self) -> u64 {
        self.shared.pool.lock().unwrap().cold_starts()
    }

    pub fn warm_hits(&self) -> u64 {
        self.shared.pool.lock().unwrap().warm_hits()
    }

    /// Slots warmed ahead of demand (prewarm + predictive sizing).
    pub fn prewarmed(&self) -> u64 {
        self.shared.pool.lock().unwrap().prewarmed()
    }

    /// Transient acquire failures that parked a worker (see the bounded
    /// condvar wait in `worker_loop`).
    pub fn acquire_retries(&self) -> u64 {
        self.shared.acquire_retries.load(Ordering::Relaxed)
    }

    /// Stop workers and join them.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, ctx: ManagerCtx, rng: &mut Rng) {
    let executor = ctx.executor.clone();
    loop {
        // Blocking wait for a task (workers have a single responsibility
        // and use blocking communication; §4.3).
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                let (guard, _) =
                    shared.cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = guard;
            }
        };

        let now = ctx.clock.now();
        ctx.latency.on_started(task.id, now);
        if ctx.recorder.enabled() {
            ctx.recorder.record(
                &format!("endpoint-{}", task.endpoint),
                task.trace,
                Some(task.id),
                now,
                TraceKind::WorkerStarted { endpoint: task.endpoint },
            );
        }

        // Container acquisition: warm hit is free; cold start costs time.
        // Bare tasks share the nil "container" (the worker's own env).
        let container_key =
            task.container.unwrap_or(crate::common::ids::ContainerId(crate::Uuid::NIL));
        let (slot, cold) = {
            let mut pool = shared.pool.lock().unwrap();
            // With workers == slots this can only fail transiently; an
            // oversubscribed pool (slots < workers) saturates for real.
            match pool.acquire_with_origin(container_key, now) {
                Some(x) => x,
                None => {
                    // Put the task back and park on the condvar until a
                    // release (or prewarm) notifies. The old 5 ms wait
                    // degenerated into a ~200 Hz spin under a saturated
                    // pool; 500 ms is only the shutdown-safety backstop.
                    drop(pool);
                    shared.acquire_retries.fetch_add(1, Ordering::Relaxed);
                    let mut q = shared.queue.lock().unwrap();
                    q.push_front(task);
                    let (q, _timed_out) =
                        shared.cv.wait_timeout(q, Duration::from_millis(500)).unwrap();
                    drop(q);
                    continue;
                }
            }
        };
        // Pipelined claim: with the slot held, grab up to depth-1 more
        // queued tasks bound for the same container type, each stacking
        // one lease on the busy slot (`ContainerPool::add_lease`). The
        // whole batch then flushes to the backend as one dispatch with
        // `depth` request frames in flight; depth 1 reproduces strict
        // one-task-per-dispatch. Lock order is pool → queue, matching
        // `view`/`is_idle`.
        let depth = ctx.pipeline_depth.max(1);
        let mut batch: Vec<Arc<Task>> = vec![task];
        if depth > 1 {
            let mut pool = shared.pool.lock().unwrap();
            let mut q = shared.queue.lock().unwrap();
            while batch.len() < depth {
                let same_type = q.front().is_some_and(|t| {
                    t.container.unwrap_or(crate::common::ids::ContainerId(crate::Uuid::NIL))
                        == container_key
                });
                if !same_type || pool.add_lease(slot).is_err() {
                    break;
                }
                batch.push(q.pop_front().expect("front() was Some"));
            }
        }
        for extra in &batch[1..] {
            let t = ctx.clock.now();
            ctx.latency.on_started(extra.id, t);
            if ctx.recorder.enabled() {
                ctx.recorder.record(
                    &format!("endpoint-{}", extra.endpoint),
                    extra.trace,
                    Some(extra.id),
                    t,
                    TraceKind::WorkerStarted { endpoint: extra.endpoint },
                );
            }
        }

        if cold {
            // Cold slot: clear any previous tenant (eviction), then
            // start the backend container. A measured backend (process
            // executor) reports the real spawn cost; a modeled one
            // returns None and the Table-3 sample is charged as
            // wall-clock sleep. Either way the observed cost feeds the
            // pool's EWMA so predictive sizing and warming-aware routing
            // work off what starts actually cost here (§6.1 economics).
            executor.stop_slot(shared.pool_id, slot);
            let (seconds, measured) = match executor.start_slot(shared.pool_id, slot) {
                Ok(Some(s)) => (s, true),
                Ok(None) => {
                    let cost = ctx.start_model.sample(rng) * ctx.cold_start_scale;
                    if cost > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(cost));
                    }
                    (cost, false)
                }
                Err(e) => {
                    // The container never started: free the slot,
                    // wake a sibling, fail every claimed task typed.
                    shared.pool.lock().unwrap().vacate(slot);
                    shared.cv.notify_all();
                    for t in &batch {
                        finish_failed(&shared, &ctx, t, &e, true);
                    }
                    continue;
                }
            };
            shared.pool.lock().unwrap().note_start_cost(seconds);
            if ctx.recorder.enabled() {
                let first = &batch[0];
                ctx.recorder.record(
                    &format!("endpoint-{}", first.endpoint),
                    first.trace,
                    Some(first.id),
                    ctx.clock.now(),
                    TraceKind::ColdStart { endpoint: first.endpoint, seconds, measured },
                );
            }
        }
        // Exactly one result of this dispatch is charged the cold start
        // (the first to finish — with the old serial loop that was the
        // only task; pipelined, the claim rode the same start).
        let mut cold_credit = cold;

        // Materialize each task's input frame: inline tasks already
        // carry it (a borrowed view of the queue frame); by-ref tasks
        // resolve their DataRef through the endpoint's data fabric (§5).
        // An unresolvable ref — evicted, expired, stale epoch, or no
        // fabric attached — fails that task cleanly (typed terminal,
        // lease released) before the batch flushes, never panics.
        let mut items: Vec<BatchItem> = Vec::with_capacity(batch.len());
        let mut item_tasks: Vec<Arc<Task>> = Vec::with_capacity(batch.len());
        for task in batch {
            let input_frame: Result<Buffer, Error> = if !task.payload.reads_input() {
                Ok(Buffer::empty())
            } else {
                // Scope the trace context over the resolve so fabric
                // events (hit tier, peer retries, replica failover) land
                // in this task's trace, not as anonymous background.
                let _trc = TraceCtx::enter(task.trace, task.id);
                match (&task.input_ref, ctx.fabric.as_ref()) {
                    (Some(r), Some(fabric)) => fabric.resolve(r, ctx.clock.now()),
                    (Some(r), None) => Err(Error::Data(format!(
                        "ref {} undeliverable: no data fabric attached to this endpoint",
                        r.key
                    ))),
                    (None, _) => Ok(task.input.clone()),
                }
            };
            match input_frame {
                Ok(frame) => {
                    items.push(BatchItem { payload: task.payload.clone(), input: frame });
                    item_tasks.push(task);
                }
                Err(e) => {
                    let was_cold = std::mem::take(&mut cold_credit);
                    finish_failed(&shared, &ctx, &task, &e, was_cold);
                    let done = ctx.clock.now();
                    shared
                        .pool
                        .lock()
                        .unwrap()
                        .release(slot, done)
                        .expect("worker holds a lease on this slot; release must succeed");
                    shared.cv.notify_all();
                }
            }
        }

        if items.is_empty() {
            continue;
        }

        // One flush, out-of-order completion: the backend invokes the
        // closure once per item as replies land (a pipelined backend
        // demuxes by frame id; the default impl degrades to serial
        // execute_in). Successes arrive as *packed* output frames, so
        // the return path has no re-serialization hop (§4.3 worker).
        executor.execute_batch(
            shared.pool_id,
            slot,
            &items,
            &mut |i: usize, result: Result<(Buffer, f64)>| {
                let task = &item_tasks[i];
                let (state, output, exec_s) = match result {
                    Ok((frame, t)) => (TaskState::Success, frame, t),
                    Err(e) => {
                        // Worker-side typed terminal: the concrete error
                        // kind (WorkerExited, Timeout, ...) is only known
                        // here, before the result is flattened into a
                        // Failed state + message.
                        if ctx.recorder.enabled() {
                            ctx.recorder.record(
                                &format!("endpoint-{}", task.endpoint),
                                task.trace,
                                Some(task.id),
                                ctx.clock.now(),
                                TraceKind::TaskFailed { error: e.kind() },
                            );
                        }
                        (
                            TaskState::Failed,
                            crate::serialize::pack(&Value::Str(e.to_string()), 0).unwrap(),
                            0.0,
                        )
                    }
                };

                let done = ctx.clock.now();
                ctx.latency.on_finished(task.id, done);
                if ctx.recorder.enabled() {
                    ctx.recorder.record(
                        &format!("endpoint-{}", task.endpoint),
                        task.trace,
                        Some(task.id),
                        done,
                        TraceKind::WorkerFinished {
                            endpoint: task.endpoint,
                            success: state == TaskState::Success,
                        },
                    );
                }
                shared
                    .pool
                    .lock()
                    .unwrap()
                    .release(slot, done)
                    .expect("worker holds a lease on this slot; release must succeed");
                // Wake siblings blocked on a transient acquire failure.
                shared.cv.notify_all();

                // §5 result offload (return-path mirror of ref dispatch):
                // a successful output above the inline result cap is
                // stored in the endpoint's fabric and returned as a
                // compact `DataRef` (`"rref"`), keeping the bytes out of
                // the result queues. No fabric, or a store failure on an
                // already-successful execution, falls back to inline
                // rather than failing the task.
                let (output, output_ref) = match (&ctx.fabric, state) {
                    (Some(fabric), TaskState::Success)
                        if output.len() > ctx.max_result_bytes =>
                    {
                        let _trc = TraceCtx::enter(task.trace, task.id);
                        match fabric.put(
                            &format!("task-result:{}", task.id),
                            output.clone(),
                            done,
                        ) {
                            Ok(r) => (Buffer::empty(), Some(r)),
                            Err(_) => (output, None),
                        }
                    }
                    _ => (output, None),
                };

                // Idle flush when the queue looks drained: nothing else
                // is finishing soon, so don't sit on the tail of a burst.
                let idle = shared.queue.lock().unwrap().is_empty();
                shared.results.push(
                    TaskResult {
                        task: task.id,
                        state,
                        output,
                        output_ref,
                        exec_time_s: exec_s,
                        cold_start: std::mem::take(&mut cold_credit),
                    },
                    idle,
                );
            },
        );

        // Out-of-band start costs (lazily spawned or in-place restarted
        // children) feed the same EWMA as measured `start_slot` costs,
        // so predictive sizing sees every real spawn.
        for seconds in executor.drain_start_costs(shared.pool_id) {
            shared.pool.lock().unwrap().note_start_cost(seconds);
        }
    }
}

/// Fail a task that never reached execution (backend start failure):
/// typed terminal trace + `Failed` result, mirroring the post-execution
/// failure path so the flight-recorder trace still closes.
fn finish_failed(shared: &Shared, ctx: &ManagerCtx, task: &Arc<Task>, e: &Error, cold: bool) {
    let done = ctx.clock.now();
    ctx.latency.on_finished(task.id, done);
    if ctx.recorder.enabled() {
        let component = format!("endpoint-{}", task.endpoint);
        ctx.recorder.record(
            &component,
            task.trace,
            Some(task.id),
            done,
            TraceKind::TaskFailed { error: e.kind() },
        );
        ctx.recorder.record(
            &component,
            task.trace,
            Some(task.id),
            done,
            TraceKind::WorkerFinished { endpoint: task.endpoint, success: false },
        );
    }
    let idle = shared.queue.lock().unwrap().is_empty();
    shared.results.push(
        TaskResult {
            task: task.id,
            state: TaskState::Failed,
            output: crate::serialize::pack(&Value::Str(e.to_string()), 0).unwrap(),
            output_ref: None,
            exec_time_s: 0.0,
            cold_start: cold,
        },
        idle,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::*;
    use crate::common::task::Payload;
    use crate::common::time::WallClock;
    use crate::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
    use crate::runtime::PayloadExecutor;
    use crate::serialize::Buffer;
    use std::sync::mpsc::{channel, Receiver};

    fn ctx(results: Sender<Vec<TaskResult>>, result_batch: usize) -> ManagerCtx {
        ManagerCtx {
            executor: Arc::new(PayloadExecutor::bare()),
            results,
            wake: Arc::new(Notify::new()),
            result_batch,
            fabric: None,
            endpoint: None,
            max_result_bytes: 10 * 1024 * 1024,
            clock: Arc::new(WallClock::new()),
            latency: Arc::new(LatencyBreakdown::new()),
            recorder: FlightRecorder::disabled(),
            start_model: TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None),
            cold_start_scale: 0.001,
            // Depth 1 keeps the timing-sensitive tests (e.g. 4 parallel
            // sleeps across 4 workers) on strict task-per-dispatch.
            pipeline_depth: 1,
        }
    }

    fn mk_task(payload: Payload) -> Arc<Task> {
        Arc::new(Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            payload,
            Buffer::empty(),
        ))
    }

    /// Collect `n` results across however many batches they arrive in.
    fn recv_n(rx: &Receiver<Vec<TaskResult>>, n: usize) -> Vec<TaskResult> {
        let mut got = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while got.len() < n && std::time::Instant::now() < deadline {
            if let Ok(batch) = rx.recv_timeout(Duration::from_millis(100)) {
                got.extend(batch);
            }
        }
        assert_eq!(got.len(), n, "timed out collecting results");
        got
    }

    #[test]
    fn executes_tasks_and_returns_results() {
        let (tx, rx) = channel();
        let m = Manager::spawn(2, 600.0, ctx(tx, 32), 1);
        m.enqueue(vec![mk_task(Payload::Noop), mk_task(Payload::Noop)]);
        for r in recv_n(&rx, 2) {
            assert_eq!(r.state, TaskState::Success);
        }
        m.shutdown();
    }

    /// Pipelined claim: one worker on one slot with depth 4 drains a
    /// same-type burst by stacking leases, completes every task, and
    /// charges exactly one cold start for the whole run.
    #[test]
    fn batch_claim_completes_all_tasks() {
        let (tx, rx) = channel();
        let mut c = ctx(tx, 32);
        c.pipeline_depth = 4;
        let m = Manager::spawn(1, 600.0, c, 14);
        m.enqueue((0..8).map(|_| mk_task(Payload::Noop)).collect());
        let results = recv_n(&rx, 8);
        for r in &results {
            assert_eq!(r.state, TaskState::Success);
        }
        assert_eq!(
            results.iter().filter(|r| r.cold_start).count(),
            1,
            "one cold start charged across the batched run"
        );
        assert_eq!(m.cold_starts(), 1);
        let v = m.view();
        assert_eq!(v.available_slots, 1, "all leases released after the drain");
        m.shutdown();
    }

    #[test]
    fn view_reflects_capacity() {
        let (tx, _rx) = channel();
        let m = Manager::spawn(4, 600.0, ctx(tx, 32), 2);
        let v = m.view();
        assert_eq!(v.total_slots, 4);
        assert_eq!(v.available_slots, 4);
        assert!(m.is_idle());
        m.shutdown();
    }

    #[test]
    fn warm_reuse_after_first_task() {
        let (tx, rx) = channel();
        let m = Manager::spawn(1, 600.0, ctx(tx, 32), 3);
        m.enqueue(vec![mk_task(Payload::Noop)]);
        recv_n(&rx, 1);
        m.enqueue(vec![mk_task(Payload::Noop)]);
        let r2 = recv_n(&rx, 1).pop().unwrap();
        assert!(!r2.cold_start, "second task of same (nil) type must hit warm");
        assert_eq!(m.cold_starts(), 1);
        assert_eq!(m.warm_hits(), 1);
        m.shutdown();
    }

    #[test]
    fn parallel_sleep_overlaps() {
        let (tx, rx) = channel();
        let m = Manager::spawn(4, 600.0, ctx(tx, 32), 4);
        let t0 = std::time::Instant::now();
        m.enqueue((0..4).map(|_| mk_task(Payload::Sleep(0.2))).collect());
        recv_n(&rx, 4);
        let elapsed = t0.elapsed().as_secs_f64();
        assert!(elapsed < 0.6, "4 parallel 0.2s sleeps took {elapsed}s");
        m.shutdown();
    }

    #[test]
    fn failed_payload_reports_failure() {
        let (tx, rx) = channel();
        let m = Manager::spawn(1, 600.0, ctx(tx, 32), 5);
        // DataOp without a channel fails inside the executor.
        m.enqueue(vec![mk_task(Payload::DataOp)]);
        let r = recv_n(&rx, 1).pop().unwrap();
        assert_eq!(r.state, TaskState::Failed);
        m.shutdown();
    }

    /// Return-path batching: a burst through a buffered manager crosses
    /// the channel in far fewer sends than results, while a result_batch
    /// of 1 degrades to one send per result.
    #[test]
    fn results_cross_channel_in_batches() {
        let (tx, rx) = channel();
        let m = Manager::spawn(2, 600.0, ctx(tx, 16), 6);
        m.enqueue((0..64).map(|_| mk_task(Payload::Noop)).collect());
        let mut results = 0usize;
        let mut sends = 0usize;
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while results < 64 && std::time::Instant::now() < deadline {
            if let Ok(batch) = rx.recv_timeout(Duration::from_millis(100)) {
                sends += 1;
                results += batch.len();
            }
        }
        assert_eq!(results, 64);
        assert!(sends < 32, "64 results arrived in {sends} sends — batching inactive");
        m.shutdown();
    }

    /// A by-ref task on an endpoint with no fabric attached fails the
    /// task (clean Failed result, not a panic).
    #[test]
    fn ref_task_without_fabric_fails_cleanly() {
        let (tx, rx) = channel();
        let m = Manager::spawn(1, 600.0, ctx(tx, 1), 9);
        let dref = crate::datastore::DataRef {
            owner: EndpointId::new(),
            epoch: 1,
            key: "task-input:x".into(),
            size: 64,
            checksum: 0,
            replicas: Vec::new(),
        };
        let task = Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Echo,
            Buffer::empty(),
        )
        .with_input_ref(dref);
        m.enqueue(vec![Arc::new(task)]);
        let r = recv_n(&rx, 1).pop().unwrap();
        assert_eq!(r.state, TaskState::Failed);
        let msg = unpack(&r.output).unwrap();
        assert!(
            msg.as_str().unwrap_or("").contains("no data fabric"),
            "failure names the missing fabric: {msg:?}"
        );
        m.shutdown();
    }

    /// With a fabric attached, a by-ref Echo resolves its input frame
    /// from the store and echoes the original value.
    #[test]
    fn ref_task_resolves_through_fabric() {
        use crate::datastore::{DataFabric, TieredConfig, TieredStore};
        let store = Arc::new(
            TieredStore::new(EndpointId::new(), TieredConfig::default()).unwrap(),
        );
        let fabric = Arc::new(DataFabric::new(store));
        let input = Value::Bytes(vec![0x5A; 2048]);
        let frame = crate::serialize::pack(&input, 0).unwrap();
        let dref = fabric.put("task-input:t1", frame, 0.0).unwrap();

        let (tx, rx) = channel();
        let mut c = ctx(tx, 1);
        c.fabric = Some(fabric);
        let m = Manager::spawn(1, 600.0, c, 10);
        let task = Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Echo,
            Buffer::empty(),
        )
        .with_input_ref(dref);
        m.enqueue(vec![Arc::new(task)]);
        let r = recv_n(&rx, 1).pop().unwrap();
        assert_eq!(r.state, TaskState::Success);
        assert_eq!(unpack(&r.output).unwrap(), input);
        m.shutdown();
    }

    /// §5 result offload: an output above `max_result_bytes` comes back
    /// as a `DataRef` into the endpoint store — empty inline bytes,
    /// resolvable frame — while small outputs stay inline.
    #[test]
    fn oversized_result_returns_by_ref() {
        use crate::datastore::{DataFabric, TieredConfig, TieredStore};
        let ep = EndpointId::new();
        let store = Arc::new(TieredStore::new(ep, TieredConfig::default()).unwrap());
        let fabric = Arc::new(DataFabric::new(store));
        let (tx, rx) = channel();
        let mut c = ctx(tx, 1);
        c.fabric = Some(fabric.clone());
        c.endpoint = Some(ep);
        c.max_result_bytes = 4096;
        let m = Manager::spawn(1, 600.0, c, 11);
        assert_eq!(m.view().endpoint, Some(ep), "view advertises the fabric's endpoint");

        // Big echo: the 64 KB output offloads.
        let input = Value::Bytes(vec![0x7E; 64 * 1024]);
        let task = Task::new(
            FunctionId::new(),
            ep,
            UserId::new(),
            None,
            Payload::Echo,
            crate::serialize::pack(&input, 0).unwrap(),
        );
        m.enqueue(vec![Arc::new(task)]);
        let r = recv_n(&rx, 1).pop().unwrap();
        assert_eq!(r.state, TaskState::Success);
        let dref = r.output_ref.expect("oversized output must return by reference");
        assert_eq!(r.output.len(), 0, "inline bytes replaced by a placeholder");
        assert!(dref.size > 64 * 1024);
        assert_eq!(dref.owner, ep);
        let frame = fabric.resolve(&dref, 0.0).unwrap();
        assert_eq!(unpack(&frame).unwrap(), input);

        // Small echo: stays inline.
        let small = Value::Int(7);
        let task = Task::new(
            FunctionId::new(),
            ep,
            UserId::new(),
            None,
            Payload::Echo,
            crate::serialize::pack(&small, 0).unwrap(),
        );
        m.enqueue(vec![Arc::new(task)]);
        let r = recv_n(&rx, 1).pop().unwrap();
        assert!(r.output_ref.is_none());
        assert_eq!(unpack(&r.output).unwrap(), small);
        m.shutdown();
    }

    /// The zero-copy dispatch invariant at the manager hop: while queued
    /// and executing, the manager works on the *same* `Task` allocation
    /// the dispatcher holds — never a clone of the record or its payload.
    #[test]
    fn enqueued_tasks_are_shared_not_cloned() {
        let (tx, rx) = channel();
        let m = Manager::spawn(1, 600.0, ctx(tx, 1), 7);
        let task = mk_task(Payload::Sleep(0.3));
        m.enqueue(vec![task.clone()]);
        // Give the worker time to pop and start executing.
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(
            Arc::strong_count(&task),
            2,
            "worker must hold the same Task allocation while executing"
        );
        recv_n(&rx, 1);
        m.shutdown();
        assert_eq!(Arc::strong_count(&task), 1, "handle released after completion");
    }

    /// Satellite of the pool-accounting fixes: a saturated pool parks
    /// workers on the condvar instead of hot-looping. Four workers
    /// contending for one slot drain a serial backlog with only a
    /// handful of acquire retries; the old 5 ms spin burned hundreds
    /// over the same window.
    #[test]
    fn saturated_pool_parks_instead_of_spinning() {
        let (tx, rx) = channel();
        let m = Manager::spawn_oversubscribed(4, 1, 600.0, ctx(tx, 1), 12);
        m.enqueue((0..4).map(|_| mk_task(Payload::Sleep(0.15))).collect());
        let got = recv_n(&rx, 4);
        assert!(got.iter().all(|r| r.state == TaskState::Success));
        let retries = m.acquire_retries();
        assert!(retries > 0, "one slot vs four workers must contend at least once");
        assert!(retries < 40, "workers spun on acquire: {retries} retries");
        m.shutdown();
    }

    /// Predictive plan: floors warm empty slots ahead of demand (the
    /// next task hits warm — zero cold starts) and the reap half tears
    /// down warm slots above the floor once past the grace window.
    #[test]
    fn warm_plan_prewarms_and_reaps() {
        let (tx, rx) = channel();
        let m = Manager::spawn(2, 600.0, ctx(tx, 1), 13);
        let nil = ContainerId(crate::Uuid::NIL);
        let mut floors = HashMap::new();
        floors.insert(nil, 2);
        let (warmed, reaped) = m.apply_warm_plan(&floors, 0.0, 0.0);
        assert_eq!(warmed, 2);
        assert_eq!(reaped, 0);
        assert_eq!(m.prewarmed(), 2);
        // Re-applying the same plan is idempotent: the floor is met.
        let (warmed, _) = m.apply_warm_plan(&floors, 0.0, 0.5);
        assert_eq!(warmed, 0);
        m.enqueue(vec![mk_task(Payload::Noop)]);
        let r = recv_n(&rx, 1).pop().unwrap();
        assert!(!r.cold_start, "prewarmed slot serves the task warm");
        assert_eq!(m.cold_starts(), 0);
        // Dropping the floors reaps every warm slot once past grace.
        // The worker pushes its result before releasing the slot, so
        // poll until both slots have gone idle and been reaped.
        let mut reaped_total = 0;
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while reaped_total < 2 && std::time::Instant::now() < deadline {
            let (_, reaped) = m.apply_warm_plan(&HashMap::new(), 0.0, 1.0e9);
            reaped_total += reaped;
            if reaped_total < 2 {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        assert_eq!(reaped_total, 2);
        m.shutdown();
    }

    /// The advertised view carries a cold-start estimate: the scaled
    /// model mean before any start is observed, the pool's EWMA of
    /// charged costs after.
    #[test]
    fn view_advertises_cold_start_estimate() {
        let (tx, rx) = channel();
        let m = Manager::spawn(1, 600.0, ctx(tx, 1), 14);
        let v0 = m.view();
        assert!(v0.cold_start_est_s > 0.0, "fallback is the scaled model mean");
        m.enqueue(vec![mk_task(Payload::Noop)]);
        recv_n(&rx, 1);
        let v1 = m.view();
        assert!(v1.cold_start_est_s > 0.0, "observed EWMA after a cold start");
        m.shutdown();
    }

    /// Fault payloads through the default in-process backend surface as
    /// typed failures (the process backend kills a real child; the
    /// modeled one returns the same error kinds).
    #[test]
    fn fault_payloads_fail_typed() {
        let (tx, rx) = channel();
        let m = Manager::spawn(1, 600.0, ctx(tx, 1), 15);
        m.enqueue(vec![mk_task(Payload::Exit(3)), mk_task(Payload::Abort)]);
        let got = recv_n(&rx, 2);
        assert!(got.iter().all(|r| r.state == TaskState::Failed));
        m.shutdown();
    }
}
